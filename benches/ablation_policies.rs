//! Ablation: importance policy choice for the hi tier (paper Fig. 4 notes
//! MiKV is policy-agnostic — H2O, FastGen-style, etc. plug in).
//!
//! Compares H2O (accumulated attention), local (recency), random, and
//! LagKV (attention-free, lag-relative KV statistics) importance at a
//! fixed budget, for both MiKV retention and pure eviction. The gap
//! between policies under *eviction* vs under *MiKV* is the paper's core
//! robustness argument: retention makes the system far less sensitive to
//! the policy being wrong. Worst-bucket and p10 columns surface the tail
//! failures a mean can hide (see `benches/fragility_grid.rs` for the
//! dedicated fragility race).

mod common;

use mikv::bench::{Cell, Table};
use mikv::eval::{EvalTask, Harness};
use mikv::model::CacheMode;
use mikv::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(engine) = common::load_engine(&args) else { return };
    let n = common::n_samples(&args, 25);
    let dims = engine.dims().clone();
    let harness = Harness::new(&engine);
    let task = EvalTask::LineRet { n_lines: 20, filler: 0 };

    let mut modes: Vec<(String, CacheMode)> = Vec::new();
    for policy in ["h2o", "local", "random", "lagkv"] {
        let retain = format!("mikv:0.2:int2:policy={policy}");
        modes.push((retain.clone(), CacheMode::parse(&retain, &dims).unwrap()));
        // eviction with the same policy
        let mut evict = CacheMode::parse(&format!("mikv:0.2:int2:policy={policy}"), &dims).unwrap();
        if let CacheMode::Mikv { cfg, .. } = &mut evict {
            cfg.retention = mikv::kvcache::RetentionMode::Evict;
        }
        modes.push((format!("evict:0.2:policy={policy}"), evict));
    }

    let outcomes = harness.run(&task, &modes, n).unwrap();
    let mut t = Table::new(
        "ablation_policies",
        "Importance-policy sensitivity: retention vs eviction at 20% budget",
        &[
            "Policy",
            "Unimportant KVs",
            "Cache size",
            "Acc.",
            "Worst bucket",
            "p10",
            "Fidelity vs full",
        ],
    );
    for o in &outcomes {
        let (policy, handling) = if o.mode_name.starts_with("mikv") {
            (o.mode_name.rsplit('=').next().unwrap(), "retained int2")
        } else {
            (o.mode_name.rsplit('=').next().unwrap(), "evicted")
        };
        t.row(vec![
            policy.into(),
            handling.into(),
            Cell::Pct(o.cache_pct, 1),
            Cell::Pct(100.0 * o.accuracy, 1),
            Cell::Pct(100.0 * o.worst_bucket, 1),
            Cell::Pct(100.0 * o.p10_score, 1),
            Cell::Pct(100.0 * o.fidelity, 1),
        ]);
    }
    t.note(format!("n={n} samples."));
    t.note("Expected shape: eviction quality depends heavily on the policy; MiKV retention flattens the gap (no token is unrecoverable).");
    t.emit().unwrap();
}
