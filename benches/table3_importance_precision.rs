//! Paper Table 3: quantizing the importance cache itself (hi tier) —
//! importance ratio 20%, outlier-aware INT2 retained tier.

mod common;

use mikv::bench::{Cell, Table};
use mikv::eval::{EvalTask, Harness};
use mikv::model::CacheMode;
use mikv::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(engine) = common::load_engine(&args) else { return };
    let n = common::n_samples(&args, 30);
    let dims = engine.dims().clone();
    let harness = Harness::new(&engine);
    let task = EvalTask::LineRet {
        n_lines: args.get("lines", 20).unwrap(),
        filler: 0,
    };

    let specs = [
        ("FP16", "mikv:0.2:int2"),
        ("INT8", "mikv:0.2:int2:hi=int8"),
        ("INT4", "mikv:0.2:int2:hi=int4"),
        ("INT2", "mikv:0.2:int2:hi=int2"),
    ];
    let modes: Vec<(String, CacheMode)> = specs
        .iter()
        .map(|(_, m)| ((*m).to_string(), CacheMode::parse(m, &dims).unwrap()))
        .collect();
    let outcomes = harness.run(&task, &modes, n).unwrap();

    // paper Table 3 (cache %, acc %): fp16 33/92.6, int8 23/92.4,
    // int4 18/92.0, (int2 row: 16/65.0)
    let paper = [(33.0, 92.6), (23.0, 92.4), (18.0, 92.0), (16.0, 65.0)];
    let mut t = Table::new(
        "table3",
        "Reducing the importance-cache precision (ratio 20%, lo=INT2+balancer) — paper Table 3",
        &["Importance prec.", "KV cache size", "Acc.", "Fidelity vs full"],
    );
    for ((o, (prec, _)), (p_cache, p_acc)) in outcomes.iter().zip(&specs).zip(&paper) {
        t.row(vec![
            (*prec).into(),
            Cell::Str(format!("{:.0}% (paper {p_cache:.0}%)", o.cache_pct)),
            Cell::Str(format!("{:.1}% (paper {p_acc}%)", 100.0 * o.accuracy)),
            Cell::Pct(100.0 * o.fidelity, 1),
        ]);
    }
    t.note(format!("n={n} samples."));
    t.note("Shape to reproduce: INT8/INT4 importance cache holds accuracy at lower memory; overly aggressive (INT2) hi tier finally degrades.");
    t.emit().unwrap();
}
