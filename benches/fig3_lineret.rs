//! Paper Fig. 3b: line-retrieval accuracy vs cache budget for full cache,
//! H2O eviction, oracle eviction, and MiKV.
//!
//! The x-axis is the eviction/importance ratio; oracle keeps top-k
//! attention weights post-softmax with k = ratio × live-slots (the paper's
//! "foreknowledge" upper bound for eviction).

mod common;

use mikv::bench::{Cell, Table};
use mikv::eval::{EvalTask, Harness};
use mikv::model::CacheMode;
use mikv::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(engine) = common::load_engine(&args) else { return };
    let n = common::n_samples(&args, 30);
    let dims = engine.dims().clone();
    let harness = Harness::new(&engine);
    let n_lines = args.get("lines", 20).unwrap();
    let task = EvalTask::LineRet { n_lines, filler: 0 };

    // approximate live context length for the oracle's top-k conversion
    let ctx_len = 2 + n_lines * 4 + 2;

    let ratios = args
        .get_list("ratios", &[0.75, 0.5, 0.25, 0.2, 0.1])
        .unwrap();
    let mut modes: Vec<(String, CacheMode)> =
        vec![("full".into(), CacheMode::parse("full", &dims).unwrap())];
    for &r in &ratios {
        for prefix in ["h2o", "mikv"] {
            let s = if prefix == "mikv" {
                format!("mikv:{r}:int2")
            } else {
                format!("h2o:{r}")
            };
            modes.push((s.clone(), CacheMode::parse(&s, &dims).unwrap()));
        }
        let k = ((ctx_len as f64) * r).ceil() as usize;
        modes.push((
            format!("oracle@{r}"),
            CacheMode::Oracle { k: k.max(1) },
        ));
    }

    let outcomes = harness.run(&task, &modes, n).unwrap();

    let mut t = Table::new(
        "fig3",
        "Line retrieval: full vs H2O eviction vs oracle eviction vs MiKV — paper Fig. 3b",
        &["Strategy", "Budget ratio", "Cache size", "Acc.", "Fidelity vs full"],
    );
    t.row(vec![
        "full".into(),
        Cell::F(1.0, 2),
        Cell::Pct(outcomes[0].cache_pct, 0),
        Cell::Pct(100.0 * outcomes[0].accuracy, 1),
        Cell::Pct(100.0 * outcomes[0].fidelity, 1),
    ]);
    let mut i = 1;
    for &r in &ratios {
        for name in ["h2o (eviction)", "MiKV (retain int2)", "oracle (eviction)"] {
            let o = &outcomes[i];
            t.row(vec![
                name.into(),
                Cell::F(r, 2),
                Cell::Pct(o.cache_pct, 0),
                Cell::Pct(100.0 * o.accuracy, 1),
                Cell::Pct(100.0 * o.fidelity, 1),
            ]);
            i += 1;
        }
    }
    t.note(format!("n={n} samples, {n_lines} lines per sample."));
    t.note("Shape to reproduce (paper Fig. 3b): eviction accuracy collapses as budget shrinks, oracle degrades more slowly but still falls, MiKV stays near the full-cache line.");
    t.emit().unwrap();
}
