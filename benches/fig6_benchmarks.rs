//! Paper Fig. 6: quality vs compressed cache size across four benchmarks
//! (MMLU, GSM8k, HumanEval, Line Retrieval), MiKV vs H2O vs RTN.
//!
//! Real LLM benchmarks are unavailable offline (repro band 0); the panels
//! map to proxy tasks on the from-scratch model (see DESIGN.md):
//!   MMLU      → lm        (Markov continuation, agreement vs full cache)
//!   GSM8k     → multihop  (2-hop retrieval)
//!   HumanEval → pattern   (exact motif continuation)
//!   LineRet   → lineret   (the paper's own task, token-level)

mod common;

use mikv::bench::{Cell, Table};
use mikv::eval::{EvalTask, Harness};
use mikv::model::CacheMode;
use mikv::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(engine) = common::load_engine(&args) else { return };
    let n = common::n_samples(&args, 25);
    let dims = engine.dims().clone();
    let harness = Harness::new(&engine);

    let panels: Vec<(&str, EvalTask)> = vec![
        ("LineRetrieval", EvalTask::LineRet { n_lines: 20, filler: 0 }),
        ("GSM8k-proxy (multihop)", EvalTask::MultiHop { n_lines: 16 }),
        ("HumanEval-proxy (pattern)", EvalTask::Pattern { motif: 6, repeats: 8 }),
        ("MMLU-proxy (lm agreement)", EvalTask::Lm { context: 96, answer: 8 }),
    ];

    // x-axis sweep: strategies at decreasing cache budgets
    let specs: Vec<(&str, String)> = vec![
        ("full", "full".into()),
        ("MiKV 50%", "mikv:0.5:int4".into()),
        ("MiKV 25%", "mikv:0.25:int2".into()),
        ("MiKV 20%", "mikv:0.2:int2".into()),
        ("H2O 50%", "h2o:0.5".into()),
        ("H2O 25%", "h2o:0.25".into()),
        ("H2O 20%", "h2o:0.2".into()),
        ("RTN int4", "rtn:int4".into()),
        ("RTN int3", "rtn:int3".into()),
        ("RTN int2", "rtn:int2".into()),
    ];
    let modes: Vec<(String, CacheMode)> = specs
        .iter()
        .map(|(name, m)| ((*name).to_string(), CacheMode::parse(m, &dims).unwrap()))
        .collect();

    let mut t = Table::new(
        "fig6",
        "Quality vs compressed cache size: MiKV vs H2O vs RTN — paper Fig. 6 (proxy tasks)",
        &["Benchmark", "Strategy", "Cache size", "Score", "Fidelity vs full"],
    );
    for (panel, task) in &panels {
        let outcomes = harness.run(task, &modes, n).unwrap();
        for o in &outcomes {
            t.row(vec![
                (*panel).into(),
                o.mode_name.clone().into(),
                Cell::Pct(o.cache_pct, 1),
                Cell::Pct(100.0 * o.accuracy, 1),
                Cell::Pct(100.0 * o.fidelity, 1),
            ]);
        }
    }
    t.note(format!("n={n} samples per panel; proxies documented in DESIGN.md."));
    t.note("Shape to reproduce: MiKV tracks the full-cache score down to ~20% cache; H2O decays with budget; uniform RTN struggles at low bits.");
    t.emit().unwrap();
}
