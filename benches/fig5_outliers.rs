//! Paper Fig. 5 (and App. B Figs. 9–12): systematic outliers in the query
//! and key channels.
//!
//! The paper plots per-channel |Q|/|K|/|V| magnitudes and observes a few
//! channels with magnitudes far above the rest, consistent across the
//! sequence and duplicated by RoPE. We reproduce the *measurement*: per
//! (layer, head) channel maxima from real prefill passes, summarized as an
//! outlier ratio (top-channel max / median-channel max) per tensor.

mod common;

use mikv::bench::{Cell, Table};
use mikv::eval::{EvalTask, Harness};
use mikv::util::cli::Args;

fn channel_stats(maxima: &[f32], planes: usize, d: usize) -> Vec<(usize, f32, usize)> {
    // per plane: (plane, outlier_ratio, argmax channel)
    (0..planes)
        .map(|p| {
            let ch = &maxima[p * d..(p + 1) * d];
            let mut sorted: Vec<f32> = ch.to_vec();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = sorted[d / 2].max(1e-6);
            let (arg, max) = ch
                .iter()
                .enumerate()
                .fold((0, 0.0f32), |(ai, m), (i, &v)| if v > m { (i, v) } else { (ai, m) });
            (p, max / median, arg)
        })
        .collect()
}

fn main() {
    let args = Args::from_env();
    let Some(engine) = common::load_engine(&args) else { return };
    let n = common::n_samples(&args, 12);
    let harness = Harness::new(&engine);
    let task = EvalTask::LineRet { n_lines: 18, filler: 2 };
    let samples = harness.samples(&task, n);
    let prompts: Vec<Vec<i64>> = samples.iter().map(|s| s.prompt.clone()).collect();
    let prefills = engine.prefill_raw(&prompts).unwrap();

    let dims = engine.dims().clone();
    let planes = dims.planes();
    let d = dims.d_head;

    // aggregate per-channel maxima over samples
    let mut qmax = vec![0.0f32; planes * d];
    let mut kmax = vec![0.0f32; planes * d];
    // consistency: does the same channel dominate across samples?
    let mut per_sample_argmax: Vec<Vec<usize>> = vec![Vec::new(); planes];
    for pf in &prefills {
        for i in 0..planes * d {
            qmax[i] = qmax[i].max(pf.qmax[i]);
            kmax[i] = kmax[i].max(pf.kmax[i]);
        }
        for (p, _, arg) in channel_stats(&pf.kmax, planes, d) {
            per_sample_argmax[p].push(arg);
        }
    }

    let qstats = channel_stats(&qmax, planes, d);
    let kstats = channel_stats(&kmax, planes, d);

    let mut t = Table::new(
        "fig5",
        "Query/key channel outlier statistics from prefill — paper Fig. 5",
        &["Layer", "KV head", "Q outlier ratio", "K outlier ratio", "K outlier channel", "Channel stable across samples"],
    );
    let h = dims.n_kv_heads;
    for p in 0..planes {
        let stable = {
            let args_ = &per_sample_argmax[p];
            let first = args_[0];
            let same = args_.iter().filter(|&&a| a == first).count();
            format!("{}/{}", same, args_.len())
        };
        t.row(vec![
            Cell::Int((p / h) as i64),
            Cell::Int((p % h) as i64),
            Cell::F(qstats[p].1 as f64, 1),
            Cell::F(kstats[p].1 as f64, 1),
            Cell::Int(kstats[p].2 as i64),
            stable.into(),
        ]);
    }
    let mean_q: f64 = qstats.iter().map(|s| s.1 as f64).sum::<f64>() / planes as f64;
    let mean_k: f64 = kstats.iter().map(|s| s.1 as f64).sum::<f64>() / planes as f64;
    t.note(format!(
        "n={n} prompts; mean outlier ratio (max/median channel magnitude): Q {mean_q:.1}×, K {mean_k:.1}×."
    ));
    t.note("Paper's observation to reproduce: outlier channels exist in Q and K (ratio ≫ 1), and the dominating channel is stable within a sequence — the property eq. 2's prefill-computed balancer relies on.");
    t.emit().unwrap();
}
