//! Paper Table 2: query-key outlier awareness rescues the INT2 retained
//! cache (importance ratio 20%).

mod common;

use mikv::bench::{Cell, Table};
use mikv::eval::{EvalTask, Harness};
use mikv::model::CacheMode;
use mikv::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(engine) = common::load_engine(&args) else { return };
    let n = common::n_samples(&args, 30);
    let dims = engine.dims().clone();
    let harness = Harness::new(&engine);
    let task = EvalTask::LineRet {
        n_lines: args.get("lines", 20).unwrap(),
        filler: 0,
    };

    let specs = [
        ("INT3", "mikv:0.2:int3:nobal", "X"),
        ("INT3", "mikv:0.2:int3", "balancer"),
        ("INT2", "mikv:0.2:int2:nobal", "X"),
        ("INT2", "mikv:0.2:int2", "balancer"),
    ];
    let modes: Vec<(String, CacheMode)> = specs
        .iter()
        .map(|(_, m, _)| ((*m).to_string(), CacheMode::parse(m, &dims).unwrap()))
        .collect();
    let outcomes = harness.run(&task, &modes, n).unwrap();

    let paper = [(36.0, 100.0), (38.0, 99.8), (32.0, 64.0), (33.0, 92.6)];
    let mut t = Table::new(
        "table2",
        "Outlier-aware retained cache at importance ratio 20% — paper Table 2",
        &["Retained prec.", "Outlier-aware", "KV cache size", "Acc.", "Fidelity vs full"],
    );
    for ((o, (prec, _, aware)), (p_cache, p_acc)) in
        outcomes.iter().zip(&specs).zip(&paper)
    {
        t.row(vec![
            (*prec).into(),
            (*aware).into(),
            Cell::Str(format!("{:.0}% (paper {p_cache:.0}%)", o.cache_pct)),
            Cell::Str(format!("{:.1}% (paper {p_acc}%)", 100.0 * o.accuracy)),
            Cell::Pct(100.0 * o.fidelity, 1),
        ]);
    }
    t.note(format!("n={n} samples; balancer = dynamic query-key channel balancer (paper eq. 2-4)."));
    t.note("Shape to reproduce: the balancer recovers most of the INT2 gap at ~1pp cache-size cost.");
    t.emit().unwrap();
}
