//! Promotion-on-re-access bench: quality proxy and cost of the lo→hi tier
//! lifecycle (`BENCH_promotion.json`).
//!
//! Drives two identical MiKV sessions — promotion **on** vs **off** —
//! through a *late-emerging-importance* workload on real `CacheManager`s
//! (synthetic tensors; no compiled artifacts, runs anywhere including CI
//! smoke mode): a small "late set" of tokens gets almost no attention at
//! prefill (so it is demoted to the lo tier), then every decode step
//! concentrates ~90% of its attention mass on exactly those tokens. Per
//! configuration the bench measures:
//!
//! * **quality proxy** — token agreement vs the full-precision reference
//!   (the eval-harness metric, `eval::agreement::token_agreement`): each
//!   step computes an attention-weighted value readout through a fixed
//!   random vocabulary projection and compares the argmax "token" against
//!   the same readout over exact (uncompressed) values. Retention is
//!   lossy-once, so promotion is expected to hold agreement roughly equal
//!   — the gate is non-regression, not improvement;
//! * **hi-tier attention coverage** — the fraction of each step's
//!   attention mass landing on hi-precision slots: the paper's "important
//!   KV pairs kept at relatively higher precision" invariant, which the
//!   promotion pass exists to restore (gated: `on` must beat `off`);
//! * **cost** — promotions/step and `thrash_suppressed` from the manager
//!   counters, plus delta-assembly bytes/step from a per-session
//!   `StepArena` (promotion dirties the promoted + swapped rows, so its
//!   assembly cost is visible here).
//!
//! ```sh
//! cargo bench --bench perf_promotion             # full grid
//! cargo bench --bench perf_promotion -- --smoke  # CI grid
//! ```
//!
//! Outputs: `bench_out/perf_promotion.{md,json}` and
//! `BENCH_promotion.json` at the repo root (schema in EXPERIMENTS.md
//! §Promotion).

use mikv::bench::{Cell, Table};
use mikv::eval::agreement::token_agreement;
use mikv::kvcache::{Placement, PromotionConfig};
use mikv::model::assembly::{assemble_mikv, StepArena};
use mikv::model::{CacheMode, Session, SessionCache};
use mikv::quant::Precision;
use mikv::runtime::ModelDims;
use mikv::util::cli::Args;
use mikv::util::json::{Json, JsonObj};
use mikv::util::rng::Pcg32;

const VOCAB: usize = 32;
const LATE_SET: usize = 4;

fn dims(max_seq: usize) -> ModelDims {
    ModelDims {
        vocab: VOCAB,
        d_model: 128,
        n_layers: 2,
        n_q_heads: 4,
        n_kv_heads: 2,
        d_head: 32,
        d_ff: 128,
        max_seq,
        quant_group: 16,
        params: 0,
    }
}

/// A MiKV session at ratio 0.25 / int4, with or without promotion.
fn session(id: u64, d: &ModelDims, promotion: bool) -> Session {
    let mut mode = CacheMode::mikv(d, 0.25, Precision::Int4);
    if let CacheMode::Mikv { cfg, .. } = &mut mode {
        if promotion {
            cfg.promotion = Some(PromotionConfig::default());
        }
    }
    Session::new(id, d, mode).unwrap()
}

fn manager(sess: &Session) -> &mikv::kvcache::CacheManager {
    match &sess.cache {
        SessionCache::Mikv(m) => m,
        _ => unreachable!("bench sessions are MiKV"),
    }
}

/// Exact (uncompressed) per-slot values — the full-cache reference.
struct Reference {
    /// `[slot][planes * d]` V vectors as ingested.
    v: Vec<Vec<f32>>,
}

/// The step's attention row over `t` live slots: ~90% of the mass on the
/// late set, the rest uniform background.
fn attention_row(t: usize, late: &[usize]) -> Vec<f32> {
    let mut w = vec![0.1 / t as f32; t];
    for &s in late {
        w[s] += 0.9 / late.len() as f32;
    }
    w
}

/// Attention-weighted V readout through the session's *effective* cache
/// values, projected to a token id by the fixed random vocabulary matrix.
fn readout_token(
    sess: &Session,
    w: &[f32],
    planes: usize,
    d: usize,
    proj: &[f32],
) -> i64 {
    let m = manager(sess);
    let mut out = vec![0.0f32; planes * d];
    let mut kb = vec![0.0f32; d];
    let mut vb = vec![0.0f32; d];
    for p in 0..planes {
        for (s, &ws) in w.iter().enumerate() {
            if m.effective_kv_into(p, s, &mut kb, &mut vb) {
                for (o, &x) in out[p * d..(p + 1) * d].iter_mut().zip(vb.iter()) {
                    *o += ws * x;
                }
            }
        }
    }
    argmax_proj(&out, proj)
}

/// Same readout over the exact reference values.
fn reference_token(
    reference: &Reference,
    w: &[f32],
    planes: usize,
    d: usize,
    proj: &[f32],
) -> i64 {
    let mut out = vec![0.0f32; planes * d];
    for (s, &ws) in w.iter().enumerate() {
        for p in 0..planes {
            let v = &reference.v[s][p * d..(p + 1) * d];
            for (o, &x) in out[p * d..(p + 1) * d].iter_mut().zip(v.iter()) {
                *o += ws * x;
            }
        }
    }
    argmax_proj(&out, proj)
}

fn argmax_proj(out: &[f32], proj: &[f32]) -> i64 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (j, row) in proj.chunks(out.len()).enumerate() {
        let v: f32 = row.iter().zip(out.iter()).map(|(a, b)| a * b).sum();
        if v > best_v {
            best_v = v;
            best = j;
        }
    }
    best as i64
}

/// Fraction of the attention mass landing on hi-precision slots (plane 0;
/// the per-plane signals are identical in this workload).
fn hi_coverage(sess: &Session, w: &[f32]) -> f64 {
    let m = manager(sess);
    let total: f32 = w.iter().sum();
    let hi: f32 = w
        .iter()
        .enumerate()
        .filter(|&(s, _)| m.placement(0, s) == Placement::Hi)
        .map(|(_, &ws)| ws)
        .sum();
    (hi / total.max(1e-9)) as f64
}

struct ConfigResult {
    t0: usize,
    steps: usize,
    agreement_on: f64,
    agreement_off: f64,
    coverage_on: f64,
    coverage_off: f64,
    promotions: u64,
    thrash_suppressed: u64,
    promotions_per_step: f64,
    delta_bytes_on: f64,
    delta_bytes_off: f64,
}

fn run_config(t0: usize, steps: usize, seed: u64) -> anyhow::Result<ConfigResult> {
    let max_seq = (t0 + steps + 8).next_power_of_two();
    let d_model = dims(max_seq);
    let planes = d_model.planes();
    let d = d_model.d_head;
    let mut rng = Pcg32::new(seed);

    // Fixed random vocabulary projection for the readout proxy.
    let proj: Vec<f32> = (0..VOCAB * planes * d).map(|_| rng.gen_normal()).collect();

    // Prefill tensors; the late set is seeded as unimportant so prefill
    // placement demotes it.
    let late: Vec<usize> = (0..LATE_SET).map(|i| 2 + 3 * i).collect();
    let k: Vec<f32> = (0..planes * t0 * d).map(|_| rng.gen_normal()).collect();
    let v: Vec<f32> = (0..planes * t0 * d).map(|_| rng.gen_normal()).collect();
    let mut acc = vec![0.0f32; planes * t0];
    for p in 0..planes {
        for s in 0..t0 {
            acc[p * t0 + s] = if late.contains(&s) {
                0.001
            } else {
                0.2 + s as f32 * 0.002
            };
        }
    }
    let qmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
    let kmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();

    let mut on = session(1, &d_model, true);
    let mut off = session(2, &d_model, false);
    for sess in [&mut on, &mut off] {
        match &mut sess.cache {
            SessionCache::Mikv(m) => m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax),
            _ => unreachable!(),
        }
        sess.prompt_len = t0;
        sess.tokens = vec![1; t0];
        sess.last_token = 1;
    }
    for &s in &late {
        anyhow::ensure!(
            manager(&on).placement(0, s) == Placement::Lo,
            "late slot {s} must start in the lo tier"
        );
    }
    let mut reference = Reference {
        v: (0..t0)
            .map(|s| {
                let mut row = vec![0.0f32; planes * d];
                for p in 0..planes {
                    row[p * d..(p + 1) * d]
                        .copy_from_slice(&v[(p * t0 + s) * d..(p * t0 + s + 1) * d]);
                }
                row
            })
            .collect(),
    };

    // Per-session delta arenas (assembly-bytes cost of promotion churn).
    let mut arena_on = StepArena::for_mikv(&d_model);
    let mut arena_off = StepArena::for_mikv(&d_model);
    {
        let mut refs = [&mut on];
        assemble_mikv(&mut arena_on, &d_model, 1, &mut refs)?;
        let mut refs = [&mut off];
        assemble_mikv(&mut arena_off, &d_model, 1, &mut refs)?;
    }
    arena_on.reset_stats();
    arena_off.reset_stats();

    let mut tokens_ref = Vec::with_capacity(steps);
    let mut tokens_on = Vec::with_capacity(steps);
    let mut tokens_off = Vec::with_capacity(steps);
    let (mut cov_on, mut cov_off) = (0.0f64, 0.0f64);

    for _ in 0..steps {
        let t = on.cache.seq_len();
        let w = attention_row(t, &late);

        // Readouts on the pre-append state (what this step's query sees).
        tokens_ref.push(reference_token(&reference, &w, planes, d, &proj));
        tokens_on.push(readout_token(&on, &w, planes, d, &proj));
        tokens_off.push(readout_token(&off, &w, planes, d, &proj));
        cov_on += hi_coverage(&on, &w);
        cov_off += hi_coverage(&off, &w);

        // Ingest the same new token + attention into both caches and the
        // reference.
        let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
        let v_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
        let mut attn_prev = vec![0.0f32; planes * max_seq];
        for p in 0..planes {
            attn_prev[p * max_seq..p * max_seq + t].copy_from_slice(&w);
        }
        let attn_self = vec![0.01f32; planes];
        on.try_ingest_step(&k_new, &v_new, &attn_prev, &attn_self)?;
        off.try_ingest_step(&k_new, &v_new, &attn_prev, &attn_self)?;
        reference.v.push(v_new.clone());

        // Delta assembly after the mutation (promotion rows ride along).
        let mut refs = [&mut on];
        assemble_mikv(&mut arena_on, &d_model, 1, &mut refs)?;
        let mut refs = [&mut off];
        assemble_mikv(&mut arena_off, &d_model, 1, &mut refs)?;
    }

    let promo_on = manager(&on).promotion_stats();
    let promo_off = manager(&off).promotion_stats();
    anyhow::ensure!(
        promo_off.promotions == 0,
        "promotion-off session promoted: {promo_off:?}"
    );
    Ok(ConfigResult {
        t0,
        steps,
        agreement_on: token_agreement(&tokens_on, &tokens_ref),
        agreement_off: token_agreement(&tokens_off, &tokens_ref),
        coverage_on: cov_on / steps as f64,
        coverage_off: cov_off / steps as f64,
        promotions: promo_on.promotions,
        thrash_suppressed: promo_on.thrash_suppressed,
        promotions_per_step: promo_on.promotions as f64 / steps as f64,
        delta_bytes_on: arena_on.stats.bytes_copied as f64 / steps as f64,
        delta_bytes_off: arena_off.stats.bytes_copied as f64 / steps as f64,
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let default_t0: &[usize] = if smoke { &[48] } else { &[64, 192] };
    let t0_list: Vec<usize> = args.get_list("prefill-list", default_t0)?;
    let steps = args.get_nonzero("steps", if smoke { 24 } else { 48 })?;
    let seed = args.get("seed", 0x9907u64)?;

    let mut table = Table::new(
        "perf_promotion",
        "Promotion on re-access: quality proxy + cost, promotion on vs off",
        &[
            "t0", "steps", "agree_on", "agree_off", "cov_on", "cov_off",
            "promos", "per_step", "thrash", "deltaB_on", "deltaB_off",
        ],
    );
    table.note(format!(
        "planes=4 d_head=32 ratio=0.25 lo=int4 late_set={LATE_SET} steps={steps} \
         seed={seed:#x}; late-emerging-importance workload (~90% of attention \
         on tokens demoted at prefill); agreement = token agreement vs exact \
         values through a fixed readout; coverage = attention mass on hi slots"
    ));

    let mut results = Vec::new();
    for &t0 in &t0_list {
        let r = run_config(t0, steps, seed ^ ((t0 as u64) << 24))?;
        // Acceptance gates.
        anyhow::ensure!(
            r.promotions > 0,
            "the late-importance workload must trigger promotions (t0={t0})"
        );
        anyhow::ensure!(
            r.coverage_on > r.coverage_off + 0.2,
            "promotion must restore hi-tier attention coverage: on {:.3} vs off {:.3}",
            r.coverage_on,
            r.coverage_off
        );
        anyhow::ensure!(
            r.agreement_on >= r.agreement_off - 0.1,
            "promotion must not regress the quality proxy: on {:.3} vs off {:.3}",
            r.agreement_on,
            r.agreement_off
        );
        table.row(vec![
            r.t0.into(),
            r.steps.into(),
            Cell::F(r.agreement_on, 3),
            Cell::F(r.agreement_off, 3),
            Cell::F(r.coverage_on, 3),
            Cell::F(r.coverage_off, 3),
            Cell::Int(r.promotions as i64),
            Cell::F(r.promotions_per_step, 2),
            Cell::Int(r.thrash_suppressed as i64),
            Cell::F(r.delta_bytes_on, 0),
            Cell::F(r.delta_bytes_off, 0),
        ]);
        results.push(r);
    }
    table.emit()?;

    // Machine-readable trajectory point at the repo root.
    let mut o = JsonObj::new();
    o.set("bench", "perf_promotion");
    o.set("pending", false);
    o.set("smoke", smoke);
    o.set("planes", 4usize);
    o.set("d_head", 32usize);
    o.set("ratio", 0.25);
    o.set("lo", "int4");
    o.set("late_set", LATE_SET);
    o.set("steps", steps);
    o.set("seed", seed as i64);
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut ro = JsonObj::new();
            ro.set("t0", r.t0);
            ro.set("steps", r.steps);
            ro.set("agreement_on", r.agreement_on);
            ro.set("agreement_off", r.agreement_off);
            ro.set("hi_coverage_on", r.coverage_on);
            ro.set("hi_coverage_off", r.coverage_off);
            ro.set("promotions", r.promotions as i64);
            ro.set("promotions_per_step", r.promotions_per_step);
            ro.set("thrash_suppressed", r.thrash_suppressed as i64);
            ro.set("delta_bytes_per_step_on", r.delta_bytes_on);
            ro.set("delta_bytes_per_step_off", r.delta_bytes_off);
            ro.set(
                "assembly_bytes_ratio_on_over_off",
                r.delta_bytes_on / r.delta_bytes_off.max(1.0),
            );
            Json::Obj(ro)
        })
        .collect();
    o.set("results", Json::Arr(rows));
    std::fs::write("BENCH_promotion.json", Json::Obj(o).to_string_pretty())?;
    println!("wrote BENCH_promotion.json");
    Ok(())
}
