//! Cold-tier spill bench: snapshot codec latency + footprint and the
//! disk round trip (`BENCH_spill.json`).
//!
//! Builds real MiKV / Full sessions (synthetic tensors; no compiled
//! artifacts, runs anywhere including CI smoke mode), drives a prefill +
//! decode history into each, then measures per configuration:
//!
//! * **snapshot footprint** — encoded frame bytes vs the session's live
//!   host bytes and vs the dense FP32 K/V prefix it replaces on disk;
//! * **codec latency** — `encode_session` / `decode_session` wall time
//!   (median over `--iters` runs);
//! * **disk round trip** — `ColdStore::put` + `take` on a temp directory
//!   (atomic write-then-rename + read-back, the serving spill path);
//! * **fidelity gate** — re-encoding the restored session must reproduce
//!   the original frame byte for byte (the codec is deterministic, so
//!   bit-identical state ⇒ identical bytes; this is the cheap standalone
//!   form of the round-trip property test in `kvcache/spill.rs`).
//!
//! ```sh
//! cargo bench --bench perf_spill             # full grid
//! cargo bench --bench perf_spill -- --smoke  # CI grid
//! ```
//!
//! Outputs: `bench_out/perf_spill.{md,json}` and `BENCH_spill.json` at the
//! repo root (schema in EXPERIMENTS.md §Spill).

use mikv::bench::{Cell, Table};
use mikv::coordinator::ColdStore;
use mikv::kvcache::spill::{decode_session, encode_session};
use mikv::kvcache::BufferPool;
use mikv::model::{CacheMode, Session, SessionCache};
use mikv::quant::Precision;
use mikv::runtime::ModelDims;
use mikv::util::cli::Args;
use mikv::util::json::{Json, JsonObj};
use mikv::util::rng::Pcg32;
use std::time::Instant;

fn dims(max_seq: usize) -> ModelDims {
    ModelDims {
        vocab: 32,
        d_model: 128,
        n_layers: 2,
        n_q_heads: 4,
        n_kv_heads: 2,
        d_head: 32,
        d_ff: 128,
        max_seq,
        quant_group: 16,
        params: 0,
    }
}

/// One bench configuration: a cache mode driven to `t0 + steps` tokens.
struct Config {
    label: &'static str,
    mode: fn(&ModelDims) -> CacheMode,
    t0: usize,
    steps: usize,
}

fn mode_mikv4(d: &ModelDims) -> CacheMode {
    CacheMode::mikv(d, 0.25, Precision::Int4)
}

fn mode_mikv2(d: &ModelDims) -> CacheMode {
    CacheMode::mikv(d, 0.25, Precision::Int2)
}

fn mode_full(_d: &ModelDims) -> CacheMode {
    CacheMode::Full
}

/// Build a session with a random prefill and `steps` decode appends —
/// the state shape a parked multi-turn session actually spills with.
fn build_session(cfg: &Config, seed: u64) -> anyhow::Result<(ModelDims, Session)> {
    let max_seq = (cfg.t0 + cfg.steps + 8).next_power_of_two();
    let d_model = dims(max_seq);
    let planes = d_model.planes();
    let d = d_model.d_head;
    let mut rng = Pcg32::new(seed);

    let mut sess = Session::new(seed, &d_model, (cfg.mode)(&d_model))?;
    let k: Vec<f32> = (0..planes * cfg.t0 * d).map(|_| rng.gen_normal()).collect();
    let v: Vec<f32> = (0..planes * cfg.t0 * d).map(|_| rng.gen_normal()).collect();
    match &mut sess.cache {
        SessionCache::Mikv(m) => {
            let acc: Vec<f32> = (0..planes * cfg.t0).map(|_| rng.gen_f32()).collect();
            let qmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
            let kmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
            m.ingest_prefill(cfg.t0, &k, &v, &acc, &qmax, &kmax);
        }
        SessionCache::Full(f) => f.ingest_prefill(cfg.t0, &k, &v),
    }
    sess.tokens = (0..cfg.t0 as i64).collect();
    sess.prompt_len = cfg.t0;
    sess.last_token = 1;

    for _ in 0..cfg.steps {
        let t = sess.cache.seq_len();
        let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
        let v_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
        let mut attn_prev = vec![0.0f32; planes * max_seq];
        for p in 0..planes {
            for s in 0..t {
                attn_prev[p * max_seq + s] = rng.gen_f32() * 0.1;
            }
        }
        let attn_self = vec![0.01f32; planes];
        sess.try_ingest_step(&k_new, &v_new, &attn_prev, &attn_self)?;
        sess.tokens.push(rng.gen_range(0, 32));
    }
    Ok((d_model, sess))
}

fn median_us(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[xs.len() / 2]
}

struct RowResult {
    label: &'static str,
    t0: usize,
    steps: usize,
    seq_len: usize,
    snapshot_bytes: usize,
    host_bytes: usize,
    dense_bytes: usize,
    encode_us: f64,
    decode_us: f64,
    cold_put_us: f64,
    cold_take_us: f64,
}

fn run_config(cfg: &Config, iters: usize, seed: u64) -> anyhow::Result<RowResult> {
    let (d_model, sess) = build_session(cfg, seed)?;
    let frame = encode_session(&sess)?;
    let pool = BufferPool::new();

    // Fidelity gate: restore, then re-encode — must reproduce the frame
    // byte for byte.
    let restored = decode_session(&frame, &d_model, &pool)?;
    let reframe = encode_session(&restored)?;
    anyhow::ensure!(
        frame == reframe,
        "{}: re-encoded restored session differs from the original frame",
        cfg.label
    );
    drop(restored);

    let mut enc = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        let f = encode_session(&sess)?;
        enc.push(t.elapsed().as_secs_f64() * 1e6);
        anyhow::ensure!(f.len() == frame.len(), "encode is deterministic");
    }
    let mut dec = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        let s = decode_session(&frame, &d_model, &pool)?;
        dec.push(t.elapsed().as_secs_f64() * 1e6);
        drop(s);
    }

    // Disk round trip through the serving cold store.
    let root = std::env::temp_dir().join(format!(
        "mikv-perf-spill-{}-{}",
        std::process::id(),
        cfg.label
    ));
    let mut store = ColdStore::open(&root, 0, 1 << 30)?;
    let (mut puts, mut takes) = (Vec::with_capacity(iters), Vec::with_capacity(iters));
    for i in 0..iters {
        let t = Instant::now();
        anyhow::ensure!(store.put(i as u64, &frame)?, "put must fit the budget");
        puts.push(t.elapsed().as_secs_f64() * 1e6);
        let t = Instant::now();
        let back = store.take(i as u64)?;
        takes.push(t.elapsed().as_secs_f64() * 1e6);
        anyhow::ensure!(back.as_deref() == Some(frame.as_slice()), "cold read-back differs");
    }
    let _ = std::fs::remove_dir_all(&root);

    let seq = sess.cache.seq_len();
    let planes = d_model.planes();
    Ok(RowResult {
        label: cfg.label,
        t0: cfg.t0,
        steps: cfg.steps,
        seq_len: seq,
        snapshot_bytes: frame.len(),
        host_bytes: sess.cache.host_bytes(),
        // Dense FP32 K+V prefix the snapshot replaces on disk.
        dense_bytes: 2 * planes * seq * d_model.d_head * 4,
        encode_us: median_us(enc),
        decode_us: median_us(dec),
        cold_put_us: median_us(puts),
        cold_take_us: median_us(takes),
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let iters = args.get_nonzero("iters", if smoke { 5 } else { 25 })?;
    let seed = args.get("seed", 0x5B11u64)?;
    let (t0_small, t0_large, steps) = if smoke { (48, 96, 16) } else { (64, 384, 48) };

    let configs = [
        Config { label: "mikv_int4", mode: mode_mikv4, t0: t0_small, steps },
        Config { label: "mikv_int4_long", mode: mode_mikv4, t0: t0_large, steps },
        Config { label: "mikv_int2", mode: mode_mikv2, t0: t0_small, steps },
        Config { label: "full", mode: mode_full, t0: t0_small, steps },
    ];

    let mut table = Table::new(
        "perf_spill",
        "Cold-tier snapshot codec: footprint + latency + disk round trip",
        &[
            "mode", "t0", "steps", "seq", "snapB", "hostB", "denseB",
            "enc_us", "dec_us", "put_us", "take_us",
        ],
    );
    table.note(format!(
        "planes=4 d_head=32 ratio=0.25 iters={iters} seed={seed:#x}; median \
         wall times; snapB = encoded frame, hostB = live session footprint, \
         denseB = FP32 K+V prefix; gate: re-encode(restore(frame)) == frame \
         and MiKV snapshots beat the dense prefix on disk"
    ));

    let mut results = Vec::new();
    for cfg in &configs {
        let r = run_config(cfg, iters, seed ^ ((cfg.t0 as u64) << 20))?;
        if cfg.label.starts_with("mikv") {
            anyhow::ensure!(
                r.snapshot_bytes < r.dense_bytes,
                "{}: snapshot ({} B) must undercut the dense FP32 prefix ({} B)",
                r.label,
                r.snapshot_bytes,
                r.dense_bytes
            );
        }
        table.row(vec![
            Cell::Str(r.label.to_string()),
            r.t0.into(),
            r.steps.into(),
            r.seq_len.into(),
            Cell::Int(r.snapshot_bytes as i64),
            Cell::Int(r.host_bytes as i64),
            Cell::Int(r.dense_bytes as i64),
            Cell::F(r.encode_us, 1),
            Cell::F(r.decode_us, 1),
            Cell::F(r.cold_put_us, 1),
            Cell::F(r.cold_take_us, 1),
        ]);
        results.push(r);
    }
    table.emit()?;

    let mut o = JsonObj::new();
    o.set("bench", "perf_spill");
    o.set("pending", false);
    o.set("smoke", smoke);
    o.set("planes", 4usize);
    o.set("d_head", 32usize);
    o.set("iters", iters);
    o.set("seed", seed as i64);
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut ro = JsonObj::new();
            ro.set("mode", r.label);
            ro.set("t0", r.t0);
            ro.set("steps", r.steps);
            ro.set("seq_len", r.seq_len);
            ro.set("snapshot_bytes", r.snapshot_bytes);
            ro.set("host_bytes", r.host_bytes);
            ro.set("dense_fp32_bytes", r.dense_bytes);
            ro.set(
                "bytes_vs_dense",
                r.snapshot_bytes as f64 / r.dense_bytes as f64,
            );
            ro.set("encode_us_p50", r.encode_us);
            ro.set("decode_us_p50", r.decode_us);
            ro.set("cold_put_us_p50", r.cold_put_us);
            ro.set("cold_take_us_p50", r.cold_take_us);
            ro.set("roundtrip_bit_identical", true);
            Json::Obj(ro)
        })
        .collect();
    o.set("results", Json::Arr(rows));
    std::fs::write("BENCH_spill.json", Json::Obj(o).to_string_pretty())?;
    println!("wrote BENCH_spill.json");
    Ok(())
}
