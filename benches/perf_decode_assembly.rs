//! Decode-step input-assembly microbench: delta vs full rescatter
//! (`BENCH_decode.json`).
//!
//! Drives `model::assembly::assemble_mikv` — the exact code path
//! `Engine::decode_chunk_mikv` runs — on real `CacheManager`s fed
//! synthetic prefill/decode tensors, so it needs no compiled artifacts and
//! runs anywhere (including CI smoke mode). For every `b × seq` point it
//! measures, per steady-state step:
//!
//! * **ns/step** and **bytes-copied/step** on the *delta* path (dirty-row
//!   copies into the persistent arena) vs a forced *full rescatter*
//!   (`arena.invalidate()` before each assembly) at the same sequence
//!   length — the interleaved schedule keeps the two paths at identical
//!   occupancy so the ratio is apples-to-apples;
//! * **heap allocations/step**, via a counting global allocator — the
//!   zero-allocation acceptance gate: a steady-state assembly must not
//!   allocate at all, on either path.
//!
//! ```sh
//! cargo bench --bench perf_decode_assembly             # full grid
//! cargo bench --bench perf_decode_assembly -- --smoke  # CI grid
//! ```
//!
//! Outputs: `bench_out/perf_decode_assembly.{md,json}` and
//! `BENCH_decode.json` at the repo root (machine-readable; schema in
//! EXPERIMENTS.md §Decode assembly).

use mikv::bench::{Cell, Table};
use mikv::model::assembly::{assemble_mikv, StepArena};
use mikv::model::{CacheMode, Session, SessionCache};
use mikv::quant::Precision;
use mikv::runtime::ModelDims;
use mikv::util::cli::Args;
use mikv::util::json::{Json, JsonObj};
use mikv::util::rng::Pcg32;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Counts every heap allocation so the bench can assert the assembly path
/// makes none in steady state.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Llama-flavoured small dims: 4 planes, d_head 32, group d/2.
fn dims(max_seq: usize) -> ModelDims {
    ModelDims {
        vocab: 64,
        d_model: 128,
        n_layers: 2,
        n_q_heads: 4,
        n_kv_heads: 2,
        d_head: 32,
        d_ff: 128,
        max_seq,
        quant_group: 16,
        params: 0,
    }
}

fn prefill(sess: &mut Session, d: &ModelDims, t: usize, rng: &mut Pcg32) {
    let planes = d.planes();
    let dh = d.d_head;
    let k: Vec<f32> = (0..planes * t * dh).map(|_| rng.gen_normal()).collect();
    let v: Vec<f32> = (0..planes * t * dh).map(|_| rng.gen_normal()).collect();
    let acc: Vec<f32> = (0..planes * t).map(|_| rng.gen_f32()).collect();
    let qmax: Vec<f32> = (0..planes * dh).map(|_| rng.gen_f32() + 0.5).collect();
    let kmax: Vec<f32> = (0..planes * dh).map(|_| rng.gen_f32() + 0.5).collect();
    match &mut sess.cache {
        SessionCache::Mikv(m) => m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax),
        _ => unreachable!(),
    }
    sess.prompt_len = t;
    sess.tokens = vec![1; t];
    sess.last_token = 1;
}

fn append(sess: &mut Session, d: &ModelDims, rng: &mut Pcg32) {
    let planes = d.planes();
    let dh = d.d_head;
    let k: Vec<f32> = (0..planes * dh).map(|_| rng.gen_normal()).collect();
    let v: Vec<f32> = (0..planes * dh).map(|_| rng.gen_normal()).collect();
    let ap: Vec<f32> = (0..planes * d.max_seq).map(|_| rng.gen_f32() * 0.1).collect();
    let asf: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
    sess.try_ingest_step(&k, &v, &ap, &asf).expect("seq bound");
    sess.last_token = (sess.last_token + 1) % 64;
    sess.tokens.push(sess.last_token);
}

struct ConfigResult {
    b: usize,
    seq: usize,
    delta_ns: f64,
    full_ns: f64,
    delta_bytes: f64,
    full_bytes: f64,
    delta_allocs_max: u64,
    full_allocs_max: u64,
    arena_host_bytes: usize,
}

fn run_config(b: usize, seq: usize, steps: usize, seed: u64) -> anyhow::Result<ConfigResult> {
    const WARMUP: usize = 3;
    let d = dims(seq);
    let mut rng = Pcg32::new(seed);
    let t0 = seq
        .checked_sub(steps + WARMUP + 2)
        .ok_or_else(|| anyhow::anyhow!("seq {seq} too short for {steps} steps"))?;
    let mode = CacheMode::mikv(&d, 0.25, Precision::Int4);
    let mut sessions: Vec<Session> = (0..b)
        .map(|i| {
            let mut s = Session::new(i as u64 + 1, &d, mode.clone())?;
            prefill(&mut s, &d, t0, &mut rng);
            Ok(s)
        })
        .collect::<anyhow::Result<_>>()?;
    let mut arena = StepArena::for_mikv(&d);

    // Warmup: shape the arena, reach steady pool/tracker capacities, and
    // exercise both paths once (delta, then invalidate → full).
    for _ in 0..WARMUP {
        for s in sessions.iter_mut() {
            append(s, &d, &mut rng);
        }
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        assemble_mikv(&mut arena, &d, b, &mut refs)?;
        arena.invalidate();
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        assemble_mikv(&mut arena, &d, b, &mut refs)?;
    }
    arena.reset_stats();

    // Interleaved measurement: per step, one append, then the delta
    // assembly (dirty rows only) and a forced full rescatter at the SAME
    // sequence length.
    let (mut delta_ns, mut full_ns) = (0u64, 0u64);
    let (mut delta_bytes, mut full_bytes) = (0u64, 0u64);
    let (mut delta_allocs_max, mut full_allocs_max) = (0u64, 0u64);
    for _ in 0..steps {
        for s in sessions.iter_mut() {
            append(s, &d, &mut rng);
        }
        {
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            let bytes0 = arena.stats.bytes_copied;
            let a0 = allocs();
            let t = Instant::now();
            assemble_mikv(&mut arena, &d, b, &mut refs)?;
            delta_ns += t.elapsed().as_nanos() as u64;
            delta_allocs_max = delta_allocs_max.max(allocs() - a0);
            delta_bytes += arena.stats.bytes_copied - bytes0;
        }
        arena.invalidate();
        {
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            let bytes0 = arena.stats.bytes_copied;
            let a0 = allocs();
            let t = Instant::now();
            assemble_mikv(&mut arena, &d, b, &mut refs)?;
            full_ns += t.elapsed().as_nanos() as u64;
            full_allocs_max = full_allocs_max.max(allocs() - a0);
            full_bytes += arena.stats.bytes_copied - bytes0;
        }
    }

    anyhow::ensure!(
        arena.stats.grows == 0,
        "arena reshaped mid-measurement ({} grows)",
        arena.stats.grows
    );
    anyhow::ensure!(
        arena.stats.delta_lanes as usize == steps * b,
        "delta path missed: {} of {} lanes",
        arena.stats.delta_lanes,
        steps * b
    );

    Ok(ConfigResult {
        b,
        seq,
        delta_ns: delta_ns as f64 / steps as f64,
        full_ns: full_ns as f64 / steps as f64,
        delta_bytes: delta_bytes as f64 / steps as f64,
        full_bytes: full_bytes as f64 / steps as f64,
        delta_allocs_max,
        full_allocs_max,
        arena_host_bytes: arena.host_bytes(),
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let default_b: &[usize] = if smoke { &[1, 4] } else { &[1, 4, 8] };
    let default_seq: &[usize] = if smoke { &[256, 1024] } else { &[256, 1024, 4096] };
    let b_list: Vec<usize> = args.get_list("batch-list", default_b)?;
    let seq_list: Vec<usize> = args.get_list("seq-list", default_seq)?;
    let steps = args.get_nonzero("steps", if smoke { 12 } else { 24 })?;
    let seed = args.get("seed", 0xA55Eu64)?;

    let mut table = Table::new(
        "perf_decode_assembly",
        "Decode-step input assembly: delta (dirty rows) vs full rescatter",
        &[
            "b", "seq", "delta_ns", "full_ns", "speedup", "delta_B", "full_B",
            "bytes_ratio", "allocs",
        ],
    );
    table.note(format!(
        "planes=4 d_head=32 groups=2 ratio=0.25 lo=int4 steps={steps} seed={seed:#x}; \
         per-step means over steady state; allocs = max heap allocations in \
         one assembly call (must be 0)"
    ));

    let mut results = Vec::new();
    for &seq in &seq_list {
        for &b in &b_list {
            let r = run_config(b, seq, steps, seed ^ ((b as u64) << 32) ^ seq as u64)?;
            // Acceptance gates.
            anyhow::ensure!(
                r.delta_allocs_max == 0 && r.full_allocs_max == 0,
                "assembly allocated (delta {} / full {} allocs per step at b={b} seq={seq})",
                r.delta_allocs_max,
                r.full_allocs_max
            );
            let ratio = r.full_bytes / r.delta_bytes.max(1.0);
            if seq == 1024 {
                anyhow::ensure!(
                    ratio >= 5.0,
                    "delta path must copy >=5x fewer bytes at seq=1024, got {ratio:.1}x"
                );
            }
            table.row(vec![
                b.into(),
                seq.into(),
                Cell::F(r.delta_ns, 0),
                Cell::F(r.full_ns, 0),
                Cell::F(r.full_ns / r.delta_ns.max(1.0), 1),
                Cell::F(r.delta_bytes, 0),
                Cell::F(r.full_bytes, 0),
                Cell::F(ratio, 1),
                Cell::Int((r.delta_allocs_max + r.full_allocs_max) as i64),
            ]);
            results.push(r);
        }
    }
    table.emit()?;

    // Machine-readable trajectory point at the repo root.
    let mut o = JsonObj::new();
    o.set("bench", "perf_decode_assembly");
    o.set("pending", false);
    o.set("smoke", smoke);
    o.set("planes", 4usize);
    o.set("d_head", 32usize);
    o.set("groups", 2usize);
    o.set("ratio", 0.25);
    o.set("lo", "int4");
    o.set("steps", steps);
    o.set("seed", seed as i64);
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut ro = JsonObj::new();
            ro.set("b", r.b);
            ro.set("seq", r.seq);
            ro.set("delta_ns_per_step", r.delta_ns);
            ro.set("full_ns_per_step", r.full_ns);
            ro.set("delta_bytes_per_step", r.delta_bytes);
            ro.set("full_bytes_per_step", r.full_bytes);
            ro.set("bytes_ratio_full_over_delta", r.full_bytes / r.delta_bytes.max(1.0));
            ro.set("assembly_speedup_full_over_delta", r.full_ns / r.delta_ns.max(1.0));
            ro.set("delta_allocs_per_step", r.delta_allocs_max as i64);
            ro.set("full_allocs_per_step", r.full_allocs_max as i64);
            ro.set("arena_host_bytes", r.arena_host_bytes);
            Json::Obj(ro)
        })
        .collect();
    o.set("results", Json::Arr(rows));
    std::fs::write("BENCH_decode.json", Json::Obj(o).to_string_pretty())?;
    println!("wrote BENCH_decode.json");
    Ok(())
}
