//! Paper Table 5: KV cache memory footprint, batch 8 × seq 4096.
//!
//! Pure arithmetic over the published architectures — reproduced exactly,
//! alongside the architecture-correct FP16 figures (the paper's numbers
//! correspond to 4 bytes/value and, for Llama-2-70b, 64 layers; see
//! `mikv::memory` docs and DESIGN.md).

use mikv::bench::{Cell, Table};
use mikv::kvcache::TierConfig;
use mikv::memory::{
    cache_bytes_at_pct, fmt_gb, full_cache_bytes, mikv_cache_bytes, paper_models,
    paper_table5_claimed_bytes,
};
use mikv::quant::Precision;

fn main() {
    let (batch, seq) = (8, 4096);
    let mut t = Table::new(
        "table5",
        "KV cache memory footprint (batch 8, seq 4096) — paper Table 5",
        &[
            "Model", "GQA", "Cache %", "Paper claim", "Ours (paper conv.)",
            "Ours (FP16 exact)", "MiKV tiers (hi=FP16 + lo=INT2)",
        ],
    );
    for m in paper_models() {
        for pct in [100.0, 25.0, 20.0] {
            let claim: &str = match (m.name, pct as i64) {
                ("Llama-2-7b", 100) => "34.36GB",
                ("Llama-2-7b", 25) => "8.59GB",
                ("Llama-2-7b", 20) => "6.87GB",
                ("Mistral-7b", 100) => "8.59GB",
                ("Mistral-7b", 25) => "2.15GB",
                ("Mistral-7b", 20) => "1.72GB",
                ("Llama-2-13b", 100) => "53.69GB",
                ("Llama-2-13b", 25) => "13.42GB",
                ("Llama-2-13b", 20) => "10.74GB",
                ("Llama-2-70b", 100) => "17.18GB",
                ("Llama-2-70b", 25) => "4.30GB",
                ("Llama-2-70b", 20) => "3.44GB",
                _ => "-",
            };
            let ours_claimconv =
                (paper_table5_claimed_bytes(&m, batch, seq) as f64 * pct / 100.0) as u64;
            let ours_fp16 = cache_bytes_at_pct(&m, batch, seq, pct);
            // a MiKV tier mix that actually lands at ~pct
            let mikv = if pct < 100.0 {
                let (hi_f, hi, lo) = mikv::memory::tiers_for_target_pct(pct, m.head_dim);
                fmt_gb(mikv_cache_bytes(&m, batch, seq, &hi, &lo, hi_f))
            } else {
                fmt_gb(mikv_cache_bytes(
                    &m,
                    batch,
                    seq,
                    &TierConfig::fp16(),
                    &TierConfig::quantized(Precision::Int2, m.head_dim / 2),
                    1.0,
                ))
            };
            t.row(vec![
                m.name.into(),
                if m.gqa() { "yes" } else { "no" }.into(),
                Cell::Pct(pct, 0),
                claim.into(),
                fmt_gb(ours_claimconv).into(),
                fmt_gb(ours_fp16).into(),
                mikv.into(),
            ]);
        }
    }
    t.note("Paper claims match our reproduction under the paper's convention (4 bytes/value; Llama-2-70b computed with 64 layers — see DESIGN.md §Deviations).");
    t.note(format!(
        "FP16-exact column uses 2 bytes/value and true layer counts; e.g. Llama-2-7b full = {}.",
        fmt_gb(full_cache_bytes(&paper_models()[0], batch, seq))
    ));
    t.emit().unwrap();
}
