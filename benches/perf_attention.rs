//! §3.4 / §Perf: decode-step latency and serving throughput of the
//! mixed-precision path vs the full-precision path.
//!
//! The paper's claim is that mixed-precision KV enables weight-only-quant
//! kernels that beat fp batch-GEMV on memory-bound GPUs. On this CPU-PJRT
//! testbed the analogous statement is: the MiKV decode step (two-tier
//! fused attention + cache-manager bookkeeping + logically-compressed
//! state) costs ≈ the full-cache decode step. This bench feeds
//! EXPERIMENTS.md §Perf.

mod common;

use mikv::bench::{fmt_bytes, fmt_duration, Bencher, Cell, Table};
use mikv::model::{CacheMode, Session};
use mikv::quant::Precision;
use mikv::util::cli::Args;
use mikv::util::rng::Pcg32;

fn main() {
    let args = Args::from_env();
    let Some(engine) = common::load_engine(&args) else { return };
    let dims = engine.dims().clone();
    let mut rng = Pcg32::new(1);
    let prompt_len = args.get("prompt", 128usize).unwrap().min(dims.max_seq - 40);
    let iters = args.get("iters", 12usize).unwrap();

    let mk_prompt = |rng: &mut Pcg32| -> Vec<i64> {
        (0..prompt_len)
            .map(|_| 1 + rng.gen_below(dims.vocab as u32 - 1) as i64)
            .collect()
    };

    let mut t = Table::new(
        "perf_attention",
        "Decode-step latency: mixed-precision vs full cache — §3.4 / §Perf",
        &["Path", "Batch", "p50", "p99", "tokens/s", "Cache %", "Host/session"],
    );

    let cases: Vec<(&str, CacheMode)> = vec![
        ("full fp", CacheMode::Full),
        ("MiKV 20% int2", CacheMode::mikv(&dims, 0.2, Precision::Int2)),
        ("MiKV 25% int4", CacheMode::mikv(&dims, 0.25, Precision::Int4)),
        ("RTN int8", CacheMode::rtn(&dims, Precision::Int8)),
        ("H2O 20% (evict)", CacheMode::h2o(&dims, 0.2)),
    ];

    for batch in engine.batches("decode_mikv") {
        for (name, mode) in &cases {
            // build `batch` prefilled sessions
            let prompts: Vec<Vec<i64>> = (0..batch).map(|_| mk_prompt(&mut rng)).collect();
            let mut sessions: Vec<Session> = (0..batch)
                .map(|i| Session::new(i as u64, &dims, mode.clone()).unwrap())
                .collect();
            {
                let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
                engine.prefill(&mut refs, &prompts).unwrap();
            }
            // bench decode steps (each iteration advances the cache by one
            // token; plenty of headroom below max_seq)
            let stats = Bencher::new(format!("{name}-b{batch}"))
                .warmup(2)
                .iters(iters)
                .run(|| {
                    let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
                    let rows = engine.decode_step(&mut refs).unwrap();
                    for (sess, row) in refs.iter_mut().zip(rows) {
                        let tok = mikv::model::sampler::greedy(&row);
                        sess.last_token = tok;
                        sess.tokens.push(tok);
                    }
                });
            t.row(vec![
                (*name).into(),
                Cell::Int(batch as i64),
                fmt_duration(stats.p50).into(),
                fmt_duration(stats.p99).into(),
                Cell::F(stats.per_second(batch as f64), 1),
                Cell::F(sessions[0].cache.cache_size_pct(), 1),
                fmt_bytes(sessions[0].cache.host_bytes()).into(),
            ]);
        }
    }

    // prefill latency reference
    let prompts: Vec<Vec<i64>> = vec![mk_prompt(&mut rng)];
    let stats = Bencher::new("prefill-b1").warmup(1).iters(5).run(|| {
        engine.prefill_raw(&prompts).unwrap();
    });
    t.note(format!(
        "prefill (len {prompt_len}, b=1): p50 {}",
        fmt_duration(stats.p50)
    ));
    t.note("Target (§Perf): MiKV decode ≤ 1.15× full-cache decode at equal batch.");
    t.emit().unwrap();
}
