//! Shared helpers for the benchmark binaries (each regenerates one paper
//! table/figure; `cargo bench` runs them all and writes `bench_out/*.md`).

use mikv::model::Engine;
use mikv::util::cli::Args;

/// Artifacts directory: `--artifacts` flag or `./artifacts`.
pub fn artifacts_dir(args: &Args) -> String {
    args.get_str("artifacts", "artifacts")
}

/// Load the engine for the bench, or explain how to build artifacts.
/// Returns `None` (after printing) when artifacts are missing so `cargo
/// bench` stays green on a fresh checkout.
pub fn load_engine(args: &Args) -> Option<Engine> {
    let dir = artifacts_dir(args);
    let model = args.get_str("model", "cfg-s");
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        println!("SKIP: no artifacts at '{dir}' — run `make artifacts` first");
        return None;
    }
    match Engine::load(&dir, &model) {
        Ok(e) => Some(e),
        Err(e) => {
            println!("SKIP: engine load failed: {e}");
            None
        }
    }
}

/// Standard sample count: `--samples` flag with a bench-appropriate default
/// (kept modest — the testbed is a single CPU core).
pub fn n_samples(args: &Args, default: usize) -> usize {
    args.get("samples", default).unwrap_or(default)
}
