//! Serving throughput across worker counts — the first point on the
//! serving perf trajectory (`BENCH_serve.json`).
//!
//! Drives the **full TCP stack** (scheduler → N workers → wire protocol)
//! on the deterministic `StubEngine` with an artificial per-session decode
//! cost (`--delay-us`, emulating an engine whose per-token work is
//! serialized on its own accelerator), and measures end-to-end tokens/s,
//! client-side TTFT p50/p99 and per-worker utilization at each worker
//! count in `--workers-list` (default 1,2,4).
//!
//! Because the decode cost is per *session-step on one engine*, a single
//! worker serializes every active session's work while N workers overlap N
//! engines — the measured scaling is the architectural win of sharding,
//! not host-CPU parallelism, so it reproduces on small CI machines.
//!
//! ```sh
//! cargo bench --bench serve_throughput                       # full run
//! cargo bench --bench serve_throughput -- --smoke --workers-list 1,2
//! ```
//!
//! Outputs: `bench_out/serve_throughput.{md,json}` (table) and
//! `BENCH_serve.json` at the repo root (machine-readable trajectory
//! point, including the workers-N vs workers-1 speedup).

use mikv::bench::{Cell, Table};
use mikv::coordinator::{CoordinatorConfig, QosConfig};
use mikv::model::StubEngine;
use mikv::server::loadgen::{
    run_load, with_stub_stack_qos, LoadConfig, LoadReport, Scenario,
};
use mikv::util::cli::Args;
use mikv::util::json::{Json, JsonObj};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let default_workers: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };
    let workers_list: Vec<usize> = args.get_list("workers-list", default_workers)?;
    anyhow::ensure!(!workers_list.is_empty(), "--workers-list is empty");
    let iters = args.get_nonzero("iters", if smoke { 1 } else { 3 })?;
    let delay = Duration::from_micros(args.get("delay-us", if smoke { 200u64 } else { 500 })?);
    // --promotion: run the conversations with the lo→hi promotion pass on,
    // so the wire `promotions`/`thrash_suppressed` counters (and their
    // serving-throughput cost) land in BENCH_serve.json.
    let promotion = args.flag("promotion");
    // --scenario: arrival-process shape (steady | bursty | heavy-tail |
    // flash-crowd | chatty); --qos boots the stack with the QoS admission
    // layer (per-connection fair queuing + shedding), so fairness and shed
    // counters become meaningful rows.
    let scenario_name = args.get_str("scenario", "steady");
    let scenario = Scenario::parse(&scenario_name)
        .ok_or_else(|| anyhow::anyhow!("unknown --scenario '{scenario_name}'"))?;
    let qos = args.flag("qos").then(QosConfig::default);
    // Shed-aware backoff: on a QoS stack, rejections carry retry_after_ms
    // hints, so the generator re-submits shed turns (up to --retries per
    // turn) instead of failing them — the retries/retry_success rows
    // record how much load the hints recovered. Default 2 with --qos,
    // 0 (historical fail-fast) without.
    let mut load = LoadConfig {
        conns: args.get_nonzero("conns", if smoke { 4 } else { 12 })?,
        turns: args.get_nonzero("turns", if smoke { 2 } else { 3 })?,
        max_new: args.get_nonzero("max-new", if smoke { 8 } else { 24 })?,
        prompt_len: args.get_nonzero("prompt-len", 6)?,
        seed: args.get("seed", 0x5EEDu64)?,
        scenario,
        max_retries: args.get("retries", if qos.is_some() { 2usize } else { 0 })?,
        ..LoadConfig::default()
    };
    if promotion {
        load.spec = load.spec.promoted();
    }

    let mut table = Table::new(
        "serve_throughput",
        "End-to-end serving throughput on StubEngine (full TCP stack)",
        &[
            "workers", "tok/s", "tokens", "wall_ms", "ttft_p50_ms", "ttft_p99_ms",
            "lat_p50_ms", "lat_p99_ms", "p99_spread", "shed", "util",
        ],
    );
    table.note(format!(
        "conns={} turns={} max_new={} delay_us={} iters={} seed={:#x} scenario={} qos={} \
         (best of iters)",
        load.conns,
        load.turns,
        load.max_new,
        delay.as_micros(),
        iters,
        load.seed,
        load.scenario.as_str(),
        qos.is_some(),
    ));

    let mut results: Vec<(usize, LoadReport)> = Vec::new();
    for &workers in &workers_list {
        let mut best: Option<LoadReport> = None;
        for _ in 0..iters {
            let report = run_one(workers, &load, delay, qos.clone())?;
            let better = best
                .as_ref()
                .map(|b| report.tokens_per_sec > b.tokens_per_sec)
                .unwrap_or(true);
            if better {
                best = Some(report);
            }
        }
        let report = best.expect("iters >= 1");
        let util = report
            .per_worker
            .iter()
            .map(|w| format!("{}:{:.0}%", w.worker, w.share * 100.0))
            .collect::<Vec<_>>()
            .join(" ");
        table.row(vec![
            workers.into(),
            Cell::F(report.tokens_per_sec, 0),
            report.tokens.into(),
            Cell::F(report.wall.as_secs_f64() * 1e3, 1),
            Cell::F(report.ttft_p50.as_secs_f64() * 1e3, 2),
            Cell::F(report.ttft_p99.as_secs_f64() * 1e3, 2),
            Cell::F(report.latency_p50.as_secs_f64() * 1e3, 2),
            Cell::F(report.latency_p99.as_secs_f64() * 1e3, 2),
            Cell::F(report.conn_p99_spread, 2),
            ((report.shed_batch + report.shed_interactive + report.rate_limited) as usize)
                .into(),
            util.into(),
        ]);
        results.push((workers, report));
    }
    table.emit()?;

    // Machine-readable trajectory point at the repo root.
    let base = results
        .iter()
        .find(|(w, _)| *w == 1)
        .map(|(_, r)| r.tokens_per_sec);
    let peak = results
        .iter()
        .max_by_key(|(w, _)| *w)
        .map(|(w, r)| (*w, r.tokens_per_sec));
    let mut o = JsonObj::new();
    o.set("bench", "serve_throughput");
    o.set("engine", "stub");
    o.set("decode_delay_us", delay.as_micros() as i64);
    o.set("conns", load.conns);
    o.set("turns", load.turns);
    o.set("max_new", load.max_new);
    o.set("seed", load.seed as i64);
    o.set("smoke", smoke);
    o.set("promotion", promotion);
    o.set("scenario", load.scenario.as_str());
    o.set("qos", qos.is_some());
    let rows: Vec<Json> = results
        .iter()
        .map(|(workers, r)| {
            let mut ro = JsonObj::new();
            ro.set("workers", *workers);
            ro.set("tokens", r.tokens);
            ro.set("tokens_per_sec", r.tokens_per_sec);
            ro.set("wall_ms", r.wall.as_secs_f64() * 1e3);
            ro.set("ttft_p50_ms", r.ttft_p50.as_secs_f64() * 1e3);
            ro.set("ttft_p99_ms", r.ttft_p99.as_secs_f64() * 1e3);
            ro.set("latency_p50_ms", r.latency_p50.as_secs_f64() * 1e3);
            ro.set("latency_p99_ms", r.latency_p99.as_secs_f64() * 1e3);
            // Fairness & shedding rows: ok/error turn split, per-conn p99
            // spread, rejection percentiles and the QoS shed counters
            // (all zero/1.0 on a QoS-less steady run).
            ro.set("turns_ok", r.turns_ok);
            ro.set("turns_err", r.turns_err);
            ro.set("conn_p99_spread", r.conn_p99_spread);
            ro.set(
                "rejected_latency_p50_ms",
                r.rejected_latency_p50.as_secs_f64() * 1e3,
            );
            ro.set(
                "rejected_latency_p99_ms",
                r.rejected_latency_p99.as_secs_f64() * 1e3,
            );
            ro.set("rejects_with_hint", r.rejects_with_hint);
            // Shed-aware backoff: re-submissions the retry_after_ms hints
            // drove and how many shed turns they recovered.
            ro.set("retries", r.retries);
            ro.set("retry_success", r.retry_success);
            ro.set("shed_batch", r.shed_batch as i64);
            ro.set("shed_interactive", r.shed_interactive as i64);
            ro.set("rate_limited", r.rate_limited as i64);
            // Fault-domain counters (all 0 on a healthy, fault-free run).
            ro.set("worker_restarts", r.worker_restarts as i64);
            ro.set("sessions_lost", r.sessions_lost as i64);
            ro.set("events_dropped", r.events_dropped as i64);
            // Server-side decode-assembly cost (µs percentiles from the
            // trailing stats op; 0 when the engine doesn't measure it).
            ro.set("assembly_us_p50", r.assembly_us_p50);
            ro.set("assembly_us_p99", r.assembly_us_p99);
            // Tier-lifecycle counters this run caused (0 without
            // --promotion).
            ro.set("promotions", r.promotions as i64);
            ro.set("thrash_suppressed", r.thrash_suppressed as i64);
            ro.set(
                "per_worker_utilization",
                Json::Arr(r.per_worker.iter().map(|w| Json::Num(w.share)).collect()),
            );
            Json::Obj(ro)
        })
        .collect();
    o.set("results", Json::Arr(rows));
    if let (Some(base), Some((peak_w, peak_tps))) = (base, peak) {
        let speedup = peak_tps / base.max(1e-9);
        o.set("speedup_peak_workers_vs_1", speedup);
        println!(
            "speedup: {peak_w} workers vs 1 worker = {speedup:.2}x \
             ({peak_tps:.0} vs {base:.0} tok/s)"
        );
        if peak_w >= 2 && speedup < 2.0 && !smoke {
            eprintln!("WARN: expected >= 2x scaling at {peak_w} workers, got {speedup:.2}x");
        }
    }
    std::fs::write("BENCH_serve.json", Json::Obj(o).to_string_pretty())?;
    println!("wrote BENCH_serve.json");
    Ok(())
}

/// Boot a sharded stub runtime, run the load workload against it over real
/// sockets, and tear it down.
fn run_one(
    workers: usize,
    load: &LoadConfig,
    delay: Duration,
    qos: Option<QosConfig>,
) -> anyhow::Result<LoadReport> {
    let mut base = StubEngine::new(StubEngine::test_dims(256));
    base.decode_delay = delay;
    let load = load.clone();
    let qos_on = qos.is_some();
    let report = with_stub_stack_qos(
        workers,
        CoordinatorConfig::default(),
        qos,
        base,
        move |addr| run_load(&addr, &load),
    )??;
    // A QoS stack is *allowed* to shed under pressure (the rejections are
    // part of what the bench measures); a stock FCFS run must stay clean.
    anyhow::ensure!(
        qos_on || report.turns_err == 0,
        "{} of {} turns failed",
        report.turns_err,
        report.turns_ok + report.turns_err
    );
    Ok(report)
}
