//! Paper Table 4: AlpacaEval win rate of MiKV vs the full cache.
//!
//! GPT-4 judging is unavailable offline; we report the deterministic
//! analogue (see `mikv::eval::agreement`): token agreement between
//! compressed-cache and full-cache greedy generations on mixed chat-like
//! prompts, mapped to a proxy win rate where 50% ⇔ indistinguishable.

mod common;

use mikv::bench::{Cell, Table};
use mikv::eval::agreement::AgreementStats;
use mikv::eval::{EvalTask, Harness};
use mikv::model::CacheMode;
use mikv::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(engine) = common::load_engine(&args) else { return };
    let n = common::n_samples(&args, 20);
    let dims = engine.dims().clone();
    let harness = Harness::new(&engine);

    // chat-like prompts: retrieval with filler, generating several tokens
    let task = EvalTask::LineRet {
        n_lines: 14,
        filler: 2,
    };
    let samples = harness.samples(&task, n);
    let prompts: Vec<Vec<i64>> = samples.iter().map(|s| s.prompt.clone()).collect();
    let prefills = engine.prefill_raw(&prompts).unwrap();

    let gen_len = args.get("gen", 8usize).unwrap();
    let mut long_samples = samples.clone();
    for s in &mut long_samples {
        s.answer = vec![0; gen_len]; // only the length matters here
    }

    let (reference, _) = harness
        .generate_mode(&long_samples, &prefills, &CacheMode::Full)
        .unwrap();

    let specs = [
        ("100%", "full"),
        ("50%", "mikv:0.5:int4"),
        ("25%", "mikv:0.25:int2"),
        ("20%", "mikv:0.2:int2"),
    ];
    let paper = [50.0, 50.9, 51.1, 48.6];

    let mut t = Table::new(
        "table4",
        "Win rate of MiKV over the full cache — paper Table 4 (agreement proxy)",
        &["Cache size", "Proxy win rate", "Token agreement", "Identical gens", "Paper win rate"],
    );
    for ((label, mode_s), p) in specs.iter().zip(&paper) {
        let mode = CacheMode::parse(mode_s, &dims).unwrap();
        let (gens, cache_pct) = harness
            .generate_mode(&long_samples, &prefills, &mode)
            .unwrap();
        let mut stats = AgreementStats::default();
        for (g, r) in gens.iter().zip(&reference) {
            stats.add(g, r);
        }
        t.row(vec![
            Cell::Str(format!("{label} ({cache_pct:.0}% measured)")),
            Cell::Pct(stats.proxy_win_rate(), 1),
            Cell::Pct(100.0 * stats.mean_agreement(), 1),
            Cell::Pct(100.0 * stats.identical_rate(), 0),
            Cell::Pct(*p, 1),
        ]);
    }
    t.note(format!("n={n} prompts × {gen_len} greedy tokens; 50% ⇔ parity with the full cache."));
    t.note("Shape to reproduce: win rate stays ≈50% down to 25% cache, dipping slightly at 20% (paper: 48.6%).");
    t.emit().unwrap();
}
