//! Paper Table 6 (Appendix C): simulated per-channel key quantization.
//!
//! Exactly the paper's hypothetical scheme: quantize the prefix's key
//! tensor per *channel* (group 64 along the sequence) as-is, keep the
//! H2O-top-20% tokens in FP16, values per-token; no reordering/buffering.
//! Decode then runs against the resulting (dequantized) cache via the
//! full-cache graph — precision effects are entirely in the cached values,
//! as in the paper's simulation.

mod common;

use mikv::bench::{Cell, Table};
use mikv::eval::{EvalTask, Harness};
use mikv::kvcache::accounting;
use mikv::model::{CacheMode, Engine, PrefillOutput};
use mikv::quant::perchannel::{per_channel_overhead_bits, quantize_dequantize_per_channel};
use mikv::quant::{dequantize, quantize, Precision, QuantParams};
use mikv::util::cli::Args;

/// Apply the Table-6 simulation to one prefill output in place.
fn simulate(
    engine: &Engine,
    pf: &mut PrefillOutput,
    prec: Precision,
    hi_ratio: f64,
    per_channel: bool,
    group_seq: usize,
) {
    let dims = engine.dims();
    let planes = dims.planes();
    let d = dims.d_head;
    let t = pf.seq_len;
    let keep = ((t as f64) * hi_ratio).ceil() as usize;

    for p in 0..planes {
        // H2O top-`keep` slots by prefill attention mass
        let acc = &pf.attn_acc[p * t..(p + 1) * t];
        let mut idx: Vec<usize> = (0..t).collect();
        idx.sort_by(|&a, &b| acc[b].partial_cmp(&acc[a]).unwrap());
        let hi: std::collections::HashSet<usize> = idx[..keep].iter().copied().collect();

        let kblock = &mut pf.k[p * t * d..(p + 1) * t * d];
        let orig = kblock.to_vec();
        if per_channel {
            let qdq = quantize_dequantize_per_channel(&orig, t, d, prec, group_seq);
            kblock.copy_from_slice(&qdq);
        } else {
            // per-token baseline for the same comparison
            let prm = QuantParams::new(prec, d / 2);
            for s in 0..t {
                let q = quantize(&orig[s * d..(s + 1) * d], prm);
                kblock[s * d..(s + 1) * d].copy_from_slice(&dequantize(&q));
            }
        }
        // restore the FP16 importance tokens
        for &s in &hi {
            kblock[s * d..(s + 1) * d].copy_from_slice(&orig[s * d..(s + 1) * d]);
        }
        // values: per-token quantization on lo slots (both variants)
        let vblock = &mut pf.v[p * t * d..(p + 1) * t * d];
        let prm = QuantParams::new(prec, d / 2);
        for s in 0..t {
            if !hi.contains(&s) {
                let q = quantize(&vblock[s * d..(s + 1) * d], prm);
                vblock[s * d..(s + 1) * d].copy_from_slice(&dequantize(&q));
            }
        }
    }
}

fn main() {
    let args = Args::from_env();
    let Some(engine) = common::load_engine(&args) else { return };
    let n = common::n_samples(&args, 30);
    let dims = engine.dims().clone();
    let harness = Harness::new(&engine);
    let task = EvalTask::LineRet { n_lines: 20, filler: 0 };
    let samples = harness.samples(&task, n);
    let prompts: Vec<Vec<i64>> = samples.iter().map(|s| s.prompt.clone()).collect();
    let base_prefills = engine.prefill_raw(&prompts).unwrap();

    // balancer per-token variant comes from the real MiKV path
    let bal_modes = [
        ("INT3", "mikv:0.2:int3"),
        ("INT2", "mikv:0.2:int2"),
    ];

    let mut t = Table::new(
        "table6",
        "Per-channel key quantization (simulated, ratio 20%) — paper Table 6",
        &["Retained prec.", "Outlier handling", "Cache size", "Acc."],
    );
    let paper = [
        ("INT3", "none (per-token)", 36.0, 100.0),
        ("INT3", "channel balancer", 38.0, 99.8),
        ("INT3", "per-channel", 38.0, 99.4),
        ("INT2", "none (per-token)", 32.0, 64.0),
        ("INT2", "channel balancer", 33.0, 92.6),
        ("INT2", "per-channel", 33.0, 99.2),
    ];
    let mut rows: Vec<(String, String, f64, f64)> = Vec::new();

    for (label, prec) in [("INT3", Precision::Int3), ("INT2", Precision::Int2)] {
        // (a) per-token, no balancer — simulated on the full-cache graph for
        // apples-to-apples with (c)
        for (handling, per_channel) in [("none (per-token)", false), ("per-channel", true)] {
            let mut pfs: Vec<PrefillOutput> = base_prefills
                .iter()
                .map(|p| PrefillOutput {
                    seq_len: p.seq_len,
                    k: p.k.clone(),
                    v: p.v.clone(),
                    attn_acc: p.attn_acc.clone(),
                    qmax: p.qmax.clone(),
                    kmax: p.kmax.clone(),
                    last_logits: p.last_logits.clone(),
                })
                .collect();
            for pf in &mut pfs {
                simulate(&engine, pf, prec, 0.2, per_channel, 64);
            }
            let (gens, _) = harness
                .generate_mode(&samples, &pfs, &CacheMode::Full)
                .unwrap();
            let acc = gens
                .iter()
                .zip(&samples)
                .filter(|(g, s)| g[..] == s.answer[..])
                .count() as f64
                / n as f64;
            // analytic cache %: 20% fp16 + 80% quantized w/ metadata
            let mean_t = pfs.iter().map(|p| p.seq_len).sum::<usize>() / pfs.len();
            let overhead = if per_channel {
                per_channel_overhead_bits(mean_t, 64)
            } else {
                // per-token groups d/2: 2 groups × 2 × 16 bits / d elems
                (2.0 * 2.0 * 16.0) / dims.d_head as f64
            };
            let lo_bits = prec.bits() as f64 + overhead;
            let pct = 100.0 * (0.2 + 0.8 * (lo_bits / 16.0));
            rows.push((label.to_string(), handling.to_string(), pct, 100.0 * acc));
        }
        // (b) channel balancer via the real mixed-precision path
        let mode_s = bal_modes.iter().find(|(l, _)| *l == label).unwrap().1;
        let mode = CacheMode::parse(mode_s, &dims).unwrap();
        let o = &harness
            .run(&task, &[(mode_s.to_string(), mode)], n)
            .unwrap()[0];
        rows.insert(
            rows.len() - 1,
            (label.to_string(), "channel balancer".to_string(), o.cache_pct, 100.0 * o.accuracy),
        );
    }

    for ((prec, handling, pct, acc), (_, _, p_pct, p_acc)) in rows.iter().zip(&paper) {
        t.row(vec![
            prec.clone().into(),
            handling.clone().into(),
            Cell::Str(format!("{pct:.0}% (paper {p_pct:.0}%)")),
            Cell::Str(format!("{acc:.1}% (paper {p_acc}%)")),
        ]);
    }
    t.note(format!("n={n} samples; per-channel simulated exactly as App. C (group 64 along sequence, keys only, no reordering)."));
    t.note("Shape to reproduce: per-channel isolates outliers and matches/beats the balancer at INT2; both far above plain per-token.");
    t.emit().unwrap();
}
