//! Fragility grid bench: race every importance policy × every retention
//! arm on the failure modes mean-agreement hides (`BENCH_fragility.json`).
//!
//! Runs [`mikv::eval::fragility`]'s scenario grid — needle retrieval at
//! pinned depths, keyed recall over many facts, and multi-turn drift
//! through the real park/append session lifecycle — for every importance
//! policy (`h2o`, `local`, `random`, `lagkv`) under three retention arms:
//!
//! * `evict` — hi-only eviction (the baselines the paper argues against),
//! * `mikv`  — mixed-precision retention (demoted tokens kept in the lo
//!   tier),
//! * `merge` — WeightedKV-style fold into a retained neighbor.
//!
//! Scores are reported per depth bucket with the worst bucket alongside
//! the mean, because the paper's headline contrast lives in the tail:
//! eviction looks fine on average while silently destroying the oldest
//! context. Two gates enforce that contrast in-bench:
//!
//! 1. aggregated over every needle cell, `mikv` ≥ `evict` on **every**
//!    populated depth bucket, and
//! 2. `mikv` strictly beats `evict` on the deepest bucket (depth 0% =
//!    oldest context — the positions eviction reclaims first).
//!
//! The grid is deterministic for a given seed at any `--workers` count
//! (regression-locked in `eval::fragility` tests), so
//! `BENCH_fragility.json` diffs are meaningful.
//!
//! ```sh
//! cargo bench --bench fragility_grid              # full grid
//! cargo bench --bench fragility_grid -- --smoke   # CI grid
//! cargo bench --bench fragility_grid -- --workers 4 --seed 7
//! ```
//!
//! Outputs: `bench_out/fragility_grid.{md,json}` and
//! `BENCH_fragility.json` at the repo root (schema in EXPERIMENTS.md
//! §Fragility).

use mikv::bench::{Cell, Table};
use mikv::eval::fragility::{aggregate_buckets, run_grid_workers, GridSpec};
use mikv::eval::harness::DEPTH_BUCKETS;
use mikv::util::cli::Args;
use mikv::util::json::{Json, JsonObj};

fn bucket_arr(v: &[f64; DEPTH_BUCKETS]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let seed: u64 = args.get("seed", 0xF2A6_11D0u64)?;
    let workers = args.get_nonzero("workers", 2)?;
    let spec = if smoke {
        GridSpec::smoke(seed)
    } else {
        GridSpec::full_grid(seed)
    };

    println!(
        "fragility grid: {} tasks × {} policies × {} arms, {} samples/cell, {} workers{}",
        spec.tasks.len(),
        spec.policies.len(),
        spec.arms.len(),
        spec.samples,
        workers,
        if smoke { " (smoke)" } else { "" }
    );
    let results = run_grid_workers(&spec, workers)?;

    let mut table = Table::new(
        "fragility_grid",
        "Fragility grid: probe accuracy per task × policy × retention arm",
        &[
            "Task", "Policy", "Arm", "Probes", "Mean", "Worst bucket", "p10", "Cache %", "Merges",
        ],
    );
    for r in &results {
        table.row(vec![
            r.task.clone().into(),
            r.policy.clone().into(),
            r.arm.into(),
            r.n_probes.into(),
            Cell::F(r.mean, 3),
            Cell::F(r.worst_bucket, 3),
            Cell::F(r.p10, 3),
            Cell::Pct(r.cache_pct, 1),
            Cell::Int(r.merges as i64),
        ]);
    }

    // The headline contrast, aggregated over every needle cell (all
    // policies): per-depth-bucket accuracy of each arm.
    let (evict_b, evict_n) = aggregate_buckets(&results, "needle", "evict");
    let (mikv_b, mikv_n) = aggregate_buckets(&results, "needle", "mikv");
    let (merge_b, _) = aggregate_buckets(&results, "needle", "merge");
    anyhow::ensure!(
        evict_n[0] > 0 && mikv_n[0] > 0,
        "deepest needle bucket must be populated (grid must pin a depth-0 needle)"
    );
    for b in 0..DEPTH_BUCKETS {
        if evict_n[b] > 0 && mikv_n[b] > 0 {
            anyhow::ensure!(
                mikv_b[b] + 1e-9 >= evict_b[b],
                "mixed precision must not lose to eviction on any needle bucket: \
                 bucket {b} mikv {:.3} < evict {:.3}",
                mikv_b[b],
                evict_b[b]
            );
        }
    }
    anyhow::ensure!(
        mikv_b[0] > evict_b[0] + 0.05,
        "the paper's recovery claim: mixed precision must strictly beat eviction \
         on the deepest needle bucket: mikv {:.3} vs evict {:.3}",
        mikv_b[0],
        evict_b[0]
    );
    let total_merges: u64 = results
        .iter()
        .filter(|r| r.arm == "merge")
        .map(|r| r.merges)
        .sum();
    anyhow::ensure!(total_merges > 0, "merge arm never folded a token");

    table.note(format!(
        "needle buckets (deepest→newest): evict {evict_b:.3?} vs mikv {mikv_b:.3?} vs merge \
         {merge_b:.3?}; depth 0% = oldest context; gates: mikv ≥ evict everywhere, strictly \
         better at bucket 0"
    ));
    table.emit()?;

    let mut o = JsonObj::new();
    o.set("bench", "fragility_grid");
    o.set("pending", false);
    o.set("smoke", smoke);
    o.set("seed", seed as i64);
    o.set("workers", workers);
    o.set("samples_per_cell", spec.samples);
    o.set("max_seq", spec.max_seq);
    o.set("ratio", spec.ratio);
    o.set("recent_window", spec.recent_window);
    o.set(
        "policies",
        Json::Arr(spec.policies.iter().map(|p| Json::Str(p.clone())).collect()),
    );
    o.set(
        "arms",
        Json::Arr(
            spec.arms
                .iter()
                .map(|a| Json::Str(a.name().to_string()))
                .collect(),
        ),
    );
    let mut nb = JsonObj::new();
    nb.set("evict", bucket_arr(&evict_b));
    nb.set("mikv", bucket_arr(&mikv_b));
    nb.set("merge", bucket_arr(&merge_b));
    nb.set(
        "probes",
        Json::Arr(mikv_n.iter().map(|&n| Json::Int(n as i64)).collect()),
    );
    o.set("needle_buckets", Json::Obj(nb));
    let cells: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut c = JsonObj::new();
            c.set("task", r.task.clone());
            c.set("family", r.family);
            match r.depth_pct {
                Some(d) => c.set("depth_pct", d as i64),
                None => c.set("depth_pct", Json::Null),
            };
            c.set("policy", r.policy.clone());
            c.set("arm", r.arm);
            c.set("n_probes", r.n_probes);
            c.set("mean", r.mean);
            c.set("worst_bucket", r.worst_bucket);
            c.set("p10", r.p10);
            c.set("bucket_scores", bucket_arr(&r.bucket_scores));
            c.set(
                "bucket_probes",
                Json::Arr(r.bucket_counts.iter().map(|&n| Json::Int(n as i64)).collect()),
            );
            c.set("cache_size_pct", r.cache_pct);
            c.set("merges", r.merges as i64);
            Json::Obj(c)
        })
        .collect();
    o.set("cells", Json::Arr(cells));
    std::fs::write("BENCH_fragility.json", Json::Obj(o).to_string_pretty())?;
    println!("wrote BENCH_fragility.json");
    Ok(())
}
