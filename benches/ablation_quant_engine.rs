//! Ablation: host-native quantization vs the offloaded Pallas `quant_block`
//! graph for bulk prefill-ingestion quantization.
//!
//! DESIGN.md calls this choice out: the cache manager quantizes demoted
//! tokens host-side (SIMD-friendly scalar code); the alternative ships the
//! whole block to the accelerator through the L1 Pallas quant kernel. On a
//! CPU-PJRT testbed the host path wins (no serialization overhead); on a
//! real accelerator the HLO path amortizes. The bench quantifies the
//! crossover inputs-per-call.

mod common;

use mikv::bench::{fmt_duration, Bencher, Cell, Table};
use mikv::quant::{quantize, Precision, QuantParams};
use mikv::runtime::{Manifest, Runtime};
use mikv::util::cli::Args;
use mikv::util::rng::Pcg32;

fn main() {
    let args = Args::from_env();
    let dir = common::artifacts_dir(&args);
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        println!("SKIP: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let model = args.get_str("model", "cfg-s");
    let entry = manifest.model(&model).unwrap().clone();
    let rt = Runtime::new().unwrap();
    let dims = entry.dims.clone();
    let (rows, d, group) = (dims.max_seq, dims.d_head, dims.quant_group);

    let mut t = Table::new(
        "ablation_quant_engine",
        "Bulk quantization: host-native vs HLO (Pallas quant_block) — DESIGN.md ablation",
        &["Bits", "Engine", "p50 / block", "Melem/s"],
    );
    let mut rng = Pcg32::new(5);
    let x: Vec<f32> = (0..rows * d).map(|_| rng.gen_normal() * 2.0).collect();
    let n_elem = (rows * d) as f64;

    for (&bits, file) in &entry.quant_graphs {
        let prec = match bits {
            2 => Precision::Int2,
            3 => Precision::Int3,
            4 => Precision::Int4,
            8 => Precision::Int8,
            _ => continue,
        };
        // host-native
        let prm = QuantParams::new(prec, group);
        let stats = Bencher::new(format!("native{bits}")).iters(20).run(|| {
            for r in 0..rows {
                std::hint::black_box(quantize(&x[r * d..(r + 1) * d], prm));
            }
        });
        t.row(vec![
            Cell::Int(bits as i64),
            "host-native".into(),
            fmt_duration(stats.p50).into(),
            Cell::F(stats.per_second(n_elem) / 1e6, 1),
        ]);

        // HLO path
        let g = mikv::runtime::GraphEntry {
            file: file.clone(),
            batch: 1,
            inputs: vec![mikv::runtime::TensorSpec {
                name: "x".into(),
                dtype: mikv::runtime::artifacts::Dtype::F32,
                shape: vec![rows, d],
            }],
            outputs: vec!["codes".into(), "scales".into(), "zeros".into()],
        };
        let exe = rt.load_executable(&manifest.path(file), g).unwrap();
        let stats = Bencher::new(format!("hlo{bits}")).iters(20).run(|| {
            let buf = rt.upload_f32(&x, &[rows, d]).unwrap();
            std::hint::black_box(exe.execute(&[&buf]).unwrap());
        });
        t.row(vec![
            Cell::Int(bits as i64),
            "hlo (pallas)".into(),
            fmt_duration(stats.p50).into(),
            Cell::F(stats.per_second(n_elem) / 1e6, 1),
        ]);
    }
    t.note(format!("block = [{rows}, {d}] f32, group {group}; HLO path includes host→device upload + tuple readback."));
    t.emit().unwrap();
}
