//! Paper Table 1: line-retrieval accuracy when the "evicted" KVs are
//! retained in low precision, across importance ratios {50, 25, 20}% and
//! retained precisions {INT4, INT3, INT2, evicted}.
//!
//! The paper's headline observation: retention at INT4/INT3 restores
//! near-full accuracy where eviction collapses; INT2 degrades without the
//! outlier balancer (Table 2 adds it — here we match Table 1's plain
//! per-token quantizer, i.e. `nobal`).

mod common;

use mikv::bench::{Cell, Table};
use mikv::eval::{EvalTask, Harness};
use mikv::model::CacheMode;
use mikv::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let Some(engine) = common::load_engine(&args) else { return };
    let n = common::n_samples(&args, 30);
    let harness = Harness::new(&engine);
    let task = EvalTask::LineRet {
        n_lines: args.get("lines", 20).unwrap(),
        filler: 0,
    };

    let dims = engine.dims().clone();
    let mut modes: Vec<(String, CacheMode)> = vec![(
        "full".into(),
        CacheMode::parse("full", &dims).unwrap(),
    )];
    for ratio in ["0.5", "0.25", "0.2"] {
        for prec in ["int4", "int3", "int2"] {
            // Table 1 uses the plain quantizer (outlier awareness is §3.2)
            let s = format!("mikv:{ratio}:{prec}:nobal");
            modes.push((s.clone(), CacheMode::parse(&s, &dims).unwrap()));
        }
        let s = format!("h2o:{ratio}");
        modes.push((s.clone(), CacheMode::parse(&s, &dims).unwrap()));
    }

    let outcomes = harness.run(&task, &modes, n).unwrap();

    let mut t = Table::new(
        "table1",
        "Line retrieval accuracy: retained low-precision vs evicted — paper Table 1",
        &["Importance ratio", "Retained prec.", "Cache size", "Acc.", "Fidelity vs full"],
    );
    let paper: &[(&str, &str, f64, f64)] = &[
        // (ratio, prec, paper cache %, paper acc %)
        ("50%", "INT4", 63.0, 100.0),
        ("50%", "INT3", 59.0, 99.8),
        ("50%", "INT2", 56.0, 84.6),
        ("50%", "evicted", 50.0, 43.2),
        ("25%", "INT4", 45.0, 100.0),
        ("25%", "INT3", 40.0, 99.8),
        ("25%", "INT2", 35.0, 68.0),
        ("25%", "evicted", 25.0, 10.6),
        ("20%", "INT4", 41.0, 100.0),
        ("20%", "INT3", 36.0, 100.0),
        ("20%", "INT2", 32.0, 64.0),
        ("20%", "evicted", 20.0, 4.0),
    ];
    // ours, aligned with the mode list (skipping the leading full row)
    let full = &outcomes[0];
    println!(
        "(reference) full cache: acc {:.1}% at 100% cache\n",
        100.0 * full.accuracy
    );
    for (o, (ratio, prec, paper_cache, paper_acc)) in outcomes[1..].iter().zip(paper) {
        t.row(vec![
            (*ratio).into(),
            (*prec).into(),
            Cell::Str(format!(
                "{:.0}% (paper {paper_cache:.0}%)",
                o.cache_pct
            )),
            Cell::Str(format!(
                "{:.1}% (paper {paper_acc}%)",
                100.0 * o.accuracy
            )),
            Cell::Pct(100.0 * o.fidelity, 1),
        ]);
    }
    t.note(format!(
        "n={n} samples, model cfg-s ({}M params, trained from scratch); full-cache reference acc {:.1}%.",
        engine.dims().params as f64 / 1e6,
        100.0 * full.accuracy
    ));
    t.note("Fidelity = token agreement with the full-cache generation (model-quality-independent compression signal).");
    t.note("Shape to reproduce: retained INT4/INT3 ≈ full-cache accuracy; eviction collapses as the ratio shrinks; INT2 sits between (Table 2 rescues it with the balancer).");
    t.emit().unwrap();
}
