//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps `xla_extension`'s PJRT C API. This build environment
//! ships no XLA runtime, so the stub mirrors the API surface that
//! `mikv::runtime::client` consumes and fails cleanly at the single
//! entry point ([`PjRtClient::cpu`]). Everything downstream of a client is
//! therefore unreachable; the methods exist only so the callers type-check,
//! and every artifact-dependent path (engine load, integration tests,
//! benches) skips with a readable error instead of failing to link.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `Display + Debug` usage.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "PJRT runtime unavailable: this build uses the offline `xla` stub \
             (no XLA shared library in the image)"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Host element types PJRT buffers can be built from.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// A device-resident buffer (never constructible through the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// A host literal (never constructible through the stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable (never constructible through the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// The PJRT client. [`PjRtClient::cpu`] is the only way in, and it fails
/// with a readable message in the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline"));
    }

    #[test]
    fn hlo_load_fails_cleanly() {
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
    }
}
