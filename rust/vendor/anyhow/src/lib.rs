//! Minimal in-tree replacement for the `anyhow` crate.
//!
//! The offline build image carries no registry crates, so this vendored
//! stand-in provides the exact API surface the workspace uses: [`Error`],
//! [`Result`], [`Error::msg`], the [`anyhow!`], [`bail!`] and [`ensure!`]
//! macros, and `?`-conversion from any `std::error::Error`. Like the real
//! crate, `Error` deliberately does **not** implement `std::error::Error`
//! (that is what makes the blanket `From` impl coherent).

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error: a rendered message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `Result<T, anyhow::Error>` with an overridable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap a concrete error, keeping it as the source.
    pub fn new<E>(error: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// The wrapped source error, if any.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.source();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cause {
            write!(f, "\n    {e}")?;
            cause = e.source();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn message_and_display() {
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
    }

    #[test]
    fn question_mark_from_std_error() {
        fn inner() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        let err = inner().unwrap_err();
        assert!(err.source().is_some());
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(fails(false).unwrap(), 7);
        assert!(fails(true).unwrap_err().to_string().contains("true"));
        fn b() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(b().unwrap_err().to_string(), "nope 1");
    }
}
