//! # MiKV — Mixed-precision KV cache compression
//!
//! Reproduction of *"No Token Left Behind: Reliable KV Cache Compression via
//! Importance-Aware Mixed Precision Quantization"* (Yang, Kim, et al., 2024).
//!
//! MiKV replaces KV-cache **eviction** with **mixed-precision retention**:
//! the KV pairs an importance policy would evict are kept in low-bit
//! (INT2/3/4) per-token asymmetric quantization with a dynamic query/key
//! outlier channel balancer, while the important ("heavy hitter") KV pairs
//! stay in high precision. The result is an eviction-shaped memory budget
//! without the context damage eviction causes. Tier membership is
//! bidirectional on request: the opt-in *promotion on re-access* pass
//! re-quantizes lo-tier tokens whose importance emerges late back into the
//! hi tier (see [`kvcache`]).
//!
//! `ARCHITECTURE.md` at the repo root is the top-down tour of the serving
//! system (request lifecycle, tier state machine, delta assembly, metrics
//! pipeline); `EXPERIMENTS.md` documents each experiment's methodology.
//!
//! ## Crate layout (layer 3 of the three-layer stack)
//!
//! * [`util`] — substrates: JSON codec, deterministic RNG, mini property-test
//!   harness, CLI parsing, logging, deterministic fault injection (the
//!   offline image has no serde / clap / proptest, so these are built
//!   in-tree).
//! * [`tensor`] — minimal row-major host tensor used across the crate.
//! * [`quant`] — per-token asymmetric quantization (paper eq. 1), INT2/3/4/8
//!   bit-packing, and the dynamic outlier channel balancer (paper eq. 2–4).
//! * [`kvcache`] — the mixed-precision cache manager: high-precision
//!   importance tier + low-precision retained tier, logical memory
//!   accounting (the paper's "cache size %" axis).
//! * [`policies`] — importance policies: H2O accumulated attention, local
//!   (recency) window, post-hoc oracle, random.
//! * [`runtime`] — PJRT wrapper over the `xla` crate: loads the HLO-text
//!   artifacts AOT-lowered by `python/compile/aot.py` and executes them.
//! * [`model`] — engine orchestrating prefill/decode graphs against the
//!   cache manager; greedy sampler; model/precision configuration.
//! * [`coordinator`] — serving layer: request router, continuous batcher,
//!   session manager, latency/throughput stats.
//! * [`server`] — threaded TCP JSON-lines server + client.
//! * [`eval`] — synthetic benchmark suites: line retrieval, proxy tasks for
//!   MMLU/GSM8k/HumanEval, generation-agreement (AlpacaEval proxy).
//! * [`memory`] — analytic KV footprint calculator (paper Table 5).
//! * [`bench`] — timing harness used by the `benches/` binaries.

pub mod bench;
pub mod coordinator;
pub mod eval;
pub mod kvcache;
pub mod memory;
pub mod model;
pub mod policies;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
