//! Serving metrics aggregation.

use super::request::RequestMetrics;
use crate::kvcache::PoolStats;
use std::time::{Duration, Instant};

/// Point-in-time serving counters answered to the wire `stats` op:
/// scheduler occupancy, session-registry footprint, throughput, and the
/// shared [`crate::kvcache::BufferPool`]'s counters.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    /// Sessions currently decoding.
    pub active: usize,
    /// Requests queued for admission.
    pub waiting: usize,
    /// Sessions parked in the registry awaiting `append`.
    pub parked_sessions: usize,
    /// Host bytes the parked sessions pin.
    pub parked_bytes: usize,
    /// Turns completed since the coordinator started.
    pub completed: usize,
    /// Tokens generated since the coordinator started.
    pub generated_tokens: usize,
    /// Generated tokens per wall-clock second.
    pub throughput_tps: f64,
    /// Mean host cache bytes per completed turn.
    pub mean_host_bytes: f64,
    /// Largest host cache footprint any completed turn reached.
    pub peak_host_bytes: usize,
    /// Shared buffer-pool counters.
    pub pool: PoolStats,
}

/// Aggregates per-request metrics into the numbers the serving benches
/// report: TTFT / latency percentiles and token throughput.
#[derive(Debug)]
pub struct MetricsCollector {
    started: Instant,
    ttfts: Vec<Duration>,
    latencies: Vec<Duration>,
    prompt_tokens: usize,
    generated_tokens: usize,
    host_bytes: Vec<usize>,
}

impl Default for MetricsCollector {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            ttfts: Vec::new(),
            latencies: Vec::new(),
            prompt_tokens: 0,
            generated_tokens: 0,
            host_bytes: Vec::new(),
        }
    }

    pub fn record(&mut self, m: &RequestMetrics) {
        self.ttfts.push(m.ttft);
        self.latencies.push(m.latency);
        self.prompt_tokens += m.prompt_tokens;
        self.generated_tokens += m.generated_tokens;
        self.host_bytes.push(m.host_bytes);
    }

    pub fn n_requests(&self) -> usize {
        self.latencies.len()
    }

    fn pct(sorted: &[Duration], p: f64) -> Duration {
        if sorted.is_empty() {
            return Duration::ZERO;
        }
        sorted[((sorted.len() as f64 - 1.0) * p).round() as usize]
    }

    /// (p50, p99) of time-to-first-token.
    pub fn ttft(&self) -> (Duration, Duration) {
        let mut v = self.ttfts.clone();
        v.sort_unstable();
        (Self::pct(&v, 0.5), Self::pct(&v, 0.99))
    }

    /// (p50, p99) of end-to-end latency.
    pub fn latency(&self) -> (Duration, Duration) {
        let mut v = self.latencies.clone();
        v.sort_unstable();
        (Self::pct(&v, 0.5), Self::pct(&v, 0.99))
    }

    /// Generated tokens per wall-clock second since collector creation.
    pub fn throughput(&self) -> f64 {
        self.generated_tokens as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn generated_tokens(&self) -> usize {
        self.generated_tokens
    }

    /// Mean host cache bytes per completed session — the number the pooled,
    /// length-aware cache layout is supposed to keep proportional to
    /// occupancy rather than `max_seq`.
    pub fn mean_host_bytes(&self) -> f64 {
        if self.host_bytes.is_empty() {
            return 0.0;
        }
        self.host_bytes.iter().sum::<usize>() as f64 / self.host_bytes.len() as f64
    }

    /// Largest host cache footprint any completed session reached.
    pub fn peak_host_bytes(&self) -> usize {
        self.host_bytes.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(ttft_ms: u64, lat_ms: u64) -> RequestMetrics {
        RequestMetrics {
            ttft: Duration::from_millis(ttft_ms),
            latency: Duration::from_millis(lat_ms),
            prompt_tokens: 10,
            generated_tokens: 5,
            cache_pct: 50.0,
            host_bytes: 1 << 20,
            hi_slots: 4,
            lo_slots: 12,
        }
    }

    #[test]
    fn aggregates_percentiles() {
        let mut c = MetricsCollector::new();
        for i in 1..=100 {
            c.record(&metrics(i, i * 2));
        }
        assert_eq!(c.n_requests(), 100);
        // index = round((n-1)·p): p50 of 1..=100 → index 50 → value 51
        let (p50, p99) = c.ttft();
        assert_eq!(p50, Duration::from_millis(51));
        assert_eq!(p99, Duration::from_millis(99));
        let (l50, l99) = c.latency();
        assert_eq!(l50, Duration::from_millis(102));
        assert_eq!(l99, Duration::from_millis(198));
        assert_eq!(c.generated_tokens(), 500);
    }

    #[test]
    fn empty_collector_is_safe() {
        let c = MetricsCollector::new();
        assert_eq!(c.ttft().0, Duration::ZERO);
        assert_eq!(c.n_requests(), 0);
        assert_eq!(c.mean_host_bytes(), 0.0);
        assert_eq!(c.peak_host_bytes(), 0);
    }

    #[test]
    fn host_bytes_mean_and_peak() {
        let mut c = MetricsCollector::new();
        let mut m = metrics(1, 2);
        m.host_bytes = 100;
        c.record(&m);
        m.host_bytes = 300;
        c.record(&m);
        assert_eq!(c.mean_host_bytes(), 200.0);
        assert_eq!(c.peak_host_bytes(), 300);
    }
}
