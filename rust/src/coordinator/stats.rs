//! Serving metrics aggregation.

use super::request::RequestMetrics;
use crate::kvcache::PoolStats;
use std::time::{Duration, Instant};

/// One worker's share of a [`StatsSnapshot`]. In the sharded runtime every
/// engine worker answers the `stats` op with its own counters and the
/// scheduler merges them; the per-worker rows ride along so occupancy and
/// throughput skew across the shards stays observable on the wire.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerStats {
    /// Worker index (0-based).
    pub worker: usize,
    /// Sessions this worker is currently decoding.
    pub active: usize,
    /// Requests queued on this worker.
    pub waiting: usize,
    /// Sessions parked in this worker's registry.
    pub parked_sessions: usize,
    /// Sessions spilled to this worker's cold tier (on-disk snapshots
    /// awaiting `append`); 0 when the cold tier is not configured.
    pub parked_cold_sessions: usize,
    /// Bytes this worker's cold-tier snapshots occupy on disk.
    pub cold_bytes: u64,
    /// Turns this worker completed.
    pub completed: usize,
    /// Tokens this worker generated.
    pub generated_tokens: usize,
    /// This worker's generated tokens per wall-clock second.
    pub throughput_tps: f64,
    /// p50 of per-decode-step host input-assembly time (µs), over the
    /// collector's retained window. 0 when the engine doesn't measure it.
    pub assembly_us_p50: f64,
    /// p99 of per-decode-step host input-assembly time (µs).
    pub assembly_us_p99: f64,
    /// Assembly samples observed (may exceed the retained window).
    pub assembly_samples: u64,
    /// p50 of cold→hot session restore time (µs) over the retained
    /// window. 0 until a spilled session is appended to.
    pub restore_us_p50: f64,
    /// p99 of cold→hot session restore time (µs).
    pub restore_us_p99: f64,
    /// Cold-tier restores performed (lifetime; may exceed the window).
    pub restore_samples: u64,
    /// lo→hi promotions across this worker's completed turns.
    pub promotions: u64,
    /// Hysteresis-suppressed promotions across completed turns.
    pub thrash_suppressed: u64,
    /// Submits the admission scheduler has dispatched to this worker that
    /// have not yet reached their terminal event. Workers cannot see this
    /// window themselves (an op may still be sitting in their channel), so
    /// the scheduler injects it when folding the broadcast answers; it is
    /// always 0 in a snapshot taken from a bare single-worker
    /// `Coordinator::run` deployment.
    pub admitted_in_flight: usize,
}

/// Point-in-time serving counters answered to the wire `stats` op:
/// scheduler occupancy, session-registry footprint, throughput, the shared
/// [`crate::kvcache::BufferPool`]'s counters, and the per-worker breakdown
/// (one row per engine worker in the sharded runtime).
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    /// Sessions currently decoding (summed over workers).
    pub active: usize,
    /// Requests queued for admission (summed over workers).
    pub waiting: usize,
    /// Sessions parked in the registries awaiting `append`.
    pub parked_sessions: usize,
    /// Host bytes the parked sessions pin.
    pub parked_bytes: usize,
    /// Sessions spilled to the cold tier (on-disk snapshots awaiting
    /// `append`, summed over workers); 0 without a configured cold tier.
    pub parked_cold_sessions: usize,
    /// Bytes the cold-tier snapshots occupy on disk (summed over workers).
    pub cold_bytes: u64,
    /// Spilled sessions evicted from the cold tier by its byte bound —
    /// each one is a permanently lost session context.
    pub cold_evictions: u64,
    /// Turns completed since the runtime started.
    pub completed: usize,
    /// Tokens generated since the runtime started.
    pub generated_tokens: usize,
    /// Generated tokens per wall-clock second.
    pub throughput_tps: f64,
    /// Mean host cache bytes per completed turn.
    pub mean_host_bytes: f64,
    /// Largest host cache footprint any completed turn reached.
    pub peak_host_bytes: usize,
    /// p50 of per-decode-step host input-assembly time (µs). In a merged
    /// snapshot this is the mean of the worker p50s weighted by each
    /// worker's retained sample window (an approximation — exact
    /// per-worker values ride in `workers`).
    pub assembly_us_p50: f64,
    /// p99 of per-decode-step host input-assembly time (µs); merged the
    /// same way.
    pub assembly_us_p99: f64,
    /// Decode-step assembly samples observed.
    pub assembly_samples: u64,
    /// p50 of cold→hot session restore time (µs); merged with the same
    /// window weighting as the assembly percentiles.
    pub restore_us_p50: f64,
    /// p99 of cold→hot session restore time (µs).
    pub restore_us_p99: f64,
    /// Cold-tier session restores performed.
    pub restore_samples: u64,
    /// lo→hi promotions across completed turns (summed over workers; the
    /// tier lifecycle's demote-inverse — 0 unless sessions opt into
    /// `compression.promotion`).
    pub promotions: u64,
    /// Hysteresis-suppressed promotions across completed turns.
    pub thrash_suppressed: u64,
    /// Buffer-pool counters (summed over the per-worker pools).
    pub pool: PoolStats,
    /// Submits dispatched by the admission scheduler that have not yet
    /// reached their terminal event (summed over workers; injected by the
    /// scheduler at fold time — see [`WorkerStats::admitted_in_flight`]).
    pub admitted_in_flight: usize,
    /// Turns waiting in the scheduler's QoS (DRR) queues at snapshot time;
    /// 0 without a QoS config.
    pub qos_queued: usize,
    /// Batch-lane turns rejected by QoS shedding (lifetime count).
    pub shed_batch: u64,
    /// Interactive-lane turns rejected by QoS shedding (lifetime count).
    pub shed_interactive: u64,
    /// Turns rejected by the per-tenant rate limiter (lifetime count).
    pub rate_limited: u64,
    /// Worker panics survived by the supervisor (each is one
    /// `catch_unwind` + engine rebuild + cold-tier recovery cycle;
    /// injected by the scheduler at fold time).
    pub worker_restarts: u64,
    /// Cold-tier snapshots adopted by respawned workers — sessions that
    /// survived their owner's crash and stayed appendable.
    pub sessions_recovered: u64,
    /// Hot-parked sessions unwound with panicking workers (their KV state
    /// is gone; injected by the scheduler at fold time).
    pub sessions_lost: u64,
    /// Non-terminal `token` events dropped by slow-client backpressure
    /// (terminal `done`/`error` events are never dropped; injected by the
    /// TCP server at encode time).
    pub events_dropped: u64,
    /// Per-worker breakdown, ordered by worker index.
    pub workers: Vec<WorkerStats>,
}

impl StatsSnapshot {
    /// Merge per-worker snapshots into the aggregate the wire reports:
    /// additive counters are summed, `mean_host_bytes` is weighted by each
    /// worker's completed turns, peaks are maxed, and the `workers` rows
    /// are concatenated in worker order.
    pub fn merged(parts: Vec<StatsSnapshot>) -> StatsSnapshot {
        let mut out = StatsSnapshot::default();
        let mut weighted_bytes = 0.0f64;
        let mut weighted_a50 = 0.0f64;
        let mut weighted_a99 = 0.0f64;
        let mut assembly_windows = 0.0f64;
        let mut weighted_r50 = 0.0f64;
        let mut weighted_r99 = 0.0f64;
        let mut restore_windows = 0.0f64;
        for part in parts {
            out.active += part.active;
            out.waiting += part.waiting;
            out.parked_sessions += part.parked_sessions;
            out.parked_bytes += part.parked_bytes;
            out.parked_cold_sessions += part.parked_cold_sessions;
            out.cold_bytes += part.cold_bytes;
            out.cold_evictions += part.cold_evictions;
            out.completed += part.completed;
            out.generated_tokens += part.generated_tokens;
            out.throughput_tps += part.throughput_tps;
            weighted_bytes += part.mean_host_bytes * part.completed as f64;
            out.peak_host_bytes = out.peak_host_bytes.max(part.peak_host_bytes);
            // Weight by the retained window, not lifetime samples: every
            // worker's percentiles cover at most ASSEMBLY_WINDOW recent
            // steps, so a long-lived worker must not drown a fresh one.
            let window = part.assembly_samples.min(ASSEMBLY_WINDOW as u64) as f64;
            weighted_a50 += part.assembly_us_p50 * window;
            weighted_a99 += part.assembly_us_p99 * window;
            assembly_windows += window;
            out.assembly_samples += part.assembly_samples;
            let rwindow = part.restore_samples.min(RESTORE_WINDOW as u64) as f64;
            weighted_r50 += part.restore_us_p50 * rwindow;
            weighted_r99 += part.restore_us_p99 * rwindow;
            restore_windows += rwindow;
            out.restore_samples += part.restore_samples;
            out.promotions += part.promotions;
            out.thrash_suppressed += part.thrash_suppressed;
            out.admitted_in_flight += part.admitted_in_flight;
            out.qos_queued += part.qos_queued;
            out.shed_batch += part.shed_batch;
            out.shed_interactive += part.shed_interactive;
            out.rate_limited += part.rate_limited;
            out.worker_restarts += part.worker_restarts;
            out.sessions_recovered += part.sessions_recovered;
            out.sessions_lost += part.sessions_lost;
            out.events_dropped += part.events_dropped;
            out.pool.free_blocks += part.pool.free_blocks;
            out.pool.free_bytes += part.pool.free_bytes;
            out.pool.outstanding_blocks += part.pool.outstanding_blocks;
            out.pool.outstanding_bytes += part.pool.outstanding_bytes;
            out.pool.hits += part.pool.hits;
            out.pool.misses += part.pool.misses;
            out.workers.extend(part.workers);
        }
        if out.completed > 0 {
            out.mean_host_bytes = weighted_bytes / out.completed as f64;
        }
        if assembly_windows > 0.0 {
            out.assembly_us_p50 = weighted_a50 / assembly_windows;
            out.assembly_us_p99 = weighted_a99 / assembly_windows;
        }
        if restore_windows > 0.0 {
            out.restore_us_p50 = weighted_r50 / restore_windows;
            out.restore_us_p99 = weighted_r99 / restore_windows;
        }
        out.workers.sort_by_key(|w| w.worker);
        out
    }
}

/// Samples of per-decode-step assembly time retained for the percentile
/// window (a ring: serving runs are long and steps are frequent, so the
/// collector keeps a sliding window instead of growing without bound).
const ASSEMBLY_WINDOW: usize = 4096;

/// Samples of cold→hot session restore time retained for the percentile
/// window. Restores are orders of magnitude rarer than decode steps, so a
/// smaller ring suffices.
const RESTORE_WINDOW: usize = 1024;

/// Aggregates per-request metrics into the numbers the serving benches
/// report: TTFT / latency percentiles and token throughput.
#[derive(Debug)]
pub struct MetricsCollector {
    started: Instant,
    ttfts: Vec<Duration>,
    latencies: Vec<Duration>,
    prompt_tokens: usize,
    generated_tokens: usize,
    host_bytes: Vec<usize>,
    /// Ring of the last [`ASSEMBLY_WINDOW`] per-step assembly times.
    assembly: Vec<Duration>,
    assembly_pos: usize,
    assembly_total: u64,
    /// Ring of the last [`RESTORE_WINDOW`] cold→hot restore times.
    restore: Vec<Duration>,
    restore_pos: usize,
    restore_total: u64,
    promotions: u64,
    thrash_suppressed: u64,
}

impl Default for MetricsCollector {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            ttfts: Vec::new(),
            latencies: Vec::new(),
            prompt_tokens: 0,
            generated_tokens: 0,
            host_bytes: Vec::new(),
            assembly: Vec::new(),
            assembly_pos: 0,
            assembly_total: 0,
            restore: Vec::new(),
            restore_pos: 0,
            restore_total: 0,
            promotions: 0,
            thrash_suppressed: 0,
        }
    }

    /// Record one decode step's host input-assembly time (ring-buffered to
    /// the last `ASSEMBLY_WINDOW` samples).
    pub fn record_assembly(&mut self, d: Duration) {
        self.assembly_total += 1;
        if self.assembly.len() < ASSEMBLY_WINDOW {
            self.assembly.push(d);
        } else {
            // The modulo above keeps `assembly_pos < ASSEMBLY_WINDOW`,
            // and this branch only runs once the ring is full.
            if let Some(slot) = self.assembly.get_mut(self.assembly_pos) {
                *slot = d;
            }
            self.assembly_pos = (self.assembly_pos + 1) % ASSEMBLY_WINDOW;
        }
    }

    /// (p50, p99) of per-step assembly time in µs over the retained
    /// window; (0, 0) when nothing was recorded.
    pub fn assembly_us(&self) -> (f64, f64) {
        if self.assembly.is_empty() {
            return (0.0, 0.0);
        }
        let mut v = self.assembly.clone();
        v.sort_unstable();
        (
            crate::bench::percentile(&v, 0.5).as_secs_f64() * 1e6,
            crate::bench::percentile(&v, 0.99).as_secs_f64() * 1e6,
        )
    }

    /// Total assembly samples observed (may exceed the retained window).
    pub fn assembly_samples(&self) -> u64 {
        self.assembly_total
    }

    /// Record one cold→hot session restore's wall time (ring-buffered to
    /// the last `RESTORE_WINDOW` samples).
    pub fn record_restore(&mut self, d: Duration) {
        self.restore_total += 1;
        if self.restore.len() < RESTORE_WINDOW {
            self.restore.push(d);
        } else {
            if let Some(slot) = self.restore.get_mut(self.restore_pos) {
                *slot = d;
            }
            self.restore_pos = (self.restore_pos + 1) % RESTORE_WINDOW;
        }
    }

    /// (p50, p99) of cold→hot restore time in µs over the retained
    /// window; (0, 0) when no session was ever restored.
    pub fn restore_us(&self) -> (f64, f64) {
        if self.restore.is_empty() {
            return (0.0, 0.0);
        }
        let mut v = self.restore.clone();
        v.sort_unstable();
        (
            crate::bench::percentile(&v, 0.5).as_secs_f64() * 1e6,
            crate::bench::percentile(&v, 0.99).as_secs_f64() * 1e6,
        )
    }

    /// Total cold-tier restores observed (may exceed the retained window).
    pub fn restore_samples(&self) -> u64 {
        self.restore_total
    }

    pub fn record(&mut self, m: &RequestMetrics) {
        self.ttfts.push(m.ttft);
        self.latencies.push(m.latency);
        self.prompt_tokens += m.prompt_tokens;
        self.generated_tokens += m.generated_tokens;
        self.host_bytes.push(m.host_bytes);
        self.promotions += m.promotions;
        self.thrash_suppressed += m.thrash_suppressed;
    }

    /// lo→hi promotions summed over completed turns.
    pub fn promotions(&self) -> u64 {
        self.promotions
    }

    /// Hysteresis-suppressed promotions summed over completed turns.
    pub fn thrash_suppressed(&self) -> u64 {
        self.thrash_suppressed
    }

    pub fn n_requests(&self) -> usize {
        self.latencies.len()
    }

    /// (p50, p99) of time-to-first-token (linear-interpolated percentiles,
    /// shared with the bench harness via [`crate::bench::percentile`]).
    pub fn ttft(&self) -> (Duration, Duration) {
        let mut v = self.ttfts.clone();
        v.sort_unstable();
        (
            crate::bench::percentile(&v, 0.5),
            crate::bench::percentile(&v, 0.99),
        )
    }

    /// (p50, p99) of end-to-end latency.
    pub fn latency(&self) -> (Duration, Duration) {
        let mut v = self.latencies.clone();
        v.sort_unstable();
        (
            crate::bench::percentile(&v, 0.5),
            crate::bench::percentile(&v, 0.99),
        )
    }

    /// Generated tokens per wall-clock second since collector creation.
    pub fn throughput(&self) -> f64 {
        self.generated_tokens as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn generated_tokens(&self) -> usize {
        self.generated_tokens
    }

    /// Mean host cache bytes per completed session — the number the pooled,
    /// length-aware cache layout is supposed to keep proportional to
    /// occupancy rather than `max_seq`.
    pub fn mean_host_bytes(&self) -> f64 {
        if self.host_bytes.is_empty() {
            return 0.0;
        }
        self.host_bytes.iter().sum::<usize>() as f64 / self.host_bytes.len() as f64
    }

    /// Largest host cache footprint any completed session reached.
    pub fn peak_host_bytes(&self) -> usize {
        self.host_bytes.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(ttft_ms: u64, lat_ms: u64) -> RequestMetrics {
        RequestMetrics {
            ttft: Duration::from_millis(ttft_ms),
            latency: Duration::from_millis(lat_ms),
            prompt_tokens: 10,
            generated_tokens: 5,
            cache_pct: 50.0,
            host_bytes: 1 << 20,
            hi_slots: 4,
            lo_slots: 12,
            promotions: 3,
            thrash_suppressed: 1,
        }
    }

    #[test]
    fn aggregates_percentiles() {
        let mut c = MetricsCollector::new();
        for i in 1..=100 {
            c.record(&metrics(i, i * 2));
        }
        assert_eq!(c.n_requests(), 100);
        // linear interpolation: p50 of 1..=100 ms sits at idx 49.5 →
        // midpoint of 50 ms and 51 ms; p99 at idx 98.01 → 99.01 ms.
        let (p50, p99) = c.ttft();
        assert!((p50.as_secs_f64() - 0.0505).abs() < 1e-9, "{p50:?}");
        assert!((p99.as_secs_f64() - 0.09901).abs() < 1e-9, "{p99:?}");
        let (l50, l99) = c.latency();
        assert!((l50.as_secs_f64() - 0.101).abs() < 1e-9, "{l50:?}");
        assert!((l99.as_secs_f64() - 0.19802).abs() < 1e-9, "{l99:?}");
        assert_eq!(c.generated_tokens(), 500);
        // per-turn promotion deltas accumulate into worker totals
        assert_eq!(c.promotions(), 300);
        assert_eq!(c.thrash_suppressed(), 100);
    }

    #[test]
    fn empty_collector_is_safe() {
        let c = MetricsCollector::new();
        assert_eq!(c.ttft().0, Duration::ZERO);
        assert_eq!(c.n_requests(), 0);
        assert_eq!(c.mean_host_bytes(), 0.0);
        assert_eq!(c.peak_host_bytes(), 0);
    }

    #[test]
    fn host_bytes_mean_and_peak() {
        let mut c = MetricsCollector::new();
        let mut m = metrics(1, 2);
        m.host_bytes = 100;
        c.record(&m);
        m.host_bytes = 300;
        c.record(&m);
        assert_eq!(c.mean_host_bytes(), 200.0);
        assert_eq!(c.peak_host_bytes(), 300);
    }

    #[test]
    fn snapshot_merge_sums_and_weights() {
        let w = |worker: usize, completed: usize| WorkerStats {
            worker,
            completed,
            generated_tokens: completed * 3,
            throughput_tps: 10.0,
            ..WorkerStats::default()
        };
        let a = StatsSnapshot {
            active: 2,
            waiting: 1,
            parked_sessions: 1,
            parked_bytes: 100,
            completed: 4,
            generated_tokens: 12,
            throughput_tps: 10.0,
            mean_host_bytes: 1000.0,
            peak_host_bytes: 5000,
            promotions: 7,
            thrash_suppressed: 2,
            workers: vec![w(1, 4)],
            ..StatsSnapshot::default()
        };
        let b = StatsSnapshot {
            active: 1,
            waiting: 0,
            parked_sessions: 2,
            parked_bytes: 50,
            completed: 12,
            generated_tokens: 36,
            throughput_tps: 30.0,
            mean_host_bytes: 2000.0,
            peak_host_bytes: 3000,
            promotions: 3,
            thrash_suppressed: 1,
            workers: vec![w(0, 12)],
            ..StatsSnapshot::default()
        };
        let m = StatsSnapshot::merged(vec![a, b]);
        assert_eq!(m.active, 3);
        assert_eq!(m.waiting, 1);
        assert_eq!(m.parked_sessions, 3);
        assert_eq!(m.parked_bytes, 150);
        assert_eq!(m.completed, 16);
        assert_eq!(m.generated_tokens, 48);
        assert!((m.throughput_tps - 40.0).abs() < 1e-9);
        // weighted: (1000·4 + 2000·12) / 16 = 1750
        assert!((m.mean_host_bytes - 1750.0).abs() < 1e-9);
        assert_eq!(m.peak_host_bytes, 5000);
        assert_eq!(m.promotions, 10);
        assert_eq!(m.thrash_suppressed, 3);
        // workers sorted by index after the merge
        assert_eq!(m.workers.len(), 2);
        assert_eq!(m.workers[0].worker, 0);
        assert_eq!(m.workers[1].worker, 1);
    }

    #[test]
    fn assembly_ring_percentiles_and_window() {
        let mut c = MetricsCollector::new();
        assert_eq!(c.assembly_us(), (0.0, 0.0));
        for i in 1..=100u64 {
            c.record_assembly(Duration::from_micros(i));
        }
        let (p50, p99) = c.assembly_us();
        assert!((p50 - 50.5).abs() < 1e-6, "{p50}");
        assert!((p99 - 99.01).abs() < 1e-6, "{p99}");
        assert_eq!(c.assembly_samples(), 100);

        // the ring caps retained samples but keeps counting
        for i in 0..(super::ASSEMBLY_WINDOW as u64 + 50) {
            c.record_assembly(Duration::from_micros(7 + (i % 3)));
        }
        assert_eq!(c.assembly.len(), super::ASSEMBLY_WINDOW);
        assert_eq!(
            c.assembly_samples(),
            100 + super::ASSEMBLY_WINDOW as u64 + 50
        );
        let (p50, _) = c.assembly_us();
        assert!((7.0..=9.0).contains(&p50), "window dominated by recents: {p50}");
    }

    #[test]
    fn merge_weights_assembly_percentiles_by_samples() {
        let a = StatsSnapshot {
            assembly_us_p50: 10.0,
            assembly_us_p99: 20.0,
            assembly_samples: 30,
            ..StatsSnapshot::default()
        };
        let b = StatsSnapshot {
            assembly_us_p50: 40.0,
            assembly_us_p99: 80.0,
            assembly_samples: 10,
            ..StatsSnapshot::default()
        };
        let m = StatsSnapshot::merged(vec![a, b]);
        assert_eq!(m.assembly_samples, 40);
        // (10·30 + 40·10)/40 = 17.5 ; (20·30 + 80·10)/40 = 35
        assert!((m.assembly_us_p50 - 17.5).abs() < 1e-9);
        assert!((m.assembly_us_p99 - 35.0).abs() < 1e-9);
        // a worker with no samples contributes nothing
        let none = StatsSnapshot::default();
        let m2 = StatsSnapshot::merged(vec![none]);
        assert_eq!(m2.assembly_us_p50, 0.0);

        // lifetime samples are capped at the retained window: a long-lived
        // worker (1M steps) and a fresh one both retain ASSEMBLY_WINDOW
        // samples, so they weigh equally.
        let old = StatsSnapshot {
            assembly_us_p50: 10.0,
            assembly_samples: 1_000_000,
            ..StatsSnapshot::default()
        };
        let fresh = StatsSnapshot {
            assembly_us_p50: 30.0,
            assembly_samples: super::ASSEMBLY_WINDOW as u64,
            ..StatsSnapshot::default()
        };
        let m3 = StatsSnapshot::merged(vec![old, fresh]);
        assert!((m3.assembly_us_p50 - 20.0).abs() < 1e-9, "{}", m3.assembly_us_p50);
        assert_eq!(m3.assembly_samples, 1_000_000 + super::ASSEMBLY_WINDOW as u64);
    }

    #[test]
    fn merge_sums_admission_side_gauges() {
        let a = StatsSnapshot {
            admitted_in_flight: 2,
            qos_queued: 3,
            shed_batch: 5,
            shed_interactive: 1,
            rate_limited: 4,
            ..StatsSnapshot::default()
        };
        let b = StatsSnapshot {
            admitted_in_flight: 1,
            qos_queued: 0,
            shed_batch: 2,
            shed_interactive: 0,
            rate_limited: 0,
            ..StatsSnapshot::default()
        };
        let m = StatsSnapshot::merged(vec![a, b]);
        assert_eq!(m.admitted_in_flight, 3);
        assert_eq!(m.qos_queued, 3);
        assert_eq!(m.shed_batch, 7);
        assert_eq!(m.shed_interactive, 1);
        assert_eq!(m.rate_limited, 4);
    }

    #[test]
    fn merge_sums_fault_domain_counters() {
        let a = StatsSnapshot {
            worker_restarts: 2,
            sessions_recovered: 3,
            sessions_lost: 1,
            events_dropped: 10,
            ..StatsSnapshot::default()
        };
        let b = StatsSnapshot {
            worker_restarts: 1,
            sessions_recovered: 0,
            sessions_lost: 4,
            events_dropped: 5,
            ..StatsSnapshot::default()
        };
        let m = StatsSnapshot::merged(vec![a, b]);
        assert_eq!(m.worker_restarts, 3);
        assert_eq!(m.sessions_recovered, 3);
        assert_eq!(m.sessions_lost, 5);
        assert_eq!(m.events_dropped, 15);
    }

    #[test]
    fn snapshot_merge_of_nothing_is_default() {
        let m = StatsSnapshot::merged(Vec::new());
        assert_eq!(m.completed, 0);
        assert_eq!(m.mean_host_bytes, 0.0);
        assert!(m.workers.is_empty());
    }

    #[test]
    fn restore_ring_percentiles_and_window() {
        let mut c = MetricsCollector::new();
        assert_eq!(c.restore_us(), (0.0, 0.0));
        for i in 1..=100u64 {
            c.record_restore(Duration::from_micros(i));
        }
        let (p50, p99) = c.restore_us();
        assert!((p50 - 50.5).abs() < 1e-6, "{p50}");
        assert!((p99 - 99.01).abs() < 1e-6, "{p99}");
        assert_eq!(c.restore_samples(), 100);

        // the ring caps retained samples but keeps counting
        for i in 0..(super::RESTORE_WINDOW as u64 + 25) {
            c.record_restore(Duration::from_micros(3 + (i % 2)));
        }
        assert_eq!(c.restore.len(), super::RESTORE_WINDOW);
        assert_eq!(c.restore_samples(), 100 + super::RESTORE_WINDOW as u64 + 25);
        let (p50, _) = c.restore_us();
        assert!((3.0..=5.0).contains(&p50), "window dominated by recents: {p50}");
    }

    #[test]
    fn merge_sums_cold_counters_and_weights_restore_percentiles() {
        let a = StatsSnapshot {
            parked_cold_sessions: 2,
            cold_bytes: 1000,
            cold_evictions: 1,
            restore_us_p50: 10.0,
            restore_us_p99: 20.0,
            restore_samples: 30,
            ..StatsSnapshot::default()
        };
        let b = StatsSnapshot {
            parked_cold_sessions: 1,
            cold_bytes: 500,
            cold_evictions: 0,
            restore_us_p50: 40.0,
            restore_us_p99: 80.0,
            restore_samples: 10,
            ..StatsSnapshot::default()
        };
        let m = StatsSnapshot::merged(vec![a, b]);
        assert_eq!(m.parked_cold_sessions, 3);
        assert_eq!(m.cold_bytes, 1500);
        assert_eq!(m.cold_evictions, 1);
        assert_eq!(m.restore_samples, 40);
        // (10·30 + 40·10)/40 = 17.5 ; (20·30 + 80·10)/40 = 35
        assert!((m.restore_us_p50 - 17.5).abs() < 1e-9);
        assert!((m.restore_us_p99 - 35.0).abs() < 1e-9);
        // a worker that never restored contributes no weight
        let m2 = StatsSnapshot::merged(vec![StatsSnapshot::default()]);
        assert_eq!(m2.restore_us_p50, 0.0);
    }
}
