//! Request/response types crossing the coordinator boundary.

use crate::model::CacheMode;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Reply channel for one request.
pub type Reply = mpsc::Sender<Response>;

/// A generation request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i64>,
    /// Maximum new tokens to generate (including the prefill's first token).
    pub max_new: usize,
    /// Stop early when this token is produced.
    pub stop: Option<i64>,
    pub mode: CacheMode,
    pub submitted_at: Instant,
    pub reply: Reply,
}

/// Per-request latency/throughput metrics.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    /// Time to first token (prefill completion).
    pub ttft: Duration,
    /// Total request latency.
    pub latency: Duration,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// Logical cache size at completion (% of full FP16).
    pub cache_pct: f64,
    /// Host bytes the session's cache pinned at completion (pooled shadow
    /// blocks + tier storage) — the bytes-per-session serving metric.
    pub host_bytes: usize,
}

/// A completed generation.
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i64>,
    pub metrics: RequestMetrics,
    pub error: Option<String>,
}

impl Response {
    pub fn error(id: u64, msg: impl Into<String>) -> Response {
        Response {
            id,
            tokens: Vec::new(),
            metrics: RequestMetrics {
                ttft: Duration::ZERO,
                latency: Duration::ZERO,
                prompt_tokens: 0,
                generated_tokens: 0,
                cache_pct: 0.0,
                host_bytes: 0,
            },
            error: Some(msg.into()),
        }
    }
}
