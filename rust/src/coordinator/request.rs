//! Request/response/event types crossing the coordinator boundary.
//!
//! The serving surface is **op-shaped and streaming**: the front-end hands
//! the coordinator [`Op`]s (submit / cancel / stats) and the coordinator
//! pushes [`ServeEvent`]s into each request's [`EventSink`] — `token`
//! events as they are sampled, then one terminal `done` (or `error`)
//! event. Compression is requested as a plain-data [`CompressionSpec`]
//! parsed by the wire layer (`server::proto`) and resolved to a
//! [`CacheMode`] only at coordinator admission, so parsing stays decoupled
//! from policy.

use super::stats::StatsSnapshot;
use crate::kvcache::TierConfig;
use crate::model::CacheMode;
use crate::quant::Precision;
use crate::runtime::ModelDims;
use std::fmt;
use std::sync::mpsc;
use std::time::{Duration, Instant};

// ----------------------------------------------------------------------
// Structured wire errors
// ----------------------------------------------------------------------

/// Machine-readable error codes carried on the wire
/// (`{"event":"error","code":...}`). Every coordinator rejection and
/// retirement failure maps onto exactly one of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed or semantically invalid request (bad JSON, non-integer
    /// prompt tokens, unknown mode/policy/precision, bad ratio/group...).
    BadRequest,
    /// The waiting queue is at `max_waiting`; retry later.
    Overloaded,
    /// `append` named a session that is not parked (never kept, expired,
    /// or evicted by the retention bound).
    SessionNotFound,
    /// `append` named a session whose previous turn is still in flight;
    /// retry after its `done` event.
    SessionBusy,
    /// The session's cache cannot hold the appended prompt plus at least
    /// one new token.
    CacheFull,
    /// Engine-side failure (prefill/decode error).
    Internal,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::SessionNotFound => "session_not_found",
            ErrorCode::SessionBusy => "session_busy",
            ErrorCode::CacheFull => "cache_full",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorCode> {
        Some(match s {
            "bad_request" => ErrorCode::BadRequest,
            "overloaded" => ErrorCode::Overloaded,
            "session_not_found" => ErrorCode::SessionNotFound,
            "session_busy" => ErrorCode::SessionBusy,
            "cache_full" => ErrorCode::CacheFull,
            "internal" => ErrorCode::Internal,
            _ => return None,
        })
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A structured error delivered over the wire: a stable code plus a
/// human-readable message, and (for `overloaded` rejections from the QoS
/// admission layer) an optional client backoff hint.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    pub code: ErrorCode,
    pub message: String,
    /// Backoff hint in milliseconds: "retrying sooner than this is almost
    /// certainly wasted". Set only by QoS shedding / rate limiting; absent
    /// (`None`) on every other error, which keeps the legacy error shape
    /// byte-identical.
    pub retry_after_ms: Option<u64>,
}

impl WireError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> WireError {
        WireError {
            code,
            message: message.into(),
            retry_after_ms: None,
        }
    }

    pub fn bad_request(message: impl Into<String>) -> WireError {
        Self::new(ErrorCode::BadRequest, message)
    }

    pub fn internal(message: impl Into<String>) -> WireError {
        Self::new(ErrorCode::Internal, message)
    }

    /// Attach a retry hint (QoS shed / rate-limit rejections).
    pub fn with_retry_after(mut self, ms: u64) -> WireError {
        self.retry_after_ms = Some(ms);
        self
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

// ----------------------------------------------------------------------
// CompressionSpec
// ----------------------------------------------------------------------

/// Plain-data description of the cache compression a request asks for.
///
/// This is what the wire layer parses; it knows nothing about model
/// dimensions or cache internals. [`CompressionSpec::resolve`] validates
/// it against a model's [`ModelDims`] and produces the [`CacheMode`] the
/// session is built with — at coordinator admission, not at parse time.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressionSpec {
    /// `full` | `oracle` | `mikv` | `h2o` | `rtn`.
    pub mode: String,
    /// Importance ratio (mikv/h2o); fraction of context kept hi.
    pub ratio: Option<f64>,
    /// Lo-tier precision name (mikv), or the uniform precision (rtn).
    pub lo: Option<String>,
    /// Channels per scale/zero group in the lo tier.
    pub group: Option<usize>,
    /// Importance policy name (`h2o` | `local` | `random`).
    pub policy: Option<String>,
    /// Oracle top-k (oracle mode only).
    pub k: Option<usize>,
    /// Opt-in lo→hi promotion on re-access (mikv mode only). Absent or
    /// `false` keeps the historical one-way tier lifecycle.
    pub promotion: Option<bool>,
    /// Whether a kept session may be spilled to the on-disk cold tier when
    /// it ages out of the parked registry. Absent or `true` allows the
    /// spill (when the server has a cold tier configured);
    /// `Some(false)` opts this session out — it is dropped on eviction
    /// instead, so its KV state never touches disk.
    pub spill: Option<bool>,
}

impl Default for CompressionSpec {
    fn default() -> Self {
        Self::full()
    }
}

impl CompressionSpec {
    fn base(mode: &str) -> CompressionSpec {
        CompressionSpec {
            mode: mode.to_string(),
            ratio: None,
            lo: None,
            group: None,
            policy: None,
            k: None,
            promotion: None,
            spill: None,
        }
    }

    /// Exact full-precision cache (the 100% baseline).
    pub fn full() -> CompressionSpec {
        Self::base("full")
    }

    /// Paper-default MiKV at `ratio` with the given lo-tier precision.
    pub fn mikv(ratio: f64, lo: &str) -> CompressionSpec {
        CompressionSpec {
            ratio: Some(ratio),
            lo: Some(lo.to_string()),
            ..Self::base("mikv")
        }
    }

    /// H2O eviction baseline at `ratio`.
    pub fn h2o(ratio: f64) -> CompressionSpec {
        CompressionSpec {
            ratio: Some(ratio),
            ..Self::base("h2o")
        }
    }

    /// Uniform round-to-nearest quantization at `precision`.
    pub fn rtn(precision: &str) -> CompressionSpec {
        CompressionSpec {
            lo: Some(precision.to_string()),
            ..Self::base("rtn")
        }
    }

    /// Post-softmax oracle top-k baseline.
    pub fn oracle(k: usize) -> CompressionSpec {
        CompressionSpec {
            k: Some(k),
            ..Self::base("oracle")
        }
    }

    /// Enable the opt-in lo→hi promotion pass (valid for mikv mode only;
    /// resolution rejects it elsewhere).
    pub fn promoted(mut self) -> CompressionSpec {
        self.promotion = Some(true);
        self
    }

    /// Opt a kept session out of cold-tier spilling: on eviction from the
    /// parked registry it is dropped (the pre-cold-tier behaviour) instead
    /// of snapshotted to disk. A serving-lifecycle knob, orthogonal to the
    /// cache mode — [`Self::resolve`] ignores it.
    pub fn no_spill(mut self) -> CompressionSpec {
        self.spill = Some(false);
        self
    }

    /// Validate against a model's dimensions and resolve to the
    /// [`CacheMode`] the session will be built with.
    pub fn resolve(&self, dims: &ModelDims) -> Result<CacheMode, WireError> {
        if let Some(r) = self.ratio {
            if !(0.0..=1.0).contains(&r) || !r.is_finite() {
                return Err(WireError::bad_request(format!(
                    "ratio {r} outside [0, 1]"
                )));
            }
        }
        if let Some(g) = self.group {
            if g == 0 || g > dims.d_head || dims.d_head % g != 0 {
                return Err(WireError::bad_request(format!(
                    "group {g} must divide head_dim {}",
                    dims.d_head
                )));
            }
        }
        if let Some(p) = &self.policy {
            if crate::policies::make_policy(p, 1, 1, 0).is_none() {
                return Err(WireError::bad_request(format!("unknown policy '{p}'")));
            }
        }
        if self.promotion == Some(true) && self.mode != "mikv" {
            return Err(WireError::bad_request(format!(
                "promotion requires mode 'mikv' (got '{}')",
                self.mode
            )));
        }
        let prec = |name: &str| {
            Precision::parse(name)
                .ok_or_else(|| WireError::bad_request(format!("unknown precision '{name}'")))
        };
        let mode = match self.mode.as_str() {
            "full" => CacheMode::Full,
            "oracle" => CacheMode::Oracle {
                k: self.k.unwrap_or(dims.max_seq + 1),
            },
            "mikv" => {
                let lo = prec(self.lo.as_deref().unwrap_or("int2"))?;
                if !lo.is_quantized() {
                    return Err(WireError::bad_request(
                        "mikv lo tier must be a quantized precision",
                    ));
                }
                let mut mode = CacheMode::mikv(dims, self.ratio.unwrap_or(0.2), lo);
                if let CacheMode::Mikv { cfg, policy } = &mut mode {
                    if let Some(g) = self.group {
                        cfg.lo = TierConfig::quantized(lo, g);
                    }
                    if let Some(p) = &self.policy {
                        *policy = p.clone();
                    }
                    if self.promotion == Some(true) {
                        cfg.promotion = Some(crate::kvcache::PromotionConfig::default());
                    }
                }
                mode
            }
            "h2o" => {
                let mut mode = CacheMode::h2o(dims, self.ratio.unwrap_or(0.2));
                if let CacheMode::Mikv { policy, .. } = &mut mode {
                    if let Some(p) = &self.policy {
                        *policy = p.clone();
                    }
                }
                mode
            }
            "rtn" => {
                let p = prec(self.lo.as_deref().unwrap_or("int8"))?;
                if !p.is_quantized() {
                    return Err(WireError::bad_request(
                        "rtn precision must be quantized",
                    ));
                }
                CacheMode::rtn(dims, p)
            }
            other => {
                return Err(WireError::bad_request(format!("unknown mode '{other}'")))
            }
        };
        Ok(mode)
    }
}

// ----------------------------------------------------------------------
// Events & sinks
// ----------------------------------------------------------------------

/// One streamed serving event. The terminal event of a submit op is always
/// `Done`; `Stats`/`CancelResult` answer their respective ops.
#[derive(Debug)]
pub enum ServeEvent {
    /// A sampled token, streamed as soon as it exists. `index` counts this
    /// turn's generated tokens from 0.
    Token { id: u64, index: usize, token: i64 },
    /// Terminal event: the completed (or failed / cancelled) turn.
    Done(Response),
    /// Answer to a `stats` op.
    Stats { id: u64, snapshot: StatsSnapshot },
    /// Answer to a `cancel` op (`found`: the target was waiting or active).
    CancelResult { id: u64, target: u64, found: bool },
}

/// Where a request's events go. The TCP front-end implements this with a
/// per-connection writer channel; tests use a plain
/// `mpsc::Sender<ServeEvent>`.
pub trait EventSink: Send {
    /// Deliver one event. Returns false when the receiver is gone (the
    /// coordinator keeps generating regardless; a vanished client just
    /// stops observing).
    fn emit(&self, ev: ServeEvent) -> bool;
}

impl EventSink for mpsc::Sender<ServeEvent> {
    fn emit(&self, ev: ServeEvent) -> bool {
        self.send(ev).is_ok()
    }
}

/// Event sink for one request.
pub type Reply = Box<dyn EventSink>;

// ----------------------------------------------------------------------
// Priority lanes
// ----------------------------------------------------------------------

/// Admission priority lane for a submit op. Plain data parsed by the wire
/// layer (`"priority": "interactive" | "batch"`); interpreted only by the
/// QoS admission layer — with QoS disabled both lanes behave identically
/// (FCFS), so the field is inert by default.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive traffic; served first and shed last.
    #[default]
    Interactive,
    /// Throughput traffic; served when the interactive lane is empty and
    /// shed first under pressure.
    Batch,
}

impl Priority {
    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<Priority> {
        Some(match s {
            "interactive" => Priority::Interactive,
            "batch" => Priority::Batch,
            _ => return None,
        })
    }
}

impl fmt::Display for Priority {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

// ----------------------------------------------------------------------
// Ops & requests
// ----------------------------------------------------------------------

/// One operation handed to the coordinator thread.
pub enum Op {
    /// Start a turn: a fresh `generate`, or an `append` continuing a
    /// parked session when [`Request::session`] is set.
    Submit(Request),
    /// Cancel a waiting or active request by id. The target receives its
    /// terminal `done` (with `cancelled: true` and any partial tokens);
    /// the cancel op itself is answered with a `CancelResult`.
    Cancel { id: u64, target: u64, reply: Reply },
    /// Snapshot pool/footprint/throughput counters.
    Stats { id: u64, reply: Reply },
}

/// A generation turn.
pub struct Request {
    pub id: u64,
    /// Prompt token ids (for `append`: only the newly added tokens).
    pub prompt: Vec<i64>,
    /// Maximum new tokens to generate (including the prefill's first token).
    pub max_new: usize,
    /// Stop early when this token is produced.
    pub stop: Option<i64>,
    /// Requested compression; resolved to a [`CacheMode`] at admission.
    /// Ignored for `append` turns (the cache keeps its original config).
    pub spec: CompressionSpec,
    /// `Some(sid)`: continue the parked session `sid` (the `append` op),
    /// re-ingesting `prompt` into its existing hi/lo tiers.
    pub session: Option<u64>,
    /// Keep the session's cache checked out after `done` so a follow-up
    /// `append` can continue it.
    pub keep: bool,
    /// Tenant identity for fair queuing and rate limits. The TCP front-end
    /// sets this to the connection id; in-process callers default to 0
    /// (one implicit tenant — QoS sees a single queue, i.e. FCFS).
    pub tenant: u64,
    /// Admission lane (inert unless the scheduler runs with QoS enabled).
    pub priority: Priority,
    pub submitted_at: Instant,
    pub reply: Reply,
}

/// Per-turn latency/throughput metrics.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    /// Time to first token of this turn.
    pub ttft: Duration,
    /// Total turn latency.
    pub latency: Duration,
    /// Prompt tokens submitted this turn (not cumulative across turns).
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// Logical cache size at completion (% of full FP16).
    pub cache_pct: f64,
    /// Host bytes the session's cache pinned at completion (pooled shadow
    /// blocks + tier storage) — the bytes-per-session serving metric.
    pub host_bytes: usize,
    /// Hi-tier token-slots occupied at completion (across planes). For
    /// multi-turn sessions this carries over from previous turns.
    pub hi_slots: u64,
    /// Lo-tier (retained) token-slots occupied at completion.
    pub lo_slots: u64,
    /// lo→hi promotions performed during THIS turn (the delta against the
    /// session's counter at admission; 0 unless the opt-in promotion pass
    /// is enabled).
    pub promotions: u64,
    /// Promotions the hysteresis suppressed during this turn.
    pub thrash_suppressed: u64,
}

impl RequestMetrics {
    pub fn zero() -> RequestMetrics {
        RequestMetrics {
            ttft: Duration::ZERO,
            latency: Duration::ZERO,
            prompt_tokens: 0,
            generated_tokens: 0,
            cache_pct: 0.0,
            host_bytes: 0,
            hi_slots: 0,
            lo_slots: 0,
            promotions: 0,
            thrash_suppressed: 0,
        }
    }
}

/// A completed turn (the payload of the terminal `done`/`error` event).
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    /// This turn's generated tokens.
    pub tokens: Vec<i64>,
    pub metrics: RequestMetrics,
    /// Session id the cache was parked under (requests with `keep`).
    pub session: Option<u64>,
    /// The turn was cancelled; `tokens` holds whatever was generated.
    pub cancelled: bool,
    pub error: Option<WireError>,
}

impl Response {
    pub fn error(id: u64, err: WireError) -> Response {
        Response {
            id,
            tokens: Vec::new(),
            metrics: RequestMetrics::zero(),
            session: None,
            cancelled: false,
            error: Some(err),
        }
    }

    /// Terminal response for a request cancelled before admission.
    pub fn cancelled(id: u64) -> Response {
        Response {
            id,
            tokens: Vec::new(),
            metrics: RequestMetrics::zero(),
            session: None,
            cancelled: true,
            error: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            max_seq: 32,
            quant_group: 4,
            params: 0,
        }
    }

    #[test]
    fn spec_resolves_all_modes() {
        let d = dims();
        assert!(matches!(
            CompressionSpec::full().resolve(&d).unwrap(),
            CacheMode::Full
        ));
        assert!(matches!(
            CompressionSpec::oracle(7).resolve(&d).unwrap(),
            CacheMode::Oracle { k: 7 }
        ));
        match CompressionSpec::mikv(0.25, "int2").resolve(&d).unwrap() {
            CacheMode::Mikv { cfg, policy } => {
                assert!((cfg.importance_ratio - 0.25).abs() < 1e-9);
                assert_eq!(cfg.lo.precision, Precision::Int2);
                assert_eq!(policy, "h2o");
            }
            _ => panic!("not mikv"),
        }
        match CompressionSpec::h2o(0.5).resolve(&d).unwrap() {
            CacheMode::Mikv { cfg, .. } => {
                assert_eq!(cfg.retention, crate::kvcache::RetentionMode::Evict)
            }
            _ => panic!("not h2o"),
        }
        match CompressionSpec::rtn("int4").resolve(&d).unwrap() {
            CacheMode::Mikv { cfg, .. } => assert_eq!(cfg.lo.precision, Precision::Int4),
            _ => panic!("not rtn"),
        }
    }

    #[test]
    fn spec_overrides_group_and_policy() {
        let d = dims();
        let mut s = CompressionSpec::mikv(0.3, "int4");
        s.group = Some(2);
        s.policy = Some("local".to_string());
        match s.resolve(&d).unwrap() {
            CacheMode::Mikv { cfg, policy } => {
                assert_eq!(cfg.lo.group, 2);
                assert_eq!(policy, "local");
            }
            _ => panic!("not mikv"),
        }
    }

    #[test]
    fn spec_promotion_resolves_and_gates_by_mode() {
        let d = dims();
        // promoted mikv carries the default promotion knobs into the cfg
        match CompressionSpec::mikv(0.25, "int4").promoted().resolve(&d).unwrap() {
            CacheMode::Mikv { cfg, .. } => {
                assert_eq!(
                    cfg.promotion,
                    Some(crate::kvcache::PromotionConfig::default())
                );
            }
            other => panic!("not mikv: {other:?}"),
        }
        // unspecified and explicit-false both resolve to off
        match CompressionSpec::mikv(0.25, "int4").resolve(&d).unwrap() {
            CacheMode::Mikv { cfg, .. } => assert_eq!(cfg.promotion, None),
            other => panic!("not mikv: {other:?}"),
        }
        let mut off = CompressionSpec::mikv(0.25, "int4");
        off.promotion = Some(false);
        match off.resolve(&d).unwrap() {
            CacheMode::Mikv { cfg, .. } => assert_eq!(cfg.promotion, None),
            other => panic!("not mikv: {other:?}"),
        }
        // promotion outside mikv is a bad_request (h2o evicts — there is
        // nothing retained to promote; full/rtn/oracle have no hi churn)
        for spec in [
            CompressionSpec::h2o(0.25).promoted(),
            CompressionSpec::full().promoted(),
            CompressionSpec::rtn("int8").promoted(),
            CompressionSpec::oracle(4).promoted(),
        ] {
            let err = spec.resolve(&d).expect_err("must reject");
            assert_eq!(err.code, ErrorCode::BadRequest);
            assert!(err.message.contains("promotion"), "{err}");
        }
    }

    #[test]
    fn spec_rejects_invalid_fields() {
        let d = dims();
        let cases: Vec<CompressionSpec> = vec![
            CompressionSpec::base("warp"),
            CompressionSpec::mikv(1.5, "int2"),
            CompressionSpec::mikv(-0.1, "int2"),
            CompressionSpec::mikv(0.2, "int99"),
            CompressionSpec::mikv(0.2, "fp16"),
            CompressionSpec {
                group: Some(3), // does not divide d_head = 8
                ..CompressionSpec::mikv(0.2, "int2")
            },
            CompressionSpec {
                policy: Some("nope".to_string()),
                ..CompressionSpec::mikv(0.2, "int2")
            },
        ];
        for s in cases {
            let err = s.resolve(&d).expect_err(&format!("{s:?} must fail"));
            assert_eq!(err.code, ErrorCode::BadRequest, "{s:?}");
        }
    }

    #[test]
    fn error_codes_roundtrip() {
        for code in [
            ErrorCode::BadRequest,
            ErrorCode::Overloaded,
            ErrorCode::SessionNotFound,
            ErrorCode::SessionBusy,
            ErrorCode::CacheFull,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), Some(code));
        }
        assert_eq!(ErrorCode::parse("warp"), None);
    }

    #[test]
    fn priority_roundtrips_and_defaults_interactive() {
        for p in [Priority::Interactive, Priority::Batch] {
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Interactive);
    }

    #[test]
    fn retry_after_is_absent_unless_attached() {
        let e = WireError::new(ErrorCode::Overloaded, "full");
        assert_eq!(e.retry_after_ms, None);
        let e = e.with_retry_after(50);
        assert_eq!(e.retry_after_ms, Some(50));
        // the plain constructors never set a hint
        assert_eq!(WireError::bad_request("x").retry_after_ms, None);
        assert_eq!(WireError::internal("x").retry_after_ms, None);
    }

    #[test]
    fn sink_over_channel_delivers() {
        let (tx, rx) = mpsc::channel::<ServeEvent>();
        assert!(tx.emit(ServeEvent::Token {
            id: 1,
            index: 0,
            token: 42
        }));
        match rx.recv().unwrap() {
            ServeEvent::Token { id, index, token } => {
                assert_eq!((id, index, token), (1, 0, 42));
            }
            other => panic!("unexpected {other:?}"),
        }
        drop(rx);
        assert!(!tx.emit(ServeEvent::Done(Response::cancelled(1))));
    }
}
