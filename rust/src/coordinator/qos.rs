//! Multi-tenant QoS admission machinery: deficit round-robin fair
//! queuing, priority lanes, per-tenant token buckets, and cheapest-first
//! shedding.
//!
//! These are the pure data structures behind the scheduler's admission
//! layer (`coordinator::scheduler`). Nothing here touches threads,
//! channels, or workers — the scheduler owns one [`DrrQueue`] per worker
//! and one [`RateLimiter`] shared across workers, and drives them from its
//! single admission thread, so no synchronization is needed.
//!
//! **Inert by default:** the scheduler only builds these structures when a
//! [`QosConfig`] is supplied. Without one, admission stays the historical
//! FCFS forward-to-worker path, byte-identical on the wire.
//!
//! Semantics:
//!
//! * **Cost** of a turn = prompt tokens + requested new tokens (min 1) —
//!   the work a turn asks for, so fairness is over *tokens*, not turn
//!   counts, and a chatty tenant cannot game it with many small turns any
//!   more than with few huge ones.
//! * **DRR**: per worker, two lanes (interactive strictly before batch);
//!   within a lane, tenants sit on a round-robin ring. A tenant at the
//!   head is served while its deficit covers the head turn's cost;
//!   otherwise it gains one `quantum` of deficit and rotates to the back.
//!   A tenant whose queue empties leaves the ring and forfeits its
//!   deficit (no credit hoarding). With a single queued tenant the
//!   deficit check is bypassed — fairness is moot and the queue must be
//!   work-conserving.
//! * **Shedding** removes the cheapest-to-reject waiting turn: the newest
//!   batch-lane arrival first, then the newest interactive arrival.
//!   Active (admitted) work is never touched.
//! * **Rate limiting** is a classic token bucket per tenant in cost
//!   units; a rejection computes the milliseconds until the bucket can
//!   cover the turn — the `retry_after_ms` hint.

use super::request::{Priority, Request};
use std::collections::{HashMap, VecDeque};
use std::time::Instant;

/// Admission-layer QoS knobs. Constructed only when QoS is explicitly
/// enabled (`mikv serve --qos ...`); its absence preserves FCFS admission
/// exactly.
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// DRR deficit quantum in cost units (tokens) credited per ring visit.
    pub quantum: usize,
    /// Per-tenant sustained admission rate in cost units per second.
    /// `None` disables rate limiting.
    pub rate: Option<f64>,
    /// Per-tenant token-bucket capacity in cost units (the burst a tenant
    /// may spend above the sustained rate).
    pub burst: f64,
    /// How many admitted turns a worker may have in flight before the
    /// scheduler holds further dispatches in its DRR queues. Small values
    /// keep ordering decisions in the fair queue instead of the worker's
    /// FCFS queue.
    pub inflight_per_worker: usize,
    /// Per-worker bound on turns waiting in the scheduler's DRR queues;
    /// beyond it the shed policy makes room (or rejects the arrival).
    pub max_backlog: usize,
    /// Base backoff hint attached to shed rejections.
    pub retry_after_ms: u64,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            quantum: 64,
            rate: None,
            burst: 512.0,
            inflight_per_worker: 4,
            max_backlog: 256,
            retry_after_ms: 50,
        }
    }
}

/// Cost of a turn in scheduling units: the tokens it asks the engine to
/// touch. Never 0, so deficits always make progress.
pub fn turn_cost(prompt_len: usize, max_new: usize) -> usize {
    (prompt_len + max_new).max(1)
}

/// Safety bound on DRR ring rotations per pop. Unreachable in practice
/// (each rotation credits a quantum, so a head turn of cost C is served
/// within C/quantum cycles); if ever hit, the head turn is served anyway —
/// the queue degrades toward round-robin, it never stalls.
const MAX_DRR_SPINS: usize = 65_536;

struct QueueEntry {
    /// Global arrival stamp; the shed policy evicts the largest.
    seq: u64,
    cost: usize,
    req: Request,
}

struct TenantQueue {
    deficit: usize,
    q: VecDeque<QueueEntry>,
}

/// One priority lane: tenants on a round-robin ring, FIFO per tenant.
/// Invariant: a tenant is in `ring` iff it is in `tenants` iff its queue
/// is non-empty.
struct Lane {
    ring: VecDeque<u64>,
    tenants: HashMap<u64, TenantQueue>,
}

impl Lane {
    fn new() -> Lane {
        Lane {
            ring: VecDeque::new(),
            tenants: HashMap::new(),
        }
    }

    fn push(&mut self, tenant: u64, entry: QueueEntry) {
        match self.tenants.get_mut(&tenant) {
            Some(tq) => tq.q.push_back(entry),
            None => {
                self.ring.push_back(tenant);
                let mut q = VecDeque::new();
                q.push_back(entry);
                self.tenants.insert(tenant, TenantQueue { deficit: 0, q });
            }
        }
    }

    /// DRR pop: serve the head tenant while its deficit covers the head
    /// cost; otherwise credit one quantum and rotate. Returns `None` only
    /// when the lane is empty.
    fn pop(&mut self, quantum: usize) -> Option<QueueEntry> {
        let quantum = quantum.max(1);
        let mut spins = 0usize;
        while let Some(&tenant) = self.ring.front() {
            let Some(tq) = self.tenants.get_mut(&tenant) else {
                // Defensive: ring/map invariant broken — drop the stale
                // ring slot and carry on.
                self.ring.pop_front();
                continue;
            };
            let Some(head_cost) = tq.q.front().map(|e| e.cost) else {
                self.ring.pop_front();
                self.tenants.remove(&tenant);
                continue;
            };
            let uncontended = self.ring.len() == 1;
            if tq.deficit >= head_cost || uncontended || spins >= MAX_DRR_SPINS {
                tq.deficit = tq.deficit.saturating_sub(head_cost);
                let entry = tq.q.pop_front();
                if tq.q.is_empty() {
                    self.ring.pop_front();
                    self.tenants.remove(&tenant);
                }
                return entry;
            }
            tq.deficit += quantum;
            self.ring.rotate_left(1);
            spins += 1;
        }
        None
    }

    /// Remove and return the newest arrival in this lane (the shed
    /// victim), if any.
    fn shed_newest(&mut self) -> Option<Request> {
        let victim = self
            .tenants
            .iter()
            .filter_map(|(&t, tq)| tq.q.back().map(|e| (e.seq, t)))
            .max_by_key(|&(seq, _)| seq)
            .map(|(_, t)| t)?;
        let tq = self.tenants.get_mut(&victim)?;
        let entry = tq.q.pop_back();
        if tq.q.is_empty() {
            self.tenants.remove(&victim);
            self.ring.retain(|&t| t != victim);
        }
        entry.map(|e| e.req)
    }

    /// Remove a queued request by id (cancel-before-dispatch).
    fn remove(&mut self, target: u64) -> Option<Request> {
        let (tenant, idx) = self.tenants.iter().find_map(|(&t, tq)| {
            tq.q.iter().position(|e| e.req.id == target).map(|i| (t, i))
        })?;
        let tq = self.tenants.get_mut(&tenant)?;
        let entry = tq.q.remove(idx);
        if tq.q.is_empty() {
            self.tenants.remove(&tenant);
            self.ring.retain(|&t| t != tenant);
        }
        entry.map(|e| e.req)
    }

    fn len(&self) -> usize {
        self.tenants.values().map(|tq| tq.q.len()).sum()
    }
}

/// Per-worker fair queue: two priority lanes of per-tenant DRR rings.
pub struct DrrQueue {
    /// `lanes[0]` interactive, `lanes[1]` batch.
    lanes: [Lane; 2],
    next_seq: u64,
    queued: usize,
}

fn lane_index(p: Priority) -> usize {
    match p {
        Priority::Interactive => 0,
        Priority::Batch => 1,
    }
}

impl DrrQueue {
    pub fn new() -> DrrQueue {
        DrrQueue {
            lanes: [Lane::new(), Lane::new()],
            next_seq: 0,
            queued: 0,
        }
    }

    /// Turns currently queued (both lanes).
    pub fn len(&self) -> usize {
        self.queued
    }

    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// Enqueue a turn into its priority lane under its tenant.
    pub fn push(&mut self, req: Request) {
        let cost = turn_cost(req.prompt.len(), req.max_new);
        let seq = self.next_seq;
        self.next_seq += 1;
        let tenant = req.tenant;
        let lane = lane_index(req.priority);
        if let Some(l) = self.lanes.get_mut(lane) {
            l.push(tenant, QueueEntry { seq, cost, req });
            self.queued += 1;
        }
    }

    /// Next turn to dispatch: interactive lane strictly first, DRR within
    /// the lane.
    pub fn pop_next(&mut self, quantum: usize) -> Option<Request> {
        for lane in self.lanes.iter_mut() {
            if let Some(entry) = lane.pop(quantum) {
                self.queued = self.queued.saturating_sub(1);
                return Some(entry.req);
            }
        }
        None
    }

    /// Shed the cheapest-to-reject waiting turn: newest batch arrival
    /// first, then newest interactive arrival. Returns the victim and the
    /// lane it was shed from. Never touches dispatched (active) work.
    pub fn shed_cheapest(&mut self) -> Option<(Request, Priority)> {
        for (li, lane) in self.lanes.iter_mut().enumerate().rev() {
            if let Some(req) = lane.shed_newest() {
                self.queued = self.queued.saturating_sub(1);
                let p = if li == 1 {
                    Priority::Batch
                } else {
                    Priority::Interactive
                };
                return Some((req, p));
            }
        }
        None
    }

    /// Remove a still-queued request by id so a `cancel` can answer it
    /// before it ever reaches a worker.
    pub fn take_by_id(&mut self, target: u64) -> Option<Request> {
        for lane in self.lanes.iter_mut() {
            if let Some(req) = lane.remove(target) {
                self.queued = self.queued.saturating_sub(1);
                return Some(req);
            }
        }
        None
    }

    /// Queued turns in the batch lane (shed-order observability).
    pub fn batch_len(&self) -> usize {
        self.lanes.get(1).map(Lane::len).unwrap_or(0)
    }
}

impl Default for DrrQueue {
    fn default() -> Self {
        Self::new()
    }
}

// ----------------------------------------------------------------------
// Token-bucket rate limiting
// ----------------------------------------------------------------------

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Per-tenant token buckets in cost units. `rate` units refill per
/// second up to `burst`; a turn is admitted when its full cost is
/// available.
pub struct RateLimiter {
    rate: f64,
    burst: f64,
    buckets: HashMap<u64, Bucket>,
}

impl RateLimiter {
    /// `rate` is clamped to a tiny positive floor so a misconfigured 0
    /// cannot divide-by-zero the retry hint (it would simply reject
    /// everything with a huge hint).
    pub fn new(rate: f64, burst: f64) -> RateLimiter {
        RateLimiter {
            rate: rate.max(f64::MIN_POSITIVE),
            burst: burst.max(1.0),
            buckets: HashMap::new(),
        }
    }

    /// Try to spend `cost` units from `tenant`'s bucket at `now`.
    /// `Err(ms)` is the retry hint: milliseconds until the bucket will
    /// have refilled enough to cover `cost`.
    pub fn try_admit(&mut self, tenant: u64, cost: usize, now: Instant) -> Result<(), u64> {
        let burst = self.burst;
        let rate = self.rate;
        let b = self.buckets.entry(tenant).or_insert(Bucket {
            tokens: burst,
            last: now,
        });
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.tokens = (b.tokens + dt * rate).min(burst);
        b.last = now;
        let c = (cost as f64).min(burst);
        if b.tokens >= c {
            b.tokens -= c;
            Ok(())
        } else {
            let ms = ((c - b.tokens) * 1000.0 / rate).ceil();
            // f64→u64 casts saturate; a huge/inf hint becomes u64::MAX.
            Err((ms as u64).max(1))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CompressionSpec, ServeEvent};
    use std::sync::mpsc;
    use std::time::Duration;

    fn req(id: u64, tenant: u64, priority: Priority, cost: usize) -> Request {
        let (tx, _rx) = mpsc::channel::<ServeEvent>();
        // keep the receiver alive is irrelevant here — qos never emits
        Request {
            id,
            prompt: vec![1; cost.saturating_sub(1).max(1)],
            max_new: 1,
            stop: None,
            spec: CompressionSpec::full(),
            session: None,
            keep: false,
            tenant,
            priority,
            submitted_at: Instant::now(),
            reply: Box::new(tx),
        }
    }

    #[test]
    fn turn_cost_floors_at_one() {
        assert_eq!(turn_cost(0, 0), 1);
        assert_eq!(turn_cost(3, 5), 8);
    }

    /// A chatty tenant with many queued turns is interleaved with a
    /// well-behaved tenant turn-for-turn (equal costs, equal quantum):
    /// DRR alternates instead of draining the chatty backlog first.
    #[test]
    fn drr_interleaves_tenants_instead_of_fifo() {
        let mut q = DrrQueue::new();
        // chatty tenant 1 enqueues 6 turns first, tenant 2 enqueues 2
        for i in 0..6 {
            q.push(req(100 + i, 1, Priority::Interactive, 8));
        }
        for i in 0..2 {
            q.push(req(200 + i, 2, Priority::Interactive, 8));
        }
        let mut order = Vec::new();
        while let Some(r) = q.pop_next(8) {
            order.push(r.tenant);
        }
        assert_eq!(q.len(), 0);
        // tenant 2's two turns are served within the first four pops, not
        // after tenant 1's entire backlog
        let first4: Vec<u64> = order.iter().take(4).copied().collect();
        assert_eq!(
            first4.iter().filter(|&&t| t == 2).count(),
            2,
            "DRR must interleave: {order:?}"
        );
        assert_eq!(order.len(), 8);
    }

    /// Deficit accounting is by cost, not turn count: a tenant with huge
    /// turns gets the same token share as a tenant with small turns.
    #[test]
    fn drr_shares_by_cost_not_turn_count() {
        let mut q = DrrQueue::new();
        // tenant 1: 2 huge turns (cost 32); tenant 2: 8 small turns (cost 8)
        for i in 0..2 {
            q.push(req(100 + i, 1, Priority::Interactive, 32));
        }
        for i in 0..8 {
            q.push(req(200 + i, 2, Priority::Interactive, 8));
        }
        let mut served = Vec::new();
        while let Some(r) = q.pop_next(8) {
            served.push((r.tenant, turn_cost(r.prompt.len(), r.max_new)));
        }
        assert_eq!(served.len(), 10);
        // by the time tenant 1's first huge turn is served, tenant 2 has
        // been served roughly the same cost (several small turns), not 0.
        let pos = served.iter().position(|&(t, _)| t == 1).unwrap();
        let t2_cost_before: usize = served[..pos]
            .iter()
            .filter(|&&(t, _)| t == 2)
            .map(|&(_, c)| c)
            .sum();
        assert!(
            t2_cost_before >= 16,
            "tenant 2 served {t2_cost_before} cost before tenant 1's huge turn: {served:?}"
        );
    }

    #[test]
    fn interactive_lane_strictly_precedes_batch() {
        let mut q = DrrQueue::new();
        q.push(req(1, 1, Priority::Batch, 4));
        q.push(req(2, 1, Priority::Batch, 4));
        q.push(req(3, 2, Priority::Interactive, 4));
        q.push(req(4, 3, Priority::Interactive, 4));
        let mut prios = Vec::new();
        while let Some(r) = q.pop_next(8) {
            prios.push(r.priority);
        }
        assert_eq!(
            prios,
            vec![
                Priority::Interactive,
                Priority::Interactive,
                Priority::Batch,
                Priority::Batch
            ]
        );
    }

    /// Shed order: newest batch arrival first, interactive only when the
    /// batch lane is dry, FIFO-queued work preserved.
    #[test]
    fn shed_takes_newest_batch_first_then_interactive() {
        let mut q = DrrQueue::new();
        q.push(req(1, 1, Priority::Interactive, 4)); // oldest
        q.push(req(2, 2, Priority::Batch, 4));
        q.push(req(3, 2, Priority::Batch, 4)); // newest batch
        q.push(req(4, 3, Priority::Interactive, 4)); // newest overall
        let (v1, lane1) = q.shed_cheapest().unwrap();
        assert_eq!((v1.id, lane1), (3, Priority::Batch));
        let (v2, lane2) = q.shed_cheapest().unwrap();
        assert_eq!((v2.id, lane2), (2, Priority::Batch));
        // batch lane dry → newest interactive
        let (v3, lane3) = q.shed_cheapest().unwrap();
        assert_eq!((v3.id, lane3), (4, Priority::Interactive));
        let (v4, _) = q.shed_cheapest().unwrap();
        assert_eq!(v4.id, 1);
        assert!(q.shed_cheapest().is_none());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn take_by_id_removes_queued_request() {
        let mut q = DrrQueue::new();
        q.push(req(7, 1, Priority::Interactive, 4));
        q.push(req(8, 1, Priority::Batch, 4));
        assert!(q.take_by_id(99).is_none());
        let r = q.take_by_id(8).unwrap();
        assert_eq!(r.id, 8);
        assert_eq!(q.len(), 1);
        assert_eq!(q.batch_len(), 0);
        let r = q.take_by_id(7).unwrap();
        assert_eq!(r.id, 7);
        assert!(q.is_empty());
        // empty tenant left no stale ring slots: pops stay clean
        assert!(q.pop_next(8).is_none());
    }

    /// An emptied tenant forfeits its deficit: re-arriving later it starts
    /// from 0 like everyone else (no credit hoarding while idle).
    #[test]
    fn deficit_resets_when_tenant_queue_empties() {
        let mut q = DrrQueue::new();
        q.push(req(1, 1, Priority::Interactive, 4));
        assert_eq!(q.pop_next(1000).unwrap().id, 1);
        // tenant 1 comes back against tenant 2; neither has stored credit,
        // so with equal costs service alternates starting from arrival
        // order.
        q.push(req(2, 1, Priority::Interactive, 8));
        q.push(req(3, 2, Priority::Interactive, 8));
        q.push(req(4, 1, Priority::Interactive, 8));
        q.push(req(5, 2, Priority::Interactive, 8));
        let mut tenants = Vec::new();
        while let Some(r) = q.pop_next(8) {
            tenants.push(r.tenant);
        }
        assert_eq!(tenants, vec![1, 2, 1, 2]);
    }

    #[test]
    fn rate_limiter_admits_burst_then_rejects_with_hint() {
        let t0 = Instant::now();
        let mut rl = RateLimiter::new(100.0, 10.0); // 100 units/s, burst 10
        assert!(rl.try_admit(1, 10, t0).is_ok()); // spends the full burst
        let hint = rl.try_admit(1, 5, t0).unwrap_err();
        // needs 5 units at 100/s → 50 ms
        assert_eq!(hint, 50);
        // an independent tenant has its own bucket
        assert!(rl.try_admit(2, 10, t0).is_ok());
        // after 100 ms, 10 units refilled → admit again
        let t1 = t0 + Duration::from_millis(100);
        assert!(rl.try_admit(1, 10, t1).is_ok());
    }

    #[test]
    fn rate_limiter_caps_cost_at_burst() {
        // a turn costlier than the whole burst must still be admittable
        // (otherwise it could never run at any rate)
        let t0 = Instant::now();
        let mut rl = RateLimiter::new(10.0, 8.0);
        assert!(rl.try_admit(1, 100, t0).is_ok());
        let hint = rl.try_admit(1, 100, t0).unwrap_err();
        // bucket empty, needs the (capped) 8 units at 10/s → 800 ms
        assert_eq!(hint, 800);
    }
}
