//! The continuous-batching worker loop.
//!
//! A [`Coordinator`] is **one engine worker**: it owns its engine, its
//! [`BufferPool`] and its parked-session registry for the lifetime of
//! [`Coordinator::run`]. In the sharded runtime
//! ([`crate::coordinator::scheduler`]) N of these run on dedicated threads
//! behind an admission scheduler; `Coordinator::new` is the degenerate
//! single-worker deployment (worker 0 of 1) and preserves the original
//! one-loop behaviour exactly.
//!
//! Runs on its engine's thread (PJRT handles are not `Send`). Each
//! iteration:
//!
//! 1. drains newly arrived [`Op`]s: submits join the waiting queue (FCFS,
//!    bounded by `max_waiting` → `overloaded`), cancels mark their target,
//!    stats ops are answered immediately;
//! 2. admits waiting turns up to `max_active`: fresh `generate`s are
//!    prefilled in chunks of the compiled prefill batch sizes, `append`s
//!    check their parked session out of the registry and queue the new
//!    prompt tokens for re-ingest;
//! 3. forms decode batches from the active set, grouped by graph kind
//!    (MiKV-cache sessions vs full/oracle-cache sessions — different
//!    executables) and, within the oracle group, by `oracle_k`. Sampled
//!    tokens are **streamed** to each turn's [`EventSink`] as they exist;
//!    sessions still re-ingesting appended prompt tokens feed the next
//!    prompt token instead of the sample;
//! 4. retires finished turns (budget / stop token / cache full / cancel /
//!    engine failure), emitting the terminal `done` (or structured
//!    `error`) event — and, for turns submitted with `keep`, **parking**
//!    the session in the registry so a follow-up `append` continues the
//!    same cache. The registry is bounded by a TTL and a total-host-bytes
//!    cap (oldest parked evicted first); dropped sessions return their
//!    blocks to the shared [`BufferPool`].
//!
//! Short requests are never stuck behind long ones: batches are re-formed
//! every step from whatever is active (the "continuous" in continuous
//! batching, per Orca/vLLM).

use super::cold::ColdStore;
use super::request::{ErrorCode, Op, Request, RequestMetrics, Response, ServeEvent, WireError};
use super::stats::{MetricsCollector, StatsSnapshot, WorkerStats};
use crate::kvcache::{spill, BufferPool, PromotionStats};
use crate::model::{sampler, CacheMode, Engine, Session};
use crate::runtime::ModelDims;
use crate::util::faults::FaultPlan;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Coordinator tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Maximum sessions decoding concurrently.
    pub max_active: usize,
    /// Maximum requests prefilled per scheduler iteration.
    pub prefill_chunk: usize,
    /// Channel poll timeout when idle.
    pub idle_poll: Duration,
    /// Waiting-queue bound; submits beyond it are rejected `overloaded`.
    pub max_waiting: usize,
    /// Parked sessions idle longer than this are dropped.
    pub session_ttl: Duration,
    /// Total host bytes parked sessions may pin; the oldest-parked are
    /// evicted beyond this bound.
    pub max_session_bytes: usize,
    /// Root directory of the opt-in cold tier. When set, sessions leaving
    /// the parked registry (TTL decay or host-bytes pressure) are spilled
    /// to a versioned snapshot under `<cold_dir>/worker-<id>/` instead of
    /// dropped, and a later `append` restores them transparently. `None`
    /// (the default) keeps the historical drop-on-evict behaviour.
    pub cold_dir: Option<PathBuf>,
    /// Byte bound on this worker's cold-tier directory (0 = unbounded);
    /// the oldest-spilled snapshots are evicted beyond it.
    pub max_cold_bytes: u64,
    /// Deterministic fault-injection plan threaded into the cold tier (and,
    /// via the scheduler's engine factory, into the engine). Disabled by
    /// default: a plan with no armed sites is a no-op on every probe.
    pub faults: FaultPlan,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            max_active: 8,
            prefill_chunk: 4,
            idle_poll: Duration::from_millis(20),
            max_waiting: 256,
            session_ttl: Duration::from_secs(120),
            max_session_bytes: 512 << 20,
            cold_dir: None,
            max_cold_bytes: 256 << 20,
            faults: FaultPlan::disabled(),
        }
    }
}

/// Shared worker health state between a [`Coordinator`] and its supervisor
/// (the scheduler). All fields are read/written across the supervisor ↔
/// worker thread boundary, so they live behind atomics rather than a lock:
/// every access is a single counter op on paths that must never block.
#[derive(Debug, Default)]
pub struct WorkerVitals {
    /// Gauge: sessions currently parked in the worker's **hot** registry.
    /// On a worker panic these are unwound with the loop's locals (their
    /// pooled blocks return via `Drop`, but the KV state is gone), so the
    /// supervisor folds this gauge into `sessions_lost`.
    pub hot_parked: AtomicUsize,
    /// Cold-tier snapshots adopted by a respawned worker — each is a parked
    /// session that survived its owner's crash and stays appendable.
    pub sessions_recovered: AtomicU64,
    /// High-water mark of the worker's strided session-id allocator. A
    /// respawned worker resumes from here so it never re-issues a sid that
    /// may still name an on-disk snapshot from its previous life.
    pub next_session: AtomicU64,
    /// Set by the supervisor before a respawn: the next [`Coordinator::run_ref`]
    /// opens its cold tier in recovery mode (adopt existing snapshots
    /// instead of GC-ing the directory).
    pub recover: AtomicBool,
}

/// The engine surface the coordinator drives. The real [`Engine`] needs
/// compiled artifacts; this seam lets the scheduler loop be exercised (and
/// its failure handling regression-tested) with the artifact-free
/// [`crate::model::StubEngine`].
pub trait StepEngine {
    fn dims(&self) -> &ModelDims;

    /// Prefill the sessions' caches from their prompts; returns last-position
    /// logits per session.
    fn prefill(
        &self,
        sessions: &mut [&mut Session],
        prompts: &[Vec<i64>],
    ) -> crate::Result<Vec<Vec<f32>>>;

    /// One decode step over a homogeneous session group; returns one logits
    /// row per session.
    fn decode_step(&self, sessions: &mut [&mut Session]) -> crate::Result<Vec<Vec<f32>>>;

    /// Host-side decode-input assembly time (µs) of the most recent
    /// `decode_step` call, when the engine measures it. Feeds the
    /// `assembly_us` percentiles in the coordinator's stats snapshot.
    fn assembly_us_last(&self) -> Option<f64> {
        None
    }
}

impl StepEngine for Engine {
    fn dims(&self) -> &ModelDims {
        Engine::dims(self)
    }

    fn prefill(
        &self,
        sessions: &mut [&mut Session],
        prompts: &[Vec<i64>],
    ) -> crate::Result<Vec<Vec<f32>>> {
        Engine::prefill(self, sessions, prompts)
    }

    fn decode_step(&self, sessions: &mut [&mut Session]) -> crate::Result<Vec<Vec<f32>>> {
        Engine::decode_step(self, sessions)
    }

    fn assembly_us_last(&self) -> Option<f64> {
        Some(Engine::last_assembly_us(self))
    }
}

/// An in-flight turn.
struct Active {
    req: Request,
    sess: Session,
    /// Appended prompt tokens not yet fed through the decode path (see
    /// `admit_append`). While non-empty, sampled logits are discarded and
    /// the next prompt token is fed instead.
    pending_feed: VecDeque<i64>,
    /// This turn's prompt length (`sess.prompt_len` is cumulative).
    turn_prompt: usize,
    /// When this turn's first token was sampled (TTFT anchor).
    first_token_at: Option<Instant>,
    /// The session's promotion counters at admission — retire reports the
    /// delta, so multi-turn sessions never double-count across turns.
    promo_base: PromotionStats,
    /// Token events emitted this turn (also the next event index).
    emitted: usize,
    generated_budget: usize,
    cancelled: bool,
    /// Set when the engine failed a step for this session; the retire pass
    /// replies with a structured error instead of retrying forever.
    error: Option<WireError>,
}

impl Active {
    fn generated_len(&self) -> usize {
        // During an append's prompt re-ingest, `prompt_len` pre-counts the
        // still-pending tokens, so saturate instead of underflowing.
        self.sess.tokens.len().saturating_sub(self.sess.prompt_len)
    }

    fn finished(&self, max_seq: usize) -> bool {
        if self.cancelled {
            return true;
        }
        if !self.pending_feed.is_empty() {
            return false;
        }
        let gen = self.generated_len();
        // The next decode appends into slot `seq_len`, which is legal while
        // `seq_len < max_seq` — retire only once the cache is actually full
        // (`seq_len == max_seq`), so the last slot is not wasted. The stop
        // check only looks at *sampled* tokens (gen > 0), never at a fed
        // prompt token.
        gen >= self.generated_budget
            || (gen > 0 && self.req.stop == Some(self.sess.last_token))
            || self.sess.cache.seq_len() >= max_seq
    }
}

/// A session parked between turns, awaiting `append`.
struct Parked {
    sess: Session,
    parked_at: Instant,
    /// Whether the session may spill to the cold tier on eviction (the
    /// parking request's `compression.spill` knob; `false` = drop instead,
    /// so the KV state never touches disk).
    spill: bool,
}

/// The worker's between-turn session registry: the hot map of parked
/// sessions plus the optional on-disk cold tier they spill to.
///
/// The host-bytes footprint of the hot map is maintained as a **running
/// total** updated on every park/checkout — a parked session's cache is
/// never mutated, so the cached per-session size cannot go stale — instead
/// of being recomputed by summing the registry on every sweep and every
/// `stats` op. A debug assertion cross-checks the total against a full
/// recompute whenever it is read.
struct ParkedRegistry {
    hot: HashMap<u64, Parked>,
    /// Running Σ host_bytes over `hot` (see the type doc).
    hot_bytes: usize,
    cold: Option<ColdStore>,
}

impl ParkedRegistry {
    fn new(cold: Option<ColdStore>) -> Self {
        Self {
            hot: HashMap::new(),
            hot_bytes: 0,
            cold,
        }
    }

    /// Park a session, keeping the running byte total current.
    fn insert(&mut self, sid: u64, p: Parked) {
        self.hot_bytes += p.sess.cache.host_bytes();
        if let Some(old) = self.hot.insert(sid, p) {
            // Unreachable in the coordinator (a parked sid is checked out
            // before it can be parked again), but keep the total honest.
            self.hot_bytes = self.hot_bytes.saturating_sub(old.sess.cache.host_bytes());
        }
    }

    /// Check a session out of the hot map (for `append`, spill or drop).
    fn checkout(&mut self, sid: u64) -> Option<Parked> {
        let p = self.hot.remove(&sid)?;
        self.hot_bytes = self.hot_bytes.saturating_sub(p.sess.cache.host_bytes());
        Some(p)
    }

    fn len(&self) -> usize {
        self.hot.len()
    }

    fn is_empty(&self) -> bool {
        self.hot.is_empty()
    }

    /// Host bytes the hot registry pins — the running total, cross-checked
    /// against a full recompute in debug builds.
    fn hot_bytes(&self) -> usize {
        debug_assert_eq!(
            self.hot_bytes,
            self.hot.values().map(|p| p.sess.cache.host_bytes()).sum::<usize>(),
            "running parked host-bytes total drifted from the registry"
        );
        self.hot_bytes
    }

    /// Parked sids idle at least `ttl` (the TTL-decay sweep set).
    fn expired(&self, ttl: Duration) -> Vec<u64> {
        self.hot
            .iter()
            .filter(|(_, p)| p.parked_at.elapsed() >= ttl)
            .map(|(&sid, _)| sid)
            .collect()
    }

    /// Oldest-parked sid (ties broken by id for determinism).
    fn oldest(&self) -> Option<u64> {
        self.hot
            .iter()
            .min_by_key(|(sid, p)| (p.parked_at, **sid))
            .map(|(&sid, _)| sid)
    }

    fn cold_sessions(&self) -> usize {
        self.cold.as_ref().map(ColdStore::len).unwrap_or(0)
    }

    fn cold_bytes(&self) -> u64 {
        self.cold.as_ref().map(ColdStore::bytes).unwrap_or(0)
    }

    fn cold_evictions(&self) -> u64 {
        self.cold.as_ref().map(ColdStore::evictions).unwrap_or(0)
    }
}

/// One engine worker. Owns the engine for the lifetime of [`Self::run`].
pub struct Coordinator<E: StepEngine = Engine> {
    engine: E,
    cfg: CoordinatorConfig,
    pool: BufferPool,
    /// This worker's index (0-based) in the sharded runtime.
    worker_id: usize,
    /// Total workers in the runtime. Session ids are strided so that
    /// `owner(sid) = (sid - 1) % n_workers` — the scheduler routes `append`
    /// ops to the owning worker without any shared registry.
    n_workers: usize,
    /// Health state shared with the supervisor (fresh/private when the
    /// coordinator is unsupervised).
    vitals: Arc<WorkerVitals>,
}

impl<E: StepEngine> Coordinator<E> {
    /// Single-worker deployment (worker 0 of 1) — the original one-loop
    /// behaviour, used directly by tests and by `--workers 1`.
    pub fn new(engine: E, cfg: CoordinatorConfig) -> Self {
        Self::for_worker(engine, cfg, 0, 1)
    }

    /// One worker of a sharded runtime. Session ids this worker assigns
    /// satisfy `(sid - 1) % n_workers == worker_id`, which is the affinity
    /// contract [`super::scheduler::worker_of_session`] routes by.
    pub fn for_worker(
        engine: E,
        cfg: CoordinatorConfig,
        worker_id: usize,
        n_workers: usize,
    ) -> Self {
        assert!(n_workers >= 1, "need at least one worker");
        assert!(worker_id < n_workers, "worker_id {worker_id} of {n_workers}");
        Self {
            engine,
            cfg,
            pool: BufferPool::new(),
            worker_id,
            n_workers,
            vitals: Arc::new(WorkerVitals::default()),
        }
    }

    /// Share this worker's health state with a supervisor. The same
    /// `vitals` handed to a respawned coordinator carries the dead
    /// predecessor's sid high-water mark and recovery flag across the
    /// panic boundary.
    pub fn with_vitals(mut self, vitals: Arc<WorkerVitals>) -> Self {
        self.vitals = vitals;
        self
    }

    /// This worker's index in the sharded runtime (0 for single-worker).
    pub fn worker_id(&self) -> usize {
        self.worker_id
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// The shared pool session cache blocks are recycled through.
    pub fn pool(&self) -> &BufferPool {
        &self.pool
    }

    /// Serve until the op channel closes and all work drains.
    pub fn run(&self, rx: Receiver<Op>) {
        self.run_ref(&rx)
    }

    /// Like [`Self::run`], but borrows the op channel instead of consuming
    /// it — the supervisor's respawn loop needs the receiver to survive a
    /// worker panic so the replacement coordinator can keep serving it.
    pub fn run_ref(&self, rx: &Receiver<Op>) {
        self.run_until_ref(rx, || false)
    }

    /// Like [`Self::run`], but also stops (after draining in-flight work)
    /// once `stop()` returns true — used when the shutdown signal is
    /// something other than channel closure (e.g. a finished test client).
    pub fn run_until(&self, rx: Receiver<Op>, stop: impl Fn() -> bool) {
        self.run_until_ref(&rx, stop)
    }

    /// The worker loop proper ([`Self::run_until`] by reference).
    pub fn run_until_ref(&self, rx: &Receiver<Op>, stop: impl Fn() -> bool) {
        let mut waiting: VecDeque<Request> = VecDeque::new();
        let mut active: Vec<Active> = Vec::new();
        // Recovery mode (set by the supervisor before a respawn) adopts the
        // dead predecessor's cold-tier snapshots instead of GC-ing them.
        let recovering = self.vitals.recover.swap(false, Ordering::AcqRel);
        // A failed cold-tier open degrades to the historical drop-on-evict
        // registry rather than killing the worker.
        let cold = self.cfg.cold_dir.as_ref().and_then(|root| {
            let opened = if recovering {
                ColdStore::open_recover(
                    root,
                    self.worker_id,
                    self.cfg.max_cold_bytes,
                    self.cfg.faults.clone(),
                )
            } else {
                ColdStore::open_with_faults(
                    root,
                    self.worker_id,
                    self.cfg.max_cold_bytes,
                    self.cfg.faults.clone(),
                )
            };
            match opened {
                Ok(c) => Some(c),
                Err(e) => {
                    crate::log_error!(
                        "cold tier disabled: open {} failed: {e}",
                        root.display()
                    );
                    None
                }
            }
        });
        if recovering {
            let adopted = cold.as_ref().map(ColdStore::len).unwrap_or(0);
            if adopted > 0 {
                self.vitals
                    .sessions_recovered
                    // lint: relaxed-ordering-audit-ok: monotonic counter, no ordering dependency
                    .fetch_add(adopted as u64, Ordering::Relaxed);
            }
            crate::log_info!(
                "worker {} respawned: adopted {adopted} cold session(s)",
                self.worker_id
            );
        }
        let mut parked = ParkedRegistry::new(cold);
        // Strided so the owning worker is recoverable from the id alone:
        // worker w of N assigns w+1, w+1+N, w+1+2N, ... A respawned worker
        // resumes from its predecessor's high-water mark so sids that may
        // still name on-disk snapshots are never re-issued.
        let mut next_session: u64 = (self.worker_id as u64 + 1)
            .max(self.vitals.next_session.load(Ordering::Acquire));
        let mut collector = MetricsCollector::new();
        let mut closed = false;

        while !((closed || stop()) && waiting.is_empty() && active.is_empty()) {
            // 1. Drain the channel (block briefly when idle). While work is
            // in flight the drain is CAPPED per loop iteration: a client
            // submitting faster than ops are handled must not keep this
            // loop spinning and starve the decode rounds below of their
            // turn (active sessions would stop emitting tokens entirely).
            // `max_waiting` ops per iteration is always enough to refill
            // the waiting queue to its bound; the rest stay in the channel
            // for the next iteration, at most one decode round away.
            let drain_cap = self.cfg.max_waiting.max(1);
            let mut drained = 0usize;
            loop {
                match if active.is_empty() && waiting.is_empty() && !closed {
                    rx.recv_timeout(self.cfg.idle_poll)
                        .map_err(|e| e == RecvTimeoutError::Disconnected)
                } else {
                    rx.try_recv()
                        .map_err(|e| e == std::sync::mpsc::TryRecvError::Disconnected)
                } {
                    Ok(op) => {
                        self.handle_op(op, &mut waiting, &mut active, &parked, &collector);
                        drained += 1;
                        if drained >= drain_cap && !(active.is_empty() && waiting.is_empty())
                        {
                            break;
                        }
                    }
                    Err(true) => {
                        closed = true;
                        break;
                    }
                    Err(false) => break,
                }
            }

            // 2. Admit a chunk: prefill fresh turns, resume appends.
            let room = self.cfg.max_active.saturating_sub(active.len());
            let n_admit = room.min(self.cfg.prefill_chunk).min(waiting.len());
            if n_admit > 0 {
                let batch: Vec<Request> = waiting.drain(..n_admit).collect();
                self.admit_batch(batch, &mut active, &mut parked, &mut collector);
            }

            // 2b. Retire turns already complete after admission
            // (`max_new <= 1`, or the prefill-sampled token hit `stop`)
            // before spending a decode step on them — a decode here would
            // overshoot the documented token budget by one.
            self.retire(&mut active, &mut parked, &mut next_session, &mut collector);
            // Publish vitals BEFORE the decode round: a panicking engine
            // step unwinds this loop's locals, and the supervisor accounts
            // `sessions_lost` from the last-published hot-parked gauge.
            self.publish_vitals(&parked, next_session);

            // 3. One decode step over the active set, grouped by graph.
            if !active.is_empty() {
                self.decode_round(&mut active, &mut collector);
            }

            // 4. Retire finished/failed/cancelled turns; bound the registry.
            self.retire(&mut active, &mut parked, &mut next_session, &mut collector);
            self.sweep_parked(&mut parked);
            self.publish_vitals(&parked, next_session);
        }
        if collector.n_requests() > 0 {
            let (p50, p99) = collector.latency();
            crate::log_info!(
                "coordinator drained: {} requests, latency p50 {p50:?} p99 {p99:?}, \
                 {:.1} tok/s, host bytes/session mean {:.0} peak {}",
                collector.n_requests(),
                collector.throughput(),
                collector.mean_host_bytes(),
                collector.peak_host_bytes()
            );
        } else {
            crate::log_info!("coordinator drained, shutting down");
        }
    }

    /// Mirror the loop's supervisor-visible state into the shared vitals.
    fn publish_vitals(&self, parked: &ParkedRegistry, next_session: u64) {
        self.vitals.hot_parked.store(parked.len(), Ordering::Release);
        self.vitals.next_session.store(next_session, Ordering::Release);
    }

    /// Apply one drained op to the scheduler state.
    fn handle_op(
        &self,
        op: Op,
        waiting: &mut VecDeque<Request>,
        active: &mut [Active],
        parked: &ParkedRegistry,
        collector: &MetricsCollector,
    ) {
        match op {
            Op::Submit(req) => {
                if waiting.len() >= self.cfg.max_waiting {
                    let err = WireError::new(
                        ErrorCode::Overloaded,
                        format!("queue full ({} waiting)", waiting.len()),
                    );
                    let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
                } else {
                    waiting.push_back(req);
                }
            }
            Op::Cancel { id, target, reply } => {
                let mut found = false;
                if let Some(pos) = waiting.iter().position(|r| r.id == target) {
                    // `pos` comes from `position` on the same deque, so
                    // `remove` cannot miss.
                    if let Some(r) = waiting.remove(pos) {
                        found = true;
                        let _ = r.reply.emit(ServeEvent::Done(Response::cancelled(r.id)));
                    }
                } else if let Some(a) = active.iter_mut().find(|a| a.req.id == target) {
                    a.cancelled = true;
                    found = true;
                }
                let _ = reply.emit(ServeEvent::CancelResult { id, target, found });
            }
            Op::Stats { id, reply } => {
                let (assembly_us_p50, assembly_us_p99) = collector.assembly_us();
                let assembly_samples = collector.assembly_samples();
                let (restore_us_p50, restore_us_p99) = collector.restore_us();
                let restore_samples = collector.restore_samples();
                let snapshot = StatsSnapshot {
                    active: active.len(),
                    waiting: waiting.len(),
                    parked_sessions: parked.len(),
                    parked_bytes: parked.hot_bytes(),
                    parked_cold_sessions: parked.cold_sessions(),
                    cold_bytes: parked.cold_bytes(),
                    cold_evictions: parked.cold_evictions(),
                    completed: collector.n_requests(),
                    generated_tokens: collector.generated_tokens(),
                    throughput_tps: collector.throughput(),
                    mean_host_bytes: collector.mean_host_bytes(),
                    peak_host_bytes: collector.peak_host_bytes(),
                    assembly_us_p50,
                    assembly_us_p99,
                    assembly_samples,
                    restore_us_p50,
                    restore_us_p99,
                    restore_samples,
                    promotions: collector.promotions(),
                    thrash_suppressed: collector.thrash_suppressed(),
                    pool: self.pool.stats(),
                    // Admission-side gauges are the scheduler's to fill in
                    // when it folds the broadcast answers; a worker cannot
                    // see ops still in flight toward it.
                    admitted_in_flight: 0,
                    qos_queued: 0,
                    shed_batch: 0,
                    shed_interactive: 0,
                    rate_limited: 0,
                    // Supervisor-side (restarts, losses) and server-side
                    // (dropped events) counters are injected downstream;
                    // recovered sessions are this worker's own knowledge.
                    worker_restarts: 0,
                    sessions_recovered: self
                        .vitals
                        .sessions_recovered
                        // lint: relaxed-ordering-audit-ok: monotonic counter snapshot
                        .load(Ordering::Relaxed),
                    sessions_lost: 0,
                    events_dropped: 0,
                    workers: vec![WorkerStats {
                        worker: self.worker_id,
                        active: active.len(),
                        waiting: waiting.len(),
                        parked_sessions: parked.len(),
                        parked_cold_sessions: parked.cold_sessions(),
                        cold_bytes: parked.cold_bytes(),
                        completed: collector.n_requests(),
                        generated_tokens: collector.generated_tokens(),
                        throughput_tps: collector.throughput(),
                        assembly_us_p50,
                        assembly_us_p99,
                        assembly_samples,
                        restore_us_p50,
                        restore_us_p99,
                        restore_samples,
                        promotions: collector.promotions(),
                        thrash_suppressed: collector.thrash_suppressed(),
                        admitted_in_flight: 0,
                    }],
                };
                let _ = reply.emit(ServeEvent::Stats { id, snapshot });
            }
        }
    }

    /// Remove finished, failed or cancelled turns from `active`, emitting
    /// each one's terminal event, recording metrics, and parking `keep`
    /// sessions in the registry.
    fn retire(
        &self,
        active: &mut Vec<Active>,
        parked: &mut ParkedRegistry,
        next_session: &mut u64,
        collector: &mut MetricsCollector,
    ) {
        let max_seq = self.engine.dims().max_seq;
        let mut i = 0;
        while let Some(candidate) = active.get(i) {
            if candidate.error.is_none() && !candidate.finished(max_seq) {
                i += 1;
                continue;
            }
            // swap_remove is the lane-friendly removal for the engine's
            // delta-assembly cache (lanes key on batch position): it
            // changes only the moved last element's rank — one full
            // rescatter per retire — where an order-preserving remove(i)
            // would shift EVERY later session down a lane and rescatter
            // them all.
            let a = active.swap_remove(i);
            let resp = match a.error {
                Some(err) => Response::error(a.req.id, err),
                None => {
                    let now = Instant::now();
                    // A turn cancelled mid-prompt-feed has produced nothing.
                    let tokens: Vec<i64> = if a.sess.tokens.len() >= a.sess.prompt_len {
                        a.sess.generated().to_vec()
                    } else {
                        Vec::new()
                    };
                    let occ = a.sess.cache.occupancy();
                    let promo = a.sess.cache.promotion_stats();
                    let metrics = RequestMetrics {
                        ttft: a
                            .first_token_at
                            .unwrap_or(now)
                            .duration_since(a.req.submitted_at),
                        latency: a.req.submitted_at.elapsed(),
                        prompt_tokens: a.turn_prompt,
                        generated_tokens: tokens.len(),
                        cache_pct: a.sess.cache.cache_size_pct(),
                        host_bytes: a.sess.cache.host_bytes(),
                        hi_slots: occ.hi_slots,
                        lo_slots: occ.lo_slots,
                        promotions: promo.promotions.saturating_sub(a.promo_base.promotions),
                        thrash_suppressed: promo
                            .thrash_suppressed
                            .saturating_sub(a.promo_base.thrash_suppressed),
                    };
                    // Cancelled partials stay out of the completed-turn
                    // stats (their ttft/latency would mix queue-abort noise
                    // into the serving percentiles); the Done event still
                    // carries this turn's own metrics.
                    if !a.cancelled {
                        collector.record(&metrics);
                    }
                    // Park for `append` when asked. A cancelled turn still
                    // parks when its cache sits at a clean token boundary;
                    // only a cancel that landed mid-prompt-feed (cache
                    // between turns) drops the session.
                    let session = if a.req.keep && a.pending_feed.is_empty() {
                        let sid = a.req.session.unwrap_or_else(|| {
                            let sid = *next_session;
                            *next_session += self.n_workers as u64;
                            sid
                        });
                        parked.insert(
                            sid,
                            Parked {
                                sess: a.sess,
                                parked_at: now,
                                spill: a.req.spec.spill.unwrap_or(true),
                            },
                        );
                        Some(sid)
                    } else {
                        None
                    };
                    Response {
                        id: a.req.id,
                        tokens,
                        metrics,
                        session,
                        cancelled: a.cancelled,
                        error: None,
                    }
                }
            };
            let _ = a.req.reply.emit(ServeEvent::Done(resp)); // receiver may be gone
        }
    }

    /// Admit a drained chunk: `append`s resume their parked session; the
    /// rest are validated, resolved and prefilled as one engine batch.
    fn admit_batch(
        &self,
        reqs: Vec<Request>,
        active: &mut Vec<Active>,
        parked: &mut ParkedRegistry,
        collector: &mut MetricsCollector,
    ) {
        let dims = self.engine.dims().clone();
        let mut sessions = Vec::new();
        let mut oks = Vec::new();
        for req in reqs {
            if req.session.is_some() {
                self.admit_append(req, active, parked, &dims, collector);
                continue;
            }
            // Validate per request BEFORE batching: one bad request must not
            // fail the engine's whole prefill chunk for its co-batched
            // neighbours.
            if req.prompt.is_empty() || req.prompt.len() > dims.max_seq {
                let err = WireError::bad_request(format!(
                    "prompt length {} invalid (must be 1..={})",
                    req.prompt.len(),
                    dims.max_seq
                ));
                let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
                continue;
            }
            // Resolve the compression spec to a cache mode only here, at
            // admission — parsing stayed policy-free.
            let mode = match req.spec.resolve(&dims) {
                Ok(m) => m,
                Err(err) => {
                    let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
                    continue;
                }
            };
            match Session::with_pool(req.id, &dims, mode, &self.pool) {
                Ok(s) => {
                    sessions.push(s);
                    oks.push(req);
                }
                Err(e) => {
                    let err = WireError::bad_request(e.to_string());
                    let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
                }
            }
        }
        if sessions.is_empty() {
            return;
        }
        let prompts: Vec<Vec<i64>> = oks.iter().map(|r| r.prompt.clone()).collect();
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        match self.engine.prefill(&mut refs, &prompts) {
            Ok(_) => {
                let now = Instant::now();
                for (req, sess) in oks.into_iter().zip(sessions) {
                    // Stream the prefill-sampled token as this turn's
                    // event 0.
                    let _ = req.reply.emit(ServeEvent::Token {
                        id: req.id,
                        index: 0,
                        token: sess.last_token,
                    });
                    active.push(Active {
                        generated_budget: req.max_new.max(1),
                        turn_prompt: req.prompt.len(),
                        promo_base: sess.cache.promotion_stats(),
                        req,
                        sess,
                        pending_feed: VecDeque::new(),
                        first_token_at: Some(now),
                        emitted: 1,
                        cancelled: false,
                        error: None,
                    });
                }
            }
            Err(e) => {
                crate::log_error!("prefill failed: {e}");
                for req in oks {
                    let err = WireError::internal(e.to_string());
                    let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
                }
            }
        }
    }

    /// Resume a parked session for an `append` turn. No engine prefill
    /// runs: the appended prompt tokens are queued and fed through the
    /// decode path one by one (each token's K/V and attention re-ingest
    /// into the session's existing hi/lo tiers), because their hidden
    /// states depend on the full cached context.
    fn admit_append(
        &self,
        req: Request,
        active: &mut Vec<Active>,
        parked: &mut ParkedRegistry,
        dims: &ModelDims,
        collector: &mut MetricsCollector,
    ) {
        let Some(sid) = req.session else {
            // The scheduler routes `append` ops here only with a session
            // id; answer a structured error rather than killing the worker
            // if that invariant is ever broken upstream.
            let err = WireError::internal("append admitted without a session id");
            let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
            return;
        };
        // Hot registry first, then the cold tier: a spilled session is
        // restored transparently — the client cannot tell it ever left
        // memory (beyond the restore latency the stats surface).
        let hot = parked.checkout(sid);
        let mut entry = match hot.map(Ok).or_else(|| {
            self.restore_from_cold(parked, sid, dims, collector).transpose()
        }) {
            Some(Ok(p)) => p,
            Some(Err(err)) => {
                let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
                return;
            }
            None => {
                // Distinguish "mid-turn, retry after done" from permanent
                // loss so clients don't abandon a live conversation.
                let err = if active.iter().any(|a| a.req.session == Some(sid)) {
                    WireError::new(
                        ErrorCode::SessionBusy,
                        format!("session {sid} is mid-turn; retry after its done event"),
                    )
                } else {
                    WireError::new(
                        ErrorCode::SessionNotFound,
                        format!("no live session {sid} (never kept, expired, or evicted)"),
                    )
                };
                let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
                return;
            }
        };
        if req.prompt.is_empty() {
            let _ = req.reply.emit(ServeEvent::Done(Response::error(
                req.id,
                WireError::bad_request("empty prompt"),
            )));
            parked.insert(sid, entry); // the session stays appendable
            return;
        }
        // Feeding re-ingests the previous turn's final token plus every
        // appended prompt token before the first new token can be sampled.
        let seq = entry.sess.cache.seq_len();
        if seq + 1 + req.prompt.len() > dims.max_seq {
            let err = WireError::new(
                ErrorCode::CacheFull,
                format!(
                    "session {sid} holds {seq} tokens; appending {} more \
                     exceeds max_seq {}",
                    req.prompt.len(),
                    dims.max_seq
                ),
            );
            let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
            parked.insert(sid, entry);
            return;
        }
        let pending: VecDeque<i64> = req.prompt.iter().copied().collect();
        // Everything past the appended prompt is this turn's generation.
        entry.sess.prompt_len = entry.sess.tokens.len() + pending.len();
        active.push(Active {
            generated_budget: req.max_new.max(1),
            turn_prompt: pending.len(),
            promo_base: entry.sess.cache.promotion_stats(),
            req,
            sess: entry.sess,
            pending_feed: pending,
            first_token_at: None,
            emitted: 0,
            cancelled: false,
            error: None,
        });
    }

    fn decode_round(&self, active: &mut [Active], collector: &mut MetricsCollector) {
        let max_seq = self.engine.dims().max_seq;
        // Group indices by (graph kind, oracle_k).
        let mut groups: BTreeMap<(String, i64), Vec<usize>> = BTreeMap::new();
        for (i, a) in active.iter_mut().enumerate() {
            if a.sess.cache.seq_len() >= max_seq {
                // Unreachable when admission bounds hold; never decode into
                // a full cache (a mid-feed overflow becomes a structured
                // error instead of a panic).
                if a.error.is_none() {
                    a.error = Some(WireError::new(
                        ErrorCode::CacheFull,
                        "cache filled during prompt re-ingest",
                    ));
                }
                continue;
            }
            let key = match a.sess.mode {
                CacheMode::Oracle { k } => ("decode_full".to_string(), k as i64),
                CacheMode::Full => ("decode_full".to_string(), -1),
                CacheMode::Mikv { .. } => ("decode_mikv".to_string(), 0),
            };
            groups.entry(key).or_default().push(i);
        }
        for (_, idxs) in groups {
            // A failed group is marked (not silently retried): the sessions
            // would otherwise stay active and be re-submitted to the same
            // failing graph every iteration — a livelock. The retire pass
            // replies with a structured error for each.
            let result = {
                let mut refs: Vec<&mut Session> = Vec::with_capacity(idxs.len());
                // SAFETY: idxs are unique indices into `active`; we create
                // non-overlapping &mut borrows, dropped before `active` is
                // touched again below.
                unsafe {
                    let base = active.as_mut_ptr();
                    for &i in &idxs {
                        refs.push(&mut (*base.add(i)).sess);
                    }
                }
                self.engine.decode_step(&mut refs)
            };
            match result {
                Ok(rows) => {
                    // Per-step host assembly cost → the stats snapshot's
                    // `assembly_us` percentiles. Only successful steps
                    // count: a failed step may bail before measuring and
                    // would re-record a stale sample.
                    if let Some(us) = self.engine.assembly_us_last() {
                        collector.record_assembly(Duration::from_secs_f64(us / 1e6));
                    }
                    let now = Instant::now();
                    for (&i, row) in idxs.iter().zip(rows.iter()) {
                        // `idxs` indexes the same `active` the batch was
                        // formed from; nothing retires mid-step.
                        let Some(a) = active.get_mut(i) else { continue };
                        if let Some(next) = a.pending_feed.pop_front() {
                            // Prompt re-ingest: these logits predate the
                            // full appended context — feed the next prompt
                            // token instead of sampling (skipping the
                            // O(vocab) argmax entirely).
                            a.sess.last_token = next;
                            a.sess.tokens.push(next);
                        } else {
                            let tok = sampler::greedy(row);
                            a.sess.last_token = tok;
                            a.sess.tokens.push(tok);
                            if a.first_token_at.is_none() {
                                a.first_token_at = Some(now);
                            }
                            let _ = a.req.reply.emit(ServeEvent::Token {
                                id: a.req.id,
                                index: a.emitted,
                                token: tok,
                            });
                            a.emitted += 1;
                        }
                    }
                }
                Err(e) => {
                    crate::log_error!("decode failed: {e}; retiring {} session(s)", idxs.len());
                    for &i in &idxs {
                        if let Some(a) = active.get_mut(i) {
                            a.error = Some(WireError::internal(e.to_string()));
                        }
                    }
                }
            }
        }
    }

    /// Enforce the parked-session registry bounds: demote sessions past
    /// the TTL, then demote oldest-parked while the total host footprint
    /// exceeds `max_session_bytes`. With a cold tier configured, a demoted
    /// session spills to its on-disk snapshot (and stays appendable);
    /// without one it is dropped — either way its cache blocks return to
    /// the shared pool and the registry's host bytes fall by its full
    /// footprint.
    fn sweep_parked(&self, parked: &mut ParkedRegistry) {
        if parked.is_empty() {
            return;
        }
        for sid in parked.expired(self.cfg.session_ttl) {
            self.demote_to_cold(parked, sid, "idle past TTL");
        }
        // The running total makes the pressure check O(1) per iteration;
        // each demotion removes the session's full footprint, so the loop
        // strictly descends.
        while !parked.is_empty() && parked.hot_bytes() > self.cfg.max_session_bytes {
            match parked.oldest() {
                Some(sid) => self.demote_to_cold(parked, sid, "host-bytes pressure"),
                None => break,
            }
        }
    }

    /// Move one parked session out of the hot registry: encode its
    /// snapshot into the cold tier when one is configured, else drop it.
    /// The session's pooled cache blocks are recycled in both cases. A
    /// spill failure (encode, bound, or IO) degrades to a drop — the
    /// historical behaviour — and is logged; it never takes the worker
    /// down.
    fn demote_to_cold(&self, parked: &mut ParkedRegistry, sid: u64, why: &str) {
        let Some(p) = parked.checkout(sid) else { return };
        if !p.spill {
            crate::log_debug!("session {sid} dropped ({why}; spill opted out)");
            return;
        }
        let Some(cold) = parked.cold.as_mut() else {
            crate::log_debug!("session {sid} dropped ({why}; no cold tier)");
            return;
        };
        match spill::encode_session(&p.sess) {
            Ok(frame) => match cold.put(sid, &frame) {
                Ok(true) => crate::log_debug!(
                    "session_spilled sid={sid} bytes={} reason=\"{why}\"",
                    frame.len()
                ),
                Ok(false) => crate::log_error!(
                    "session {sid} dropped: {} B snapshot exceeds the cold-tier bound",
                    frame.len()
                ),
                Err(e) => {
                    crate::log_error!("session {sid} dropped: cold-tier write failed: {e}")
                }
            },
            Err(e) => crate::log_error!("session {sid} dropped: snapshot encode failed: {e}"),
        }
        // `p` drops here, returning its blocks to the pool.
    }

    /// Restore a session from the cold tier for `append`. `Ok(None)` means
    /// "not in the cold tier" (including: no cold tier configured, or the
    /// snapshot failed validation — a corrupt snapshot is a *lost* session
    /// and reports `session_not_found`, never a worker panic). An IO error
    /// reading an indexed snapshot is `internal`: the session existed and
    /// the store, not the client, failed.
    fn restore_from_cold(
        &self,
        parked: &mut ParkedRegistry,
        sid: u64,
        dims: &ModelDims,
        collector: &mut MetricsCollector,
    ) -> Result<Option<Parked>, WireError> {
        let Some(cold) = parked.cold.as_mut() else {
            return Ok(None);
        };
        let frame = match cold.take(sid) {
            Ok(Some(f)) => f,
            Ok(None) => return Ok(None),
            Err(e) => {
                return Err(WireError::internal(format!(
                    "cold-tier read for session {sid} failed: {e}"
                )))
            }
        };
        let started = Instant::now();
        match spill::decode_session(&frame, dims, &self.pool) {
            Ok(sess) => {
                let took = started.elapsed();
                collector.record_restore(took);
                crate::log_debug!(
                    "session_restored sid={sid} bytes={} restore_us={}",
                    frame.len(),
                    took.as_micros()
                );
                Ok(Some(Parked {
                    sess,
                    parked_at: Instant::now(),
                    // It was spilled once already, so it may spill again.
                    spill: true,
                }))
            }
            Err(e) => {
                crate::log_error!("session {sid} cold snapshot rejected: {e}");
                Ok(None)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CompressionSpec, Reply};
    use crate::model::{SessionCache, StubEngine};
    use std::sync::mpsc;

    #[test]
    fn config_defaults_sane() {
        let c = CoordinatorConfig::default();
        assert!(c.max_active >= c.prefill_chunk);
        assert!(c.idle_poll > Duration::ZERO);
        assert!(c.max_waiting > 0);
        assert!(c.session_ttl > Duration::ZERO);
        assert!(c.max_session_bytes > 0);
        // The cold tier is opt-in: a default coordinator never touches
        // disk, and evicted parked sessions are dropped as before.
        assert!(c.cold_dir.is_none());
        assert!(c.max_cold_bytes > 0);
        // Fault injection is opt-in too: the default plan never fires.
        assert!(!c.faults.is_enabled());
    }

    fn test_dims() -> ModelDims {
        let mut d = StubEngine::test_dims(8);
        d.vocab = 16;
        d
    }

    fn stub(fail_decode: bool) -> StubEngine {
        let mut e = StubEngine::new(test_dims());
        e.fail_decode = fail_decode;
        e
    }

    fn request(id: u64, prompt_len: usize, max_new: usize, reply: Reply) -> Request {
        Request {
            id,
            prompt: vec![1; prompt_len],
            max_new,
            stop: None,
            spec: CompressionSpec::full(),
            session: None,
            keep: false,
            tenant: 0,
            priority: crate::coordinator::Priority::Interactive,
            submitted_at: Instant::now(),
            reply,
        }
    }

    fn sink(tx: &mpsc::Sender<ServeEvent>) -> Reply {
        Box::new(tx.clone())
    }

    /// Collect the terminal responses out of an event stream.
    fn dones(rx: mpsc::Receiver<ServeEvent>) -> Vec<Response> {
        rx.iter()
            .filter_map(|e| match e {
                ServeEvent::Done(r) => Some(r),
                _ => None,
            })
            .collect()
    }

    /// Regression: a decode failure must retire the group with a structured
    /// `internal` error instead of retrying it forever (the seed livelock).
    #[test]
    fn decode_failure_retires_sessions_with_error() {
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        tx.send(Op::Submit(request(7, 3, 4, sink(&reply_tx)))).unwrap();
        drop(tx);
        drop(reply_tx);

        // This call must terminate; before the fix it spun forever
        // re-submitting the failing group.
        Coordinator::new(stub(true), CoordinatorConfig::default()).run(rx);

        let resps = dones(reply_rx);
        assert_eq!(resps.len(), 1, "exactly one terminal response");
        assert_eq!(resps[0].id, 7);
        let err = resps[0].error.clone().expect("failure must surface");
        assert_eq!(err.code, ErrorCode::Internal);
        assert!(err.message.contains("injected decode failure"), "{err}");
    }

    /// `max_new = 1` is satisfied by the prefill-sampled token alone: the
    /// session must retire before any decode step. Proven with the failing
    /// engine — if a decode were attempted, the response would be an error.
    #[test]
    fn budget_of_one_retires_after_prefill_without_decoding() {
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        tx.send(Op::Submit(request(9, 3, 1, sink(&reply_tx)))).unwrap();
        drop(tx);
        drop(reply_tx);

        Coordinator::new(stub(true), CoordinatorConfig::default()).run(rx);

        let resps = dones(reply_rx);
        assert_eq!(resps.len(), 1);
        assert!(resps[0].error.is_none(), "no decode must run: {:?}", resps[0].error);
        assert_eq!(resps[0].tokens.len(), 1, "exactly the prefill token");
    }

    /// An oversized prompt is rejected per-request with `bad_request`;
    /// co-batched valid requests still complete (no chunk blast radius).
    #[test]
    fn oversized_prompt_does_not_fail_its_batch_neighbours() {
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        tx.send(Op::Submit(request(1, 9, 2, sink(&reply_tx)))).unwrap(); // > max_seq = 8
        tx.send(Op::Submit(request(2, 3, 2, sink(&reply_tx)))).unwrap();
        drop(tx);
        drop(reply_tx);

        Coordinator::new(stub(false), CoordinatorConfig::default()).run(rx);

        let mut resps = dones(reply_rx);
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        let err = resps[0].error.clone().expect("oversized prompt rejected");
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("prompt length 9"), "{err}");
        assert!(resps[1].error.is_none(), "neighbour must succeed");
        assert_eq!(resps[1].tokens.len(), 2);
    }

    /// Happy path: completed turns, plus token events streamed before the
    /// terminal `done` and matching its token list exactly.
    #[test]
    fn tokens_stream_in_order_before_done() {
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        tx.send(Op::Submit(request(4, 3, 3, sink(&reply_tx)))).unwrap();
        drop(tx);
        drop(reply_tx);

        Coordinator::new(stub(false), CoordinatorConfig::default()).run(rx);

        let events: Vec<ServeEvent> = reply_rx.iter().collect();
        let mut streamed = Vec::new();
        let mut done: Option<Response> = None;
        for ev in events {
            match ev {
                ServeEvent::Token { id, index, token } => {
                    assert_eq!(id, 4);
                    assert!(done.is_none(), "token after done");
                    assert_eq!(index, streamed.len(), "indices are contiguous");
                    streamed.push(token);
                }
                ServeEvent::Done(r) => done = Some(r),
                other => panic!("unexpected event {other:?}"),
            }
        }
        let done = done.expect("terminal event");
        assert!(done.error.is_none());
        assert_eq!(streamed.len(), 3);
        assert_eq!(done.tokens, streamed, "done tokens == streamed tokens");
        assert!(done.metrics.host_bytes > 0);
    }

    /// Regression for the retire off-by-one: with max_seq = 8 and a 5-token
    /// prompt, decoding may legally fill slots 5, 6 AND 7 — the session
    /// retires at seq_len == 8, not one token early.
    #[test]
    fn last_cache_slot_is_usable() {
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        // budget far above what the cache allows → cache capacity binds
        tx.send(Op::Submit(request(1, 5, 100, sink(&reply_tx)))).unwrap();
        drop(tx);
        drop(reply_tx);

        Coordinator::new(stub(false), CoordinatorConfig::default()).run(rx);

        let resps = dones(reply_rx);
        assert_eq!(resps.len(), 1);
        assert!(resps[0].error.is_none());
        // prefill contributes 1 token; decodes fill slots 5..8 → 3 more.
        assert_eq!(
            resps[0].tokens.len(),
            4,
            "the last legal slot must be used (seed retired one token early)"
        );
    }

    /// Submits beyond `max_waiting` are rejected with `overloaded` while
    /// queued neighbours still complete.
    #[test]
    fn queue_bound_rejects_with_overloaded() {
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        for id in 0..3u64 {
            tx.send(Op::Submit(request(id, 3, 2, sink(&reply_tx)))).unwrap();
        }
        drop(tx);
        drop(reply_tx);

        let cfg = CoordinatorConfig {
            max_waiting: 1,
            ..CoordinatorConfig::default()
        };
        Coordinator::new(stub(false), cfg).run(rx);

        let resps = dones(reply_rx);
        assert_eq!(resps.len(), 3);
        let overloaded = resps
            .iter()
            .filter(|r| {
                r.error
                    .as_ref()
                    .map(|e| e.code == ErrorCode::Overloaded)
                    .unwrap_or(false)
            })
            .count();
        let ok = resps.iter().filter(|r| r.error.is_none()).count();
        assert_eq!(overloaded, 2, "all drained past the bound are rejected");
        assert_eq!(ok, 1);
    }

    /// Regression for the drain-loop starvation bug: step 1 of `run_until`
    /// used to drain the op channel until it was EMPTY while work was in
    /// flight, so a client flooding ops faster than `handle_op` processes
    /// them kept the loop spinning and the active turn frozen mid-stream
    /// (no decode rounds → no tokens → no terminal event). With the
    /// per-iteration drain cap, one decode round is guaranteed between
    /// bounded drains, so the already-active turn below completes no
    /// matter how hard the flooders hammer the channel.
    #[test]
    fn flooding_submitter_does_not_stall_active_turn() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        let mut dims = StubEngine::test_dims(64);
        dims.vocab = 16;
        let mut engine = StubEngine::new(dims);
        // Each decode step takes ~1ms, holding the turn active long enough
        // for the flood to saturate the channel while it streams.
        engine.decode_delay = Duration::from_millis(1);
        let cfg = CoordinatorConfig {
            max_active: 4,
            max_waiting: 4,
            ..CoordinatorConfig::default()
        };
        let coordinator = Coordinator::new(engine, cfg);
        let (tx, rx) = mpsc::channel::<Op>();
        let stop_flood = Arc::new(AtomicBool::new(false));

        let driver = std::thread::spawn({
            let stop_flood = stop_flood.clone();
            move || {
                let (etx, erx) = mpsc::channel::<ServeEvent>();
                tx.send(Op::Submit(request(1, 3, 10, sink(&etx)))).unwrap();
                // First token proves the turn is admitted and decoding
                // BEFORE the flood begins — progress from here on is what
                // the drain cap must protect.
                loop {
                    match erx.recv_timeout(Duration::from_secs(10)) {
                        Ok(ServeEvent::Token { .. }) => break,
                        Ok(_) => {}
                        Err(e) => panic!("no first token: {e:?}"),
                    }
                }
                // Flooders hammer the channel with cheap ops (unknown-target
                // cancels: handled in O(active+waiting), never admitted, so
                // the post-test drain stays fast) until the turn completes.
                // The send cap is a safety valve bounding memory if the
                // starvation bug ever regresses.
                let mut floods = Vec::new();
                for _ in 0..3 {
                    let tx = tx.clone();
                    let stop_flood = stop_flood.clone();
                    floods.push(std::thread::spawn(move || {
                        let (ftx, frx) = mpsc::channel::<ServeEvent>();
                        drop(frx);
                        let mut sent = 0u64;
                        while !stop_flood.load(Ordering::Acquire) && sent < 2_000_000 {
                            sent += 1;
                            if tx
                                .send(Op::Cancel {
                                    id: u64::MAX - sent,
                                    target: u64::MAX - sent,
                                    reply: Box::new(ftx.clone()),
                                })
                                .is_err()
                            {
                                break;
                            }
                        }
                    }));
                }
                let done = loop {
                    match erx.recv_timeout(Duration::from_secs(30)) {
                        Ok(ServeEvent::Done(r)) => break r,
                        Ok(_) => {}
                        Err(e) => {
                            stop_flood.store(true, Ordering::Release);
                            panic!("active turn starved under op flood: {e:?}");
                        }
                    }
                };
                stop_flood.store(true, Ordering::Release);
                for f in floods {
                    f.join().unwrap();
                }
                assert!(done.error.is_none(), "{:?}", done.error);
                assert_eq!(done.tokens.len(), 10, "full token budget delivered");
                drop(tx);
            }
        });
        coordinator.run(rx);
        driver.join().unwrap();
    }

    /// Cancelling a waiting request is deterministic: it never runs, its
    /// terminal `done` carries `cancelled: true`, and the cancel op is
    /// answered with `found: true`.
    #[test]
    fn cancel_waiting_request_before_admission() {
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        let (cancel_tx, cancel_rx) = mpsc::channel::<ServeEvent>();
        tx.send(Op::Submit(request(1, 3, 2, sink(&reply_tx)))).unwrap();
        tx.send(Op::Cancel {
            id: 2,
            target: 1,
            reply: Box::new(cancel_tx.clone()),
        })
        .unwrap();
        // A cancel for an unknown id answers found: false.
        tx.send(Op::Cancel {
            id: 3,
            target: 99,
            reply: Box::new(cancel_tx.clone()),
        })
        .unwrap();
        drop(tx);
        drop(reply_tx);
        drop(cancel_tx);

        Coordinator::new(stub(false), CoordinatorConfig::default()).run(rx);

        let resps = dones(reply_rx);
        assert_eq!(resps.len(), 1);
        assert!(resps[0].cancelled);
        assert!(resps[0].error.is_none());
        assert!(resps[0].tokens.is_empty());
        let answers: Vec<ServeEvent> = cancel_rx.iter().collect();
        assert_eq!(answers.len(), 2);
        match &answers[0] {
            ServeEvent::CancelResult { id, target, found } => {
                assert_eq!((*id, *target, *found), (2, 1, true));
            }
            other => panic!("unexpected {other:?}"),
        }
        match &answers[1] {
            ServeEvent::CancelResult { found, .. } => assert!(!found),
            other => panic!("unexpected {other:?}"),
        }
    }

    /// The multi-turn acceptance path at the channel level: a kept
    /// `generate` parks its session; a follow-up `append` resumes the SAME
    /// cache — tier occupancy carries over and grows, and each turn
    /// reports its own host bytes.
    #[test]
    fn generate_then_append_reuses_the_parked_cache() {
        let dims = StubEngine::test_dims(64);
        let engine = StubEngine::new(dims);
        let (tx, rx) = mpsc::channel::<Op>();
        let coordinator = Coordinator::new(engine, CoordinatorConfig::default());

        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            let mikv = CompressionSpec::mikv(0.5, "int4");
            tx.send(Op::Submit(Request {
                id: 1,
                prompt: vec![1, 2, 3],
                max_new: 4,
                stop: None,
                spec: mikv.clone(),
                session: None,
                keep: true,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn1 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            assert!(turn1.error.is_none(), "{:?}", turn1.error);
            let sid = turn1.session.expect("keep=true parks the session");
            assert_eq!(turn1.tokens.len(), 4);
            let occ1 = turn1.metrics.hi_slots + turn1.metrics.lo_slots;
            // prompt 3 + 3 decoded KV appends = 6 slots × 4 planes
            assert_eq!(occ1, 24);
            assert!(turn1.metrics.host_bytes > 0);

            tx.send(Op::Submit(Request {
                id: 2,
                prompt: vec![4, 5],
                max_new: 3,
                stop: None,
                spec: mikv,
                session: Some(sid),
                keep: true,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn2 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            assert!(turn2.error.is_none(), "{:?}", turn2.error);
            assert_eq!(turn2.session, Some(sid), "same session id across turns");
            assert_eq!(turn2.metrics.prompt_tokens, 2, "per-turn prompt size");
            assert_eq!(turn2.tokens.len(), 3);
            let occ2 = turn2.metrics.hi_slots + turn2.metrics.lo_slots;
            // turn1's 6 slots + fed last token + 2 appended prompt tokens
            // + 2 decoded KV appends = 11 slots × 4 planes
            assert_eq!(occ2, 44, "occupancy carried over and grew");
            assert!(turn2.metrics.host_bytes >= turn1.metrics.host_bytes);
            drop(tx);
        });

        coordinator.run(rx);
        driver.join().unwrap();
    }

    /// TTL bound: with a zero TTL the parked session is dropped on the next
    /// sweep and a follow-up `append` gets `session_not_found`; the
    /// session's pooled blocks are recycled.
    #[test]
    fn expired_sessions_are_evicted_and_append_fails_cleanly() {
        let engine = StubEngine::new(StubEngine::test_dims(32));
        let (tx, rx) = mpsc::channel::<Op>();
        let cfg = CoordinatorConfig {
            session_ttl: Duration::ZERO,
            ..CoordinatorConfig::default()
        };
        let coordinator = Coordinator::new(engine, cfg);

        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(Op::Submit(Request {
                id: 1,
                prompt: vec![1, 2, 3],
                max_new: 2,
                stop: None,
                spec: CompressionSpec::mikv(0.5, "int4"),
                session: None,
                keep: true,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn1 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            let sid = turn1.session.expect("parked before the sweep runs");

            tx.send(Op::Submit(Request {
                id: 2,
                prompt: vec![4],
                max_new: 2,
                stop: None,
                spec: CompressionSpec::full(),
                session: Some(sid),
                keep: false,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn2 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            let err = turn2.error.expect("expired session must be gone");
            assert_eq!(err.code, ErrorCode::SessionNotFound);
            drop(tx);
        });

        coordinator.run(rx);
        // The evicted session's shadow blocks went back to the pool.
        let stats = coordinator.pool().stats();
        assert_eq!(stats.outstanding_blocks, 0, "{stats:?}");
        driver.join().unwrap();
    }

    /// Footprint bound: with a zero byte budget nothing stays parked.
    #[test]
    fn footprint_bound_evicts_parked_sessions() {
        let engine = StubEngine::new(StubEngine::test_dims(32));
        let (tx, rx) = mpsc::channel::<Op>();
        let cfg = CoordinatorConfig {
            max_session_bytes: 0,
            ..CoordinatorConfig::default()
        };
        let coordinator = Coordinator::new(engine, cfg);

        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(Op::Submit(Request {
                id: 1,
                prompt: vec![1, 2],
                max_new: 2,
                stop: None,
                spec: CompressionSpec::mikv(0.5, "int4"),
                session: None,
                keep: true,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn1 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            let sid = turn1.session.expect("parked momentarily");
            tx.send(Op::Submit(Request {
                id: 2,
                prompt: vec![3],
                max_new: 1,
                stop: None,
                spec: CompressionSpec::full(),
                session: Some(sid),
                keep: false,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn2 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            let err = turn2.error.expect("evicted by the byte bound");
            assert_eq!(err.code, ErrorCode::SessionNotFound);
            drop(tx);
        });

        coordinator.run(rx);
        driver.join().unwrap();
    }

    /// An append racing a still-active turn on the same session gets the
    /// retryable `session_busy`, not the terminal `session_not_found`.
    #[test]
    fn append_to_checked_out_session_reports_busy() {
        let c = Coordinator::new(stub(false), CoordinatorConfig::default());
        let dims = test_dims();
        let mut parked = ParkedRegistry::new(None);
        let mut collector = MetricsCollector::new();
        let mut active: Vec<Active> = Vec::new();
        let (etx, _erx) = mpsc::channel::<ServeEvent>();
        let mut holder = request(1, 2, 4, Box::new(etx));
        holder.session = Some(5); // an in-flight append turn on session 5
        active.push(Active {
            sess: Session::new(1, &dims, CacheMode::Full).unwrap(),
            pending_feed: VecDeque::new(),
            turn_prompt: 2,
            first_token_at: None,
            promo_base: PromotionStats::default(),
            emitted: 0,
            generated_budget: 4,
            cancelled: false,
            error: None,
            req: holder,
        });

        let (etx2, erx2) = mpsc::channel::<ServeEvent>();
        let mut req = request(2, 1, 2, Box::new(etx2));
        req.session = Some(5);
        c.admit_append(req, &mut active, &mut parked, &dims, &mut collector);
        match erx2.recv().unwrap() {
            ServeEvent::Done(r) => {
                assert_eq!(r.error.unwrap().code, ErrorCode::SessionBusy);
            }
            other => panic!("unexpected {other:?}"),
        }

        // an unknown sid still reports session_not_found
        let (etx3, erx3) = mpsc::channel::<ServeEvent>();
        let mut req = request(3, 1, 2, Box::new(etx3));
        req.session = Some(6);
        c.admit_append(req, &mut active, &mut parked, &dims, &mut collector);
        match erx3.recv().unwrap() {
            ServeEvent::Done(r) => {
                assert_eq!(r.error.unwrap().code, ErrorCode::SessionNotFound);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    /// A worker in a sharded runtime assigns session ids from its own
    /// stride — `(sid - 1) % n_workers == worker_id` — so the scheduler
    /// can route `append` ops to the owner without shared state.
    #[test]
    fn session_ids_are_strided_by_worker() {
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        for id in 0..3u64 {
            let mut req = request(id, 2, 2, sink(&reply_tx));
            req.keep = true;
            tx.send(Op::Submit(req)).unwrap();
        }
        drop(tx);
        drop(reply_tx);

        // worker 1 of 3 → sids 2, 5, 8
        Coordinator::for_worker(stub(false), CoordinatorConfig::default(), 1, 3).run(rx);

        let mut sids: Vec<u64> = dones(reply_rx)
            .into_iter()
            .map(|r| r.session.expect("keep parks a session"))
            .collect();
        sids.sort_unstable();
        assert_eq!(sids, vec![2, 5, 8]);
        for sid in sids {
            assert_eq!((sid - 1) % 3, 1, "owner encoding holds for {sid}");
        }
    }

    /// The worker's stats snapshot carries its own per-worker row.
    #[test]
    fn stats_snapshot_reports_worker_row() {
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        tx.send(Op::Submit(request(1, 3, 2, sink(&reply_tx)))).unwrap();
        tx.send(Op::Stats {
            id: 9,
            reply: sink(&reply_tx),
        })
        .unwrap();
        drop(tx);
        drop(reply_tx);

        Coordinator::for_worker(stub(false), CoordinatorConfig::default(), 2, 4).run(rx);

        let snapshot = reply_rx
            .iter()
            .find_map(|e| match e {
                ServeEvent::Stats { snapshot, .. } => Some(snapshot),
                _ => None,
            })
            .expect("stats answered");
        assert_eq!(snapshot.workers.len(), 1);
        assert_eq!(snapshot.workers[0].worker, 2);
        assert_eq!(snapshot.workers[0].completed, snapshot.completed);
    }

    /// Direct unit check of the retire predicate.
    #[test]
    fn finished_uses_the_full_cache_capacity() {
        let dims = test_dims();
        let (reply_tx, _reply_rx) = mpsc::channel::<ServeEvent>();
        let mut sess = Session::new(1, &dims, CacheMode::Full).unwrap();
        let planes = dims.planes();
        let t = 7; // one below max_seq = 8
        let kv = vec![0.0f32; planes * t * dims.d_head];
        match &mut sess.cache {
            SessionCache::Full(f) => f.ingest_prefill(t, &kv, &kv),
            _ => unreachable!(),
        }
        sess.prompt_len = t;
        sess.tokens = vec![1; t + 1];
        sess.last_token = 1;
        let mut a = Active {
            req: request(1, t, 100, Box::new(reply_tx)),
            sess,
            pending_feed: VecDeque::new(),
            turn_prompt: t,
            first_token_at: Some(Instant::now()),
            promo_base: PromotionStats::default(),
            emitted: 1,
            generated_budget: 100,
            cancelled: false,
            error: None,
        };
        assert!(
            !a.finished(dims.max_seq),
            "seq_len = 7 of 8: one decode still fits"
        );
        let kv1 = vec![0.0f32; planes * dims.d_head];
        match &mut a.sess.cache {
            SessionCache::Full(f) => f.append(&kv1, &kv1),
            _ => unreachable!(),
        }
        assert!(a.finished(dims.max_seq), "seq_len = 8 of 8: full");
        // a pending prompt feed always defers retirement
        a.pending_feed.push_back(9);
        assert!(!a.finished(dims.max_seq));
    }

    /// Unique per-test cold-tier root under the OS temp dir.
    fn tmp_cold_root(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "mikv-batcher-cold-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    /// S2: the registry's running host-bytes total tracks park/checkout
    /// exactly (the `hot_bytes()` accessor itself debug-asserts the total
    /// against a full recompute, so calling it is the check).
    #[test]
    fn parked_registry_running_total_matches_recompute() {
        let dims = StubEngine::test_dims(32);
        let mut reg = ParkedRegistry::new(None);
        assert_eq!(reg.hot_bytes(), 0);
        let mut sizes = Vec::new();
        for sid in 1..=3u64 {
            let sess = Session::new(sid, &dims, CacheMode::Full).unwrap();
            sizes.push(sess.cache.host_bytes());
            reg.insert(
                sid,
                Parked {
                    sess,
                    parked_at: Instant::now(),
                    spill: true,
                },
            );
        }
        assert_eq!(reg.hot_bytes(), sizes.iter().sum::<usize>());
        let p = reg.checkout(2).expect("parked");
        assert_eq!(
            reg.hot_bytes(),
            sizes.iter().sum::<usize>() - p.sess.cache.host_bytes()
        );
        // re-park and double-insert: the defensive replace path keeps the
        // total honest rather than double-counting
        let b = p.sess.cache.host_bytes();
        reg.insert(
            2,
            Parked {
                sess: p.sess,
                parked_at: Instant::now(),
                spill: true,
            },
        );
        let extra = Session::new(9, &dims, CacheMode::Full).unwrap();
        let eb = extra.cache.host_bytes();
        reg.insert(
            2,
            Parked {
                sess: extra,
                parked_at: Instant::now(),
                spill: true,
            },
        );
        let _ = b;
        assert_eq!(
            reg.hot_bytes(),
            sizes.iter().sum::<usize>() - sizes[1] + eb
        );
        assert_eq!(reg.len(), 3);
    }

    /// The cold-tier acceptance path: with a zero TTL the kept session is
    /// spilled to disk on the first sweep, and a follow-up `append`
    /// restores it transparently — same session id, occupancy carried over
    /// and grown by EXACTLY the amounts the never-spilled multi-turn test
    /// observes, and the restore surfaced in the stats snapshot.
    #[test]
    fn ttl_spill_then_append_restores_the_same_cache() {
        let root = tmp_cold_root("ttl-restore");
        let engine = StubEngine::new(StubEngine::test_dims(64));
        let (tx, rx) = mpsc::channel::<Op>();
        let cfg = CoordinatorConfig {
            session_ttl: Duration::ZERO,
            cold_dir: Some(root.clone()),
            ..CoordinatorConfig::default()
        };
        let coordinator = Coordinator::new(engine, cfg);

        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            let mikv = CompressionSpec::mikv(0.5, "int4");
            tx.send(Op::Submit(Request {
                id: 1,
                prompt: vec![1, 2, 3],
                max_new: 4,
                stop: None,
                spec: mikv.clone(),
                session: None,
                keep: true,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn1 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            assert!(turn1.error.is_none(), "{:?}", turn1.error);
            let sid = turn1.session.expect("keep=true parks the session");
            assert_eq!(turn1.tokens.len(), 4);
            assert_eq!(turn1.metrics.hi_slots + turn1.metrics.lo_slots, 24);

            // By the time `done` was emitted + one sweep, the zero TTL has
            // demoted the session to the cold tier. The append must not
            // care.
            tx.send(Op::Submit(Request {
                id: 2,
                prompt: vec![4, 5],
                max_new: 3,
                stop: None,
                spec: mikv,
                session: Some(sid),
                keep: false,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn2 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            assert!(turn2.error.is_none(), "restored append failed: {:?}", turn2.error);
            assert_eq!(turn2.tokens.len(), 3);
            // identical occupancy growth to the never-spilled multi-turn
            // test: 6 carried slots + 1 fed + 2 appended + 2 decoded, × 4
            // planes
            assert_eq!(
                turn2.metrics.hi_slots + turn2.metrics.lo_slots,
                44,
                "restored cache must carry the exact tier occupancy"
            );

            tx.send(Op::Stats {
                id: 9,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            let snap = loop {
                if let ServeEvent::Stats { snapshot, .. } = erx.recv().unwrap() {
                    break snapshot;
                }
            };
            assert_eq!(snap.restore_samples, 1, "one cold restore happened");
            assert!(snap.restore_us_p50 > 0.0);
            assert_eq!(
                snap.parked_cold_sessions, 0,
                "restore takes the snapshot out of the cold tier"
            );
            drop(tx);
        });

        coordinator.run(rx);
        // Nothing leaked: the spilled-then-restored session's blocks all
        // went back to the pool when the keep=false turn retired.
        let stats = coordinator.pool().stats();
        assert_eq!(stats.outstanding_blocks, 0, "{stats:?}");
        driver.join().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    /// Host-bytes pressure demotes to cold instead of dropping: the hot
    /// registry's footprint reads ~0 in `stats` while the snapshot bytes
    /// show up under the cold-tier counters.
    #[test]
    fn pressure_spill_zeroes_hot_registry_bytes_in_stats() {
        let root = tmp_cold_root("pressure");
        let engine = StubEngine::new(StubEngine::test_dims(32));
        let (tx, rx) = mpsc::channel::<Op>();
        let cfg = CoordinatorConfig {
            max_session_bytes: 0,
            cold_dir: Some(root.clone()),
            ..CoordinatorConfig::default()
        };
        let coordinator = Coordinator::new(engine, cfg);

        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(Op::Submit(Request {
                id: 1,
                prompt: vec![1, 2],
                max_new: 2,
                stop: None,
                spec: CompressionSpec::mikv(0.5, "int4"),
                session: None,
                keep: true,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn1 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            let sid = turn1.session.expect("parked momentarily");
            assert!(turn1.metrics.host_bytes > 0);

            tx.send(Op::Stats {
                id: 8,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            let snap = loop {
                if let ServeEvent::Stats { snapshot, .. } = erx.recv().unwrap() {
                    break snapshot;
                }
            };
            assert_eq!(snap.parked_sessions, 0, "hot registry drained");
            assert_eq!(snap.parked_bytes, 0, "spilled session pins no host bytes");
            assert_eq!(snap.parked_cold_sessions, 1);
            assert!(snap.cold_bytes > 0, "snapshot accounted on disk");
            assert_eq!(snap.workers.len(), 1);
            assert_eq!(snap.workers[0].parked_cold_sessions, 1);

            // ... and the session is still appendable from disk.
            tx.send(Op::Submit(Request {
                id: 2,
                prompt: vec![3],
                max_new: 1,
                stop: None,
                spec: CompressionSpec::full(),
                session: Some(sid),
                keep: false,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn2 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            assert!(turn2.error.is_none(), "{:?}", turn2.error);
            drop(tx);
        });

        coordinator.run(rx);
        driver.join().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    /// A corrupted on-disk snapshot is a cleanly lost session: the append
    /// gets `session_not_found` (the codec rejected the frame), never a
    /// panic or a poisoned cache.
    #[test]
    fn corrupt_cold_snapshot_yields_session_not_found() {
        let root = tmp_cold_root("corrupt");
        let engine = StubEngine::new(StubEngine::test_dims(32));
        let (tx, rx) = mpsc::channel::<Op>();
        let cfg = CoordinatorConfig {
            session_ttl: Duration::ZERO,
            cold_dir: Some(root.clone()),
            ..CoordinatorConfig::default()
        };
        let coordinator = Coordinator::new(engine, cfg);

        let root2 = root.clone();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(Op::Submit(Request {
                id: 1,
                prompt: vec![1, 2, 3],
                max_new: 2,
                stop: None,
                spec: CompressionSpec::mikv(0.5, "int4"),
                session: None,
                keep: true,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn1 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            let sid = turn1.session.expect("kept");

            // The spill runs on the sweep right after retirement; wait for
            // the snapshot file, then clobber it.
            let snap_path = root2.join("worker-0").join(format!("{sid}.snap"));
            let deadline = Instant::now() + Duration::from_secs(10);
            while !snap_path.exists() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
            }
            assert!(snap_path.exists(), "session never spilled");
            std::fs::write(&snap_path, b"not a snapshot").unwrap();

            tx.send(Op::Submit(Request {
                id: 2,
                prompt: vec![4],
                max_new: 1,
                stop: None,
                spec: CompressionSpec::full(),
                session: Some(sid),
                keep: false,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn2 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            let err = turn2.error.expect("corrupt snapshot must fail the append");
            assert_eq!(err.code, ErrorCode::SessionNotFound);
            drop(tx);
        });

        coordinator.run(rx);
        let stats = coordinator.pool().stats();
        assert_eq!(stats.outstanding_blocks, 0, "{stats:?}");
        driver.join().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    /// `compression.spill=false` opts a kept session out of the cold tier:
    /// eviction drops it (the pre-cold-tier contract) and no snapshot file
    /// is ever written, so its KV state never touches disk.
    #[test]
    fn spill_opt_out_drops_instead_of_spilling() {
        let root = tmp_cold_root("opt-out");
        let engine = StubEngine::new(StubEngine::test_dims(32));
        let (tx, rx) = mpsc::channel::<Op>();
        let cfg = CoordinatorConfig {
            session_ttl: Duration::ZERO,
            cold_dir: Some(root.clone()),
            ..CoordinatorConfig::default()
        };
        let coordinator = Coordinator::new(engine, cfg);

        let root2 = root.clone();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(Op::Submit(Request {
                id: 1,
                prompt: vec![1, 2, 3],
                max_new: 2,
                stop: None,
                spec: CompressionSpec::mikv(0.5, "int4").no_spill(),
                session: None,
                keep: true,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn1 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            assert!(turn1.error.is_none(), "{:?}", turn1.error);
            let sid = turn1.session.expect("kept");

            // Force a sweep (and prove the session is gone) by appending:
            // the zero TTL evicted it, and the opt-out means it was
            // dropped rather than demoted, so the append cannot restore.
            tx.send(Op::Submit(Request {
                id: 2,
                prompt: vec![4],
                max_new: 1,
                stop: None,
                spec: CompressionSpec::full(),
                session: Some(sid),
                keep: false,
                tenant: 0,
                priority: crate::coordinator::Priority::Interactive,
                submitted_at: Instant::now(),
                reply: Box::new(etx.clone()),
            }))
            .unwrap();
            let turn2 = loop {
                if let ServeEvent::Done(r) = erx.recv().unwrap() {
                    break r;
                }
            };
            let err = turn2.error.expect("dropped session must not restore");
            assert_eq!(err.code, ErrorCode::SessionNotFound);
            assert!(
                !root2.join("worker-0").join(format!("{sid}.snap")).exists(),
                "opted-out session must never be written to disk"
            );
            drop(tx);
        });

        coordinator.run(rx);
        let stats = coordinator.pool().stats();
        assert_eq!(stats.outstanding_blocks, 0, "{stats:?}");
        driver.join().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }

    /// The respawn contract at the coordinator level: a second coordinator
    /// sharing the first one's vitals (recovery flag set) adopts the
    /// predecessor's cold-tier snapshots — the old session stays appendable
    /// under its old sid — and resumes the sid allocator past the old
    /// high-water mark instead of re-issuing used ids.
    #[test]
    fn respawn_adopts_cold_sessions_and_resumes_sid_stride() {
        let root = tmp_cold_root("respawn");
        let vitals = Arc::new(WorkerVitals::default());
        let cfg = CoordinatorConfig {
            session_ttl: Duration::ZERO, // spill on the first sweep
            cold_dir: Some(root.clone()),
            ..CoordinatorConfig::default()
        };

        // Life 1: keep one session; the zero TTL spills it to disk.
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        let mut req = request(1, 3, 2, sink(&reply_tx));
        req.keep = true;
        req.spec = CompressionSpec::mikv(0.5, "int4");
        tx.send(Op::Submit(req)).unwrap();
        drop(tx);
        drop(reply_tx);
        let c1 = Coordinator::new(StubEngine::new(StubEngine::test_dims(64)), cfg.clone())
            .with_vitals(vitals.clone());
        c1.run(rx);
        let sid = dones(reply_rx)
            .pop()
            .and_then(|r| r.session)
            .expect("turn 1 parked a session");
        assert_eq!(sid, 1);
        assert!(
            vitals.next_session.load(Ordering::Acquire) > sid,
            "high-water mark published"
        );

        // Life 2: same vitals, recovery flagged (as the supervisor would).
        vitals.recover.store(true, Ordering::Release);
        let (tx, rx) = mpsc::channel::<Op>();
        let (reply_tx, reply_rx) = mpsc::channel::<ServeEvent>();
        let mut back = request(2, 1, 1, sink(&reply_tx));
        back.session = Some(sid);
        tx.send(Op::Submit(back)).unwrap();
        let mut fresh = request(3, 2, 1, sink(&reply_tx));
        fresh.keep = true;
        tx.send(Op::Submit(fresh)).unwrap();
        drop(tx);
        drop(reply_tx);
        let c2 = Coordinator::new(StubEngine::new(StubEngine::test_dims(64)), cfg)
            .with_vitals(vitals.clone());
        c2.run(rx);

        let mut resps = dones(reply_rx);
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        assert!(
            resps[0].error.is_none(),
            "append must restore the adopted snapshot: {:?}",
            resps[0].error
        );
        // lint: relaxed-ordering-audit-ok: test-only read after join
        assert_eq!(vitals.sessions_recovered.load(Ordering::Relaxed), 1);
        let new_sid = resps[1].session.expect("fresh keep parks");
        assert!(new_sid > sid, "sid {new_sid} must not collide with life 1");
        let _ = std::fs::remove_dir_all(&root);
    }
}
