//! The continuous-batching coordinator loop.
//!
//! Runs on the engine thread (PJRT handles are not `Send`). Each scheduler
//! iteration:
//!
//! 1. drains newly arrived requests into the waiting queue (FCFS);
//! 2. admits waiting requests up to `max_active` and prefills them in
//!    chunks of the compiled prefill batch sizes;
//! 3. forms decode batches from the active set, grouped by graph kind
//!    (MiKV-cache sessions vs full/oracle-cache sessions — different
//!    executables) and, within the oracle group, by `oracle_k`;
//! 4. retires finished sessions (budget reached / stop token / cache full)
//!    and replies on each request's channel.
//!
//! Short requests are never stuck behind long ones: batches are re-formed
//! every step from whatever is active (the "continuous" in continuous
//! batching, per Orca/vLLM).

use super::request::{Request, RequestMetrics, Response};
use crate::model::{sampler, CacheMode, Engine, Session};
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Coordinator tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Maximum sessions decoding concurrently.
    pub max_active: usize,
    /// Maximum requests prefilled per scheduler iteration.
    pub prefill_chunk: usize,
    /// Channel poll timeout when idle.
    pub idle_poll: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            max_active: 8,
            prefill_chunk: 4,
            idle_poll: Duration::from_millis(20),
        }
    }
}

struct Active {
    req: Request,
    sess: Session,
    prefill_done: Instant,
    generated_budget: usize,
}

impl Active {
    fn finished(&self, max_seq: usize) -> bool {
        let gen = self.sess.tokens.len() - self.sess.prompt_len;
        gen >= self.generated_budget
            || self.req.stop == Some(self.sess.last_token)
            || self.sess.cache.seq_len() + 1 >= max_seq
    }
}

/// The coordinator. Owns the engine for the lifetime of [`Self::run`].
pub struct Coordinator {
    engine: Engine,
    cfg: CoordinatorConfig,
}

impl Coordinator {
    pub fn new(engine: Engine, cfg: CoordinatorConfig) -> Self {
        Self { engine, cfg }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Serve until the request channel closes and all work drains.
    pub fn run(&self, rx: Receiver<Request>) {
        self.run_until(rx, || false)
    }

    /// Like [`Self::run`], but also stops (after draining in-flight work)
    /// once `stop()` returns true — used when the shutdown signal is
    /// something other than channel closure (e.g. a finished test client).
    pub fn run_until(&self, rx: Receiver<Request>, stop: impl Fn() -> bool) {
        let mut waiting: VecDeque<Request> = VecDeque::new();
        let mut active: Vec<Active> = Vec::new();
        let mut closed = false;

        while !((closed || stop()) && waiting.is_empty() && active.is_empty()) {
            // 1. Drain the channel (block briefly when idle).
            loop {
                match if active.is_empty() && waiting.is_empty() && !closed {
                    rx.recv_timeout(self.cfg.idle_poll).map_err(|e| e == RecvTimeoutError::Disconnected)
                } else {
                    rx.try_recv().map_err(|e| e == std::sync::mpsc::TryRecvError::Disconnected)
                } {
                    Ok(req) => waiting.push_back(req),
                    Err(true) => {
                        closed = true;
                        break;
                    }
                    Err(false) => break,
                }
            }

            // 2. Admit + prefill a chunk.
            let room = self.cfg.max_active.saturating_sub(active.len());
            let n_admit = room.min(self.cfg.prefill_chunk).min(waiting.len());
            if n_admit > 0 {
                let batch: Vec<Request> = waiting.drain(..n_admit).collect();
                self.prefill_batch(batch, &mut active);
            }

            // 3. One decode step over the active set, grouped by graph.
            if !active.is_empty() {
                self.decode_round(&mut active);
            }

            // 4. Retire finished sessions.
            let max_seq = self.engine.dims().max_seq;
            let mut i = 0;
            while i < active.len() {
                if active[i].finished(max_seq) {
                    let a = active.swap_remove(i);
                    let tokens = a.sess.generated().to_vec();
                    let resp = Response {
                        id: a.req.id,
                        metrics: RequestMetrics {
                            ttft: a.prefill_done - a.req.submitted_at,
                            latency: a.req.submitted_at.elapsed(),
                            prompt_tokens: a.sess.prompt_len,
                            generated_tokens: tokens.len(),
                            cache_pct: a.sess.cache.cache_size_pct(),
                        },
                        tokens,
                        error: None,
                    };
                    let _ = a.req.reply.send(resp); // receiver may be gone
                } else {
                    i += 1;
                }
            }
        }
        crate::log_info!("coordinator drained, shutting down");
    }

    fn prefill_batch(&self, reqs: Vec<Request>, active: &mut Vec<Active>) {
        let dims = self.engine.dims().clone();
        let mut sessions = Vec::new();
        let mut oks = Vec::new();
        for req in reqs {
            match Session::new(req.id, &dims, req.mode.clone()) {
                Ok(s) => {
                    sessions.push(s);
                    oks.push(req);
                }
                Err(e) => {
                    let _ = req.reply.send(Response::error(req.id, e.to_string()));
                }
            }
        }
        if sessions.is_empty() {
            return;
        }
        let prompts: Vec<Vec<i64>> = oks.iter().map(|r| r.prompt.clone()).collect();
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        match self.engine.prefill(&mut refs, &prompts) {
            Ok(_) => {
                let now = Instant::now();
                for (req, sess) in oks.into_iter().zip(sessions) {
                    active.push(Active {
                        generated_budget: req.max_new.max(1),
                        req,
                        sess,
                        prefill_done: now,
                    });
                }
            }
            Err(e) => {
                crate::log_error!("prefill failed: {e}");
                for req in oks {
                    let _ = req.reply.send(Response::error(req.id, e.to_string()));
                }
            }
        }
    }

    fn decode_round(&self, active: &mut [Active]) {
        // Group indices by (graph kind, oracle_k).
        let mut groups: std::collections::BTreeMap<(String, i64), Vec<usize>> =
            std::collections::BTreeMap::new();
        for (i, a) in active.iter().enumerate() {
            let key = match a.sess.mode {
                CacheMode::Oracle { k } => ("decode_full".to_string(), k as i64),
                CacheMode::Full => ("decode_full".to_string(), -1),
                CacheMode::Mikv { .. } => ("decode_mikv".to_string(), 0),
            };
            groups.entry(key).or_default().push(i);
        }
        for (_, idxs) in groups {
            // split_at_mut gymnastics: collect raw pointers safely via
            // partition in index order (indices are distinct).
            let mut refs: Vec<&mut Session> = Vec::with_capacity(idxs.len());
            // SAFETY: idxs are unique indices into `active`; we create
            // non-overlapping &mut borrows.
            unsafe {
                let base = active.as_mut_ptr();
                for &i in &idxs {
                    refs.push(&mut (*base.add(i)).sess);
                }
            }
            match self.engine.decode_step(&mut refs) {
                Ok(rows) => {
                    for (sess, row) in refs.iter_mut().zip(rows) {
                        let tok = sampler::greedy(&row);
                        sess.last_token = tok;
                        sess.tokens.push(tok);
                    }
                }
                Err(e) => crate::log_error!("decode failed: {e}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = CoordinatorConfig::default();
        assert!(c.max_active >= c.prefill_chunk);
        assert!(c.idle_poll > Duration::ZERO);
    }
    // The full coordinator loop is exercised by rust/tests/ integration
    // tests with real artifacts and by examples/serve_e2e.rs.
}
