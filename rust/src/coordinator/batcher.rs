//! The continuous-batching coordinator loop.
//!
//! Runs on the engine thread (PJRT handles are not `Send`). Each scheduler
//! iteration:
//!
//! 1. drains newly arrived requests into the waiting queue (FCFS);
//! 2. admits waiting requests up to `max_active` and prefills them in
//!    chunks of the compiled prefill batch sizes;
//! 3. forms decode batches from the active set, grouped by graph kind
//!    (MiKV-cache sessions vs full/oracle-cache sessions — different
//!    executables) and, within the oracle group, by `oracle_k`;
//! 4. retires finished sessions (budget reached / stop token / cache full /
//!    engine failure) and replies on each request's channel.
//!
//! Short requests are never stuck behind long ones: batches are re-formed
//! every step from whatever is active (the "continuous" in continuous
//! batching, per Orca/vLLM). Session cache blocks are checked out of one
//! shared [`BufferPool`], so a retiring request's allocations are recycled
//! by the next admit instead of round-tripping the allocator.

use super::request::{Request, RequestMetrics, Response};
use super::stats::MetricsCollector;
use crate::kvcache::BufferPool;
use crate::model::{sampler, CacheMode, Engine, Session};
use crate::runtime::ModelDims;
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Coordinator tuning knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Maximum sessions decoding concurrently.
    pub max_active: usize,
    /// Maximum requests prefilled per scheduler iteration.
    pub prefill_chunk: usize,
    /// Channel poll timeout when idle.
    pub idle_poll: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            max_active: 8,
            prefill_chunk: 4,
            idle_poll: Duration::from_millis(20),
        }
    }
}

/// The engine surface the coordinator drives. The real [`Engine`] needs
/// compiled artifacts; this seam lets the scheduler loop be exercised (and
/// its failure handling regression-tested) with stub engines.
pub trait StepEngine {
    fn dims(&self) -> &ModelDims;

    /// Prefill the sessions' caches from their prompts; returns last-position
    /// logits per session.
    fn prefill(
        &self,
        sessions: &mut [&mut Session],
        prompts: &[Vec<i64>],
    ) -> crate::Result<Vec<Vec<f32>>>;

    /// One decode step over a homogeneous session group; returns one logits
    /// row per session.
    fn decode_step(&self, sessions: &mut [&mut Session]) -> crate::Result<Vec<Vec<f32>>>;
}

impl StepEngine for Engine {
    fn dims(&self) -> &ModelDims {
        Engine::dims(self)
    }

    fn prefill(
        &self,
        sessions: &mut [&mut Session],
        prompts: &[Vec<i64>],
    ) -> crate::Result<Vec<Vec<f32>>> {
        Engine::prefill(self, sessions, prompts)
    }

    fn decode_step(&self, sessions: &mut [&mut Session]) -> crate::Result<Vec<Vec<f32>>> {
        Engine::decode_step(self, sessions)
    }
}

struct Active {
    req: Request,
    sess: Session,
    prefill_done: Instant,
    generated_budget: usize,
    /// Set when the engine failed a step for this session; the retire pass
    /// replies with an error instead of retrying forever.
    error: Option<String>,
}

impl Active {
    fn finished(&self, max_seq: usize) -> bool {
        let gen = self.sess.tokens.len() - self.sess.prompt_len;
        // The next decode appends into slot `seq_len`, which is legal while
        // `seq_len < max_seq` — retire only once the cache is actually full
        // (`seq_len == max_seq`), so the last slot is not wasted.
        gen >= self.generated_budget
            || self.req.stop == Some(self.sess.last_token)
            || self.sess.cache.seq_len() >= max_seq
    }
}

/// The coordinator. Owns the engine for the lifetime of [`Self::run`].
pub struct Coordinator<E: StepEngine = Engine> {
    engine: E,
    cfg: CoordinatorConfig,
    pool: BufferPool,
}

impl<E: StepEngine> Coordinator<E> {
    pub fn new(engine: E, cfg: CoordinatorConfig) -> Self {
        Self {
            engine,
            cfg,
            pool: BufferPool::new(),
        }
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// The shared pool session cache blocks are recycled through.
    pub fn pool(&self) -> &BufferPool {
        &self.pool
    }

    /// Serve until the request channel closes and all work drains.
    pub fn run(&self, rx: Receiver<Request>) {
        self.run_until(rx, || false)
    }

    /// Like [`Self::run`], but also stops (after draining in-flight work)
    /// once `stop()` returns true — used when the shutdown signal is
    /// something other than channel closure (e.g. a finished test client).
    pub fn run_until(&self, rx: Receiver<Request>, stop: impl Fn() -> bool) {
        let mut waiting: VecDeque<Request> = VecDeque::new();
        let mut active: Vec<Active> = Vec::new();
        let mut collector = MetricsCollector::new();
        let mut closed = false;

        while !((closed || stop()) && waiting.is_empty() && active.is_empty()) {
            // 1. Drain the channel (block briefly when idle).
            loop {
                match if active.is_empty() && waiting.is_empty() && !closed {
                    rx.recv_timeout(self.cfg.idle_poll).map_err(|e| e == RecvTimeoutError::Disconnected)
                } else {
                    rx.try_recv().map_err(|e| e == std::sync::mpsc::TryRecvError::Disconnected)
                } {
                    Ok(req) => waiting.push_back(req),
                    Err(true) => {
                        closed = true;
                        break;
                    }
                    Err(false) => break,
                }
            }

            // 2. Admit + prefill a chunk.
            let room = self.cfg.max_active.saturating_sub(active.len());
            let n_admit = room.min(self.cfg.prefill_chunk).min(waiting.len());
            if n_admit > 0 {
                let batch: Vec<Request> = waiting.drain(..n_admit).collect();
                self.prefill_batch(batch, &mut active);
            }

            // 2b. Retire sessions that are already complete after prefill
            // (`max_new <= 1`, or the prefill-sampled token hit `stop`)
            // before spending a decode step on them — a decode here would
            // overshoot the documented token budget by one.
            self.retire(&mut active, &mut collector);

            // 3. One decode step over the active set, grouped by graph.
            if !active.is_empty() {
                self.decode_round(&mut active);
            }

            // 4. Retire finished (or failed) sessions.
            self.retire(&mut active, &mut collector);
        }
        if collector.n_requests() > 0 {
            let (p50, p99) = collector.latency();
            crate::log_info!(
                "coordinator drained: {} requests, latency p50 {p50:?} p99 {p99:?}, \
                 {:.1} tok/s, host bytes/session mean {:.0} peak {}",
                collector.n_requests(),
                collector.throughput(),
                collector.mean_host_bytes(),
                collector.peak_host_bytes()
            );
        } else {
            crate::log_info!("coordinator drained, shutting down");
        }
    }

    /// Remove finished or failed sessions from `active`, replying on each
    /// request's channel and recording completed-request metrics.
    fn retire(&self, active: &mut Vec<Active>, collector: &mut MetricsCollector) {
        let max_seq = self.engine.dims().max_seq;
        let mut i = 0;
        while i < active.len() {
            if active[i].error.is_some() || active[i].finished(max_seq) {
                let a = active.swap_remove(i);
                let resp = match a.error {
                    Some(msg) => Response::error(a.req.id, msg),
                    None => {
                        let tokens = a.sess.generated().to_vec();
                        let metrics = RequestMetrics {
                            ttft: a.prefill_done - a.req.submitted_at,
                            latency: a.req.submitted_at.elapsed(),
                            prompt_tokens: a.sess.prompt_len,
                            generated_tokens: tokens.len(),
                            cache_pct: a.sess.cache.cache_size_pct(),
                            host_bytes: a.sess.cache.host_bytes(),
                        };
                        collector.record(&metrics);
                        Response {
                            id: a.req.id,
                            metrics,
                            tokens,
                            error: None,
                        }
                    }
                };
                let _ = a.req.reply.send(resp); // receiver may be gone
            } else {
                i += 1;
            }
        }
    }

    fn prefill_batch(&self, reqs: Vec<Request>, active: &mut Vec<Active>) {
        let dims = self.engine.dims().clone();
        let mut sessions = Vec::new();
        let mut oks = Vec::new();
        for req in reqs {
            // Validate per request BEFORE batching: one bad prompt must not
            // fail the engine's whole prefill chunk for its co-batched
            // neighbours.
            if req.prompt.is_empty() || req.prompt.len() > dims.max_seq {
                let _ = req.reply.send(Response::error(
                    req.id,
                    format!(
                        "prompt length {} invalid (must be 1..={})",
                        req.prompt.len(),
                        dims.max_seq
                    ),
                ));
                continue;
            }
            match Session::with_pool(req.id, &dims, req.mode.clone(), &self.pool) {
                Ok(s) => {
                    sessions.push(s);
                    oks.push(req);
                }
                Err(e) => {
                    let _ = req.reply.send(Response::error(req.id, e.to_string()));
                }
            }
        }
        if sessions.is_empty() {
            return;
        }
        let prompts: Vec<Vec<i64>> = oks.iter().map(|r| r.prompt.clone()).collect();
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        match self.engine.prefill(&mut refs, &prompts) {
            Ok(_) => {
                let now = Instant::now();
                for (req, sess) in oks.into_iter().zip(sessions) {
                    active.push(Active {
                        generated_budget: req.max_new.max(1),
                        req,
                        sess,
                        prefill_done: now,
                        error: None,
                    });
                }
            }
            Err(e) => {
                crate::log_error!("prefill failed: {e}");
                for req in oks {
                    let _ = req.reply.send(Response::error(req.id, e.to_string()));
                }
            }
        }
    }

    fn decode_round(&self, active: &mut [Active]) {
        // Group indices by (graph kind, oracle_k).
        let mut groups: std::collections::BTreeMap<(String, i64), Vec<usize>> =
            std::collections::BTreeMap::new();
        for (i, a) in active.iter().enumerate() {
            let key = match a.sess.mode {
                CacheMode::Oracle { k } => ("decode_full".to_string(), k as i64),
                CacheMode::Full => ("decode_full".to_string(), -1),
                CacheMode::Mikv { .. } => ("decode_mikv".to_string(), 0),
            };
            groups.entry(key).or_default().push(i);
        }
        for (_, idxs) in groups {
            // A failed group is marked (not silently retried): the sessions
            // would otherwise stay active and be re-submitted to the same
            // failing graph every iteration — a livelock. The retire pass
            // replies with an error Response for each.
            let group_err: Option<String> = {
                // split_at_mut gymnastics: collect raw pointers safely via
                // partition in index order (indices are distinct).
                let mut refs: Vec<&mut Session> = Vec::with_capacity(idxs.len());
                // SAFETY: idxs are unique indices into `active`; we create
                // non-overlapping &mut borrows, dropped before `active` is
                // touched again below.
                unsafe {
                    let base = active.as_mut_ptr();
                    for &i in &idxs {
                        refs.push(&mut (*base.add(i)).sess);
                    }
                }
                match self.engine.decode_step(&mut refs) {
                    Ok(rows) => {
                        for (sess, row) in refs.iter_mut().zip(rows) {
                            let tok = sampler::greedy(&row);
                            sess.last_token = tok;
                            sess.tokens.push(tok);
                        }
                        None
                    }
                    Err(e) => Some(e.to_string()),
                }
            };
            if let Some(msg) = group_err {
                crate::log_error!("decode failed: {msg}; retiring {} session(s)", idxs.len());
                for &i in &idxs {
                    active[i].error = Some(msg.clone());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SessionCache;
    use std::sync::mpsc;

    #[test]
    fn config_defaults_sane() {
        let c = CoordinatorConfig::default();
        assert!(c.max_active >= c.prefill_chunk);
        assert!(c.idle_poll > Duration::ZERO);
    }

    fn test_dims() -> ModelDims {
        ModelDims {
            vocab: 16,
            d_model: 16,
            n_layers: 2,
            n_q_heads: 2,
            n_kv_heads: 2,
            d_head: 4,
            d_ff: 32,
            max_seq: 8,
            quant_group: 2,
            params: 0,
        }
    }

    /// Stub engine: prefill fills the (Full) cache with zeros; decode either
    /// appends a constant token or fails, per `fail_decode`.
    struct StubEngine {
        dims: ModelDims,
        fail_decode: bool,
    }

    impl StubEngine {
        fn new(fail_decode: bool) -> Self {
            Self {
                dims: test_dims(),
                fail_decode,
            }
        }
    }

    impl StepEngine for StubEngine {
        fn dims(&self) -> &ModelDims {
            &self.dims
        }

        fn prefill(
            &self,
            sessions: &mut [&mut Session],
            prompts: &[Vec<i64>],
        ) -> crate::Result<Vec<Vec<f32>>> {
            let planes = self.dims.planes();
            let d = self.dims.d_head;
            for (sess, prompt) in sessions.iter_mut().zip(prompts) {
                sess.tokens = prompt.clone();
                sess.prompt_len = prompt.len();
                let kv = vec![0.0f32; planes * prompt.len() * d];
                match &mut sess.cache {
                    SessionCache::Full(f) => f.ingest_prefill(prompt.len(), &kv, &kv),
                    SessionCache::Mikv(_) => anyhow::bail!("stub only prefills Full sessions"),
                }
                sess.last_token = 1;
                sess.tokens.push(1);
            }
            Ok(vec![vec![0.0; self.dims.vocab]; sessions.len()])
        }

        fn decode_step(&self, sessions: &mut [&mut Session]) -> crate::Result<Vec<Vec<f32>>> {
            anyhow::ensure!(!self.fail_decode, "injected decode failure");
            let planes = self.dims.planes();
            let (d, s) = (self.dims.d_head, self.dims.max_seq);
            let kv = vec![0.0f32; planes * d];
            let attn_prev = vec![0.0f32; planes * s];
            let attn_self = vec![0.0f32; planes];
            let mut rows = Vec::with_capacity(sessions.len());
            for sess in sessions.iter_mut() {
                sess.ingest_step(&kv, &kv, &attn_prev, &attn_self);
                let mut logits = vec![0.0f32; self.dims.vocab];
                logits[2] = 1.0;
                rows.push(logits);
            }
            Ok(rows)
        }
    }

    fn request(id: u64, prompt_len: usize, max_new: usize, reply: super::super::request::Reply) -> Request {
        Request {
            id,
            prompt: vec![1; prompt_len],
            max_new,
            stop: None,
            mode: CacheMode::Full,
            submitted_at: Instant::now(),
            reply,
        }
    }

    /// Regression: a decode failure must retire the group with an error
    /// Response instead of retrying it forever (the seed livelock).
    #[test]
    fn decode_failure_retires_sessions_with_error() {
        let (tx, rx) = mpsc::channel::<Request>();
        let (reply_tx, reply_rx) = mpsc::channel::<Response>();
        tx.send(request(7, 3, 4, reply_tx.clone())).unwrap();
        drop(tx);
        drop(reply_tx);

        // This call must terminate; before the fix it spun forever
        // re-submitting the failing group.
        Coordinator::new(StubEngine::new(true), CoordinatorConfig::default()).run(rx);

        let resp = reply_rx.recv().expect("a response must be delivered");
        assert_eq!(resp.id, 7);
        let err = resp.error.expect("failure must surface as an error");
        assert!(err.contains("injected decode failure"), "got: {err}");
        assert!(reply_rx.recv().is_err(), "exactly one response");
    }

    /// `max_new = 1` is satisfied by the prefill-sampled token alone: the
    /// session must retire before any decode step. Proven with the failing
    /// engine — if a decode were attempted, the response would be an error.
    #[test]
    fn budget_of_one_retires_after_prefill_without_decoding() {
        let (tx, rx) = mpsc::channel::<Request>();
        let (reply_tx, reply_rx) = mpsc::channel::<Response>();
        tx.send(request(9, 3, 1, reply_tx.clone())).unwrap();
        drop(tx);
        drop(reply_tx);

        Coordinator::new(StubEngine::new(true), CoordinatorConfig::default()).run(rx);

        let resp = reply_rx.recv().unwrap();
        assert!(resp.error.is_none(), "no decode must run: {:?}", resp.error);
        assert_eq!(resp.tokens.len(), 1, "exactly the prefill token");
    }

    /// An oversized prompt is rejected per-request; co-batched valid
    /// requests still complete (no chunk-wide blast radius).
    #[test]
    fn oversized_prompt_does_not_fail_its_batch_neighbours() {
        let (tx, rx) = mpsc::channel::<Request>();
        let (reply_tx, reply_rx) = mpsc::channel::<Response>();
        tx.send(request(1, 9, 2, reply_tx.clone())).unwrap(); // > max_seq = 8
        tx.send(request(2, 3, 2, reply_tx.clone())).unwrap();
        drop(tx);
        drop(reply_tx);

        Coordinator::new(StubEngine::new(false), CoordinatorConfig::default()).run(rx);

        let mut resps: Vec<Response> = reply_rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        let err = resps[0].error.as_deref().expect("oversized prompt rejected");
        assert!(err.contains("prompt length 9"), "got: {err}");
        assert!(resps[1].error.is_none(), "neighbour must succeed");
        assert_eq!(resps[1].tokens.len(), 2);
    }

    /// Happy path through the real scheduler loop with a stub engine.
    #[test]
    fn coordinator_completes_requests_with_stub_engine() {
        let (tx, rx) = mpsc::channel::<Request>();
        let (reply_tx, reply_rx) = mpsc::channel::<Response>();
        for id in 0..3u64 {
            tx.send(request(id, 3, 2, reply_tx.clone())).unwrap();
        }
        drop(tx);
        drop(reply_tx);

        Coordinator::new(StubEngine::new(false), CoordinatorConfig::default()).run(rx);

        let mut resps: Vec<Response> = reply_rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 3);
        for r in &resps {
            assert!(r.error.is_none());
            assert_eq!(r.tokens.len(), 2);
            assert!(r.metrics.host_bytes > 0);
        }
    }

    /// Regression for the retire off-by-one: with max_seq = 8 and a 5-token
    /// prompt, decoding may legally fill slots 5, 6 AND 7 — the session
    /// retires at seq_len == 8, not one token early.
    #[test]
    fn last_cache_slot_is_usable() {
        let (tx, rx) = mpsc::channel::<Request>();
        let (reply_tx, reply_rx) = mpsc::channel::<Response>();
        // budget far above what the cache allows → cache capacity binds
        tx.send(request(1, 5, 100, reply_tx.clone())).unwrap();
        drop(tx);
        drop(reply_tx);

        Coordinator::new(StubEngine::new(false), CoordinatorConfig::default()).run(rx);

        let resp = reply_rx.recv().unwrap();
        assert!(resp.error.is_none());
        // prefill contributes 1 token; decodes fill slots 5..8 → 3 more.
        assert_eq!(
            resp.tokens.len(),
            4,
            "the last legal slot must be used (seed retired one token early)"
        );
    }

    /// Direct unit check of the retire predicate.
    #[test]
    fn finished_uses_the_full_cache_capacity() {
        let dims = test_dims();
        let (reply_tx, _reply_rx) = mpsc::channel::<Response>();
        let mut sess = Session::new(1, &dims, CacheMode::Full).unwrap();
        let planes = dims.planes();
        let t = 7; // one below max_seq = 8
        let kv = vec![0.0f32; planes * t * dims.d_head];
        match &mut sess.cache {
            SessionCache::Full(f) => f.ingest_prefill(t, &kv, &kv),
            _ => unreachable!(),
        }
        sess.prompt_len = t;
        sess.tokens = vec![1; t + 1];
        sess.last_token = 1;
        let mut a = Active {
            req: request(1, t, 100, reply_tx),
            sess,
            prefill_done: Instant::now(),
            generated_budget: 100,
            error: None,
        };
        assert!(
            !a.finished(dims.max_seq),
            "seq_len = 7 of 8: one decode still fits"
        );
        let kv1 = vec![0.0f32; planes * dims.d_head];
        match &mut a.sess.cache {
            SessionCache::Full(f) => f.append(&kv1, &kv1),
            _ => unreachable!(),
        }
        assert!(a.finished(dims.max_seq), "seq_len = 8 of 8: full");
    }
}
