//! Serving coordinator: request queue, continuous batcher, metrics.
//!
//! PJRT handles are not `Send`, so the [`crate::model::Engine`] lives on a
//! dedicated engine thread running [`Coordinator::run`]; other threads
//! (TCP connection handlers, benchmark drivers) talk to it through
//! [`std::sync::mpsc`] channels. The coordinator implements
//! **continuous batching**: new requests are prefilled in chunks while
//! active sessions keep decoding, and decode batches are re-formed every
//! step from whatever is in flight (grouped by graph kind), so a long
//! generation never blocks short ones behind it.

pub mod batcher;
pub mod request;
pub mod stats;

pub use batcher::{Coordinator, CoordinatorConfig};
pub use request::{Reply, Request, RequestMetrics, Response};
pub use stats::MetricsCollector;
