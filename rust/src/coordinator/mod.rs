//! Serving coordinator: admission scheduler, continuous-batching workers,
//! session registries, metrics.
//!
//! The runtime is **sharded**: a [`Scheduler`] admission loop places ops
//! onto N engine workers, each a [`Coordinator`] on its own thread owning
//! its engine, [`crate::kvcache::BufferPool`] and parked-session registry
//! (PJRT handles are not `Send`, so every engine is constructed on — and
//! never leaves — its worker's thread). Other threads (TCP connection
//! handlers, benchmark drivers) talk to the scheduler through
//! [`std::sync::mpsc`] channels carrying [`Op`]s. Placement is
//! least-loaded for fresh turns and **session-affine** for `append`s:
//! workers assign session ids from disjoint strides, so the owner of a
//! parked cache is recoverable from the id alone
//! ([`scheduler::worker_of_session`]). Each worker runs **continuous
//! batching**: new requests are prefilled in chunks while active sessions
//! keep decoding, and decode batches are re-formed every step from
//! whatever is in flight (grouped by graph kind), so a long generation
//! never blocks short ones behind it — and sessions retire/admit between
//! decode steps without draining the batch. Workers are **supervised**: a
//! panicking worker is caught and respawned by the scheduler, every
//! in-flight client gets a structured `internal` terminal event, and
//! cold-spilled sessions survive the crash (see [`WorkerVitals`]).
//!
//! The serving surface is **streaming and multi-turn**: each turn's
//! sampled tokens are pushed into its [`EventSink`] as `token` events
//! followed by a terminal `done`, and turns submitted with `keep` park
//! their session (cache included) in the owning worker's TTL- and
//! footprint-bounded registry so a later `append` op continues the same
//! hi/lo tiers. Compression is requested as a plain-data
//! [`CompressionSpec`] and resolved to a cache mode only at admission.

pub mod batcher;
pub mod cold;
pub mod qos;
pub mod request;
pub mod scheduler;
pub mod stats;

pub use batcher::{Coordinator, CoordinatorConfig, StepEngine, WorkerVitals};
pub use cold::ColdStore;
pub use qos::QosConfig;
pub use request::{
    CompressionSpec, ErrorCode, EventSink, Op, Priority, Reply, Request, RequestMetrics, Response,
    ServeEvent, WireError,
};
pub use scheduler::{worker_of_session, Scheduler};
pub use stats::{MetricsCollector, StatsSnapshot, WorkerStats};
