//! Serving coordinator: op queue, continuous batcher, session registry,
//! metrics.
//!
//! PJRT handles are not `Send`, so the [`crate::model::Engine`] lives on a
//! dedicated engine thread running [`Coordinator::run`]; other threads
//! (TCP connection handlers, benchmark drivers) talk to it through
//! [`std::sync::mpsc`] channels carrying [`Op`]s. The coordinator
//! implements **continuous batching**: new requests are prefilled in
//! chunks while active sessions keep decoding, and decode batches are
//! re-formed every step from whatever is in flight (grouped by graph
//! kind), so a long generation never blocks short ones behind it.
//!
//! The serving surface is **streaming and multi-turn**: each turn's
//! sampled tokens are pushed into its [`EventSink`] as `token` events
//! followed by a terminal `done`, and turns submitted with `keep` park
//! their session (cache included) in a TTL- and footprint-bounded
//! registry so a later `append` op continues the same hi/lo tiers.
//! Compression is requested as a plain-data [`CompressionSpec`] and
//! resolved to a cache mode only at admission.

pub mod batcher;
pub mod request;
pub mod stats;

pub use batcher::{Coordinator, CoordinatorConfig, StepEngine};
pub use request::{
    CompressionSpec, ErrorCode, EventSink, Op, Reply, Request, RequestMetrics, Response,
    ServeEvent, WireError,
};
pub use stats::{MetricsCollector, StatsSnapshot};
