//! The cold tier's on-disk store: a capacity-bounded directory of
//! per-session snapshot files.
//!
//! Each worker owns one [`ColdStore`] rooted at `<dir>/worker-<id>/` —
//! workers assign session ids from disjoint strides
//! ([`super::scheduler::worker_of_session`]), so a per-worker namespace
//! never sees another worker's files and needs no cross-thread locking.
//! Files are written atomically (write to `<sid>.snap.tmp`, then rename to
//! `<sid>.snap`), so a crash mid-spill leaves either the old snapshot or
//! none — never a torn frame (and torn frames would still be caught by the
//! codec checksum, see [`crate::kvcache::spill`]).
//!
//! The store is bounded by `max_bytes`: when a new snapshot would push the
//! running total past the bound, the **oldest** spilled sessions (by spill
//! order) are evicted until it fits — cold eviction is the real context
//! loss the paper warns against, so it is counted and surfaced in `stats`.
//! Session ids restart at every process launch, so snapshots from a
//! previous run could alias fresh ids; [`ColdStore::open`] therefore
//! removes every leftover file in its namespace (orphan GC) before
//! serving. The exception is supervised **respawn within one process**
//! ([`ColdStore::open_recover`]): session ids stay valid across a worker
//! restart, so recovery adopts the dead worker's intact `.snap` files
//! (they restore transparently on the next `append`) and GCs only tmp
//! debris.
//!
//! For crash-consistency testing, every IO point in the `put`/`take`
//! sequence is probed through a [`FaultPlan`]
//! (`cold_put_before_write` / `cold_put_partial_write` /
//! `cold_put_before_rename` / `cold_put_after_rename` /
//! `cold_take_read`), so tests can enumerate mid-sequence crashes and
//! assert the invariants above actually hold.

use crate::util::faults::{FaultPlan, FaultSite};
use std::collections::HashMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// A structured IO error for an injected fault (the fault plan models
/// the disk failing, so it surfaces exactly like one).
fn injected(what: &str, sid: u64) -> io::Error {
    io::Error::new(
        io::ErrorKind::Other,
        format!("fault plan: injected {what} for session {sid}"),
    )
}

struct ColdEntry {
    bytes: u64,
    /// Monotone spill order — the eviction clock.
    seq: u64,
}

/// Capacity-bounded directory of spilled session snapshots (one worker's
/// cold-tier namespace).
pub struct ColdStore {
    dir: PathBuf,
    /// Byte bound on the directory (0 = unbounded).
    max_bytes: u64,
    total_bytes: u64,
    entries: HashMap<u64, ColdEntry>,
    seq: u64,
    evictions: u64,
    orphans_removed: u64,
    /// Deterministic IO fault injection (disabled by default).
    faults: FaultPlan,
}

impl ColdStore {
    /// Open (creating if needed) the worker's namespace under `root` and
    /// GC any leftover snapshot files from a previous run.
    pub fn open(root: &Path, worker_id: usize, max_bytes: u64) -> io::Result<ColdStore> {
        Self::open_with_faults(root, worker_id, max_bytes, FaultPlan::disabled())
    }

    /// [`Self::open`] with a fault plan probed at every IO point.
    pub fn open_with_faults(
        root: &Path,
        worker_id: usize,
        max_bytes: u64,
        faults: FaultPlan,
    ) -> io::Result<ColdStore> {
        let dir = root.join(format!("worker-{worker_id}"));
        fs::create_dir_all(&dir)?;
        let mut orphans_removed = 0u64;
        for entry in fs::read_dir(&dir)? {
            let entry = entry?;
            if entry.file_type()?.is_file() {
                fs::remove_file(entry.path())?;
                orphans_removed += 1;
            }
        }
        Ok(ColdStore {
            dir,
            max_bytes,
            total_bytes: 0,
            entries: HashMap::new(),
            seq: 0,
            evictions: 0,
            orphans_removed,
            faults,
        })
    }

    /// Reopen a namespace after a supervised worker respawn **within the
    /// same process**: session ids are still live, so intact `<sid>.snap`
    /// files are adopted back into the index (oldest first by modification
    /// time, so the eviction clock keeps its meaning) instead of GC'd.
    /// Only tmp debris and unparseable names are removed. Adopted
    /// snapshots beyond `max_bytes` are evicted oldest-first on the spot.
    pub fn open_recover(
        root: &Path,
        worker_id: usize,
        max_bytes: u64,
        faults: FaultPlan,
    ) -> io::Result<ColdStore> {
        let dir = root.join(format!("worker-{worker_id}"));
        fs::create_dir_all(&dir)?;
        let mut orphans_removed = 0u64;
        // (sid, bytes, mtime) of every adoptable snapshot.
        let mut found: Vec<(u64, u64, std::time::SystemTime)> = Vec::new();
        for entry in fs::read_dir(&dir)? {
            let entry = entry?;
            if !entry.file_type()?.is_file() {
                continue;
            }
            let path = entry.path();
            let sid = path
                .extension()
                .and_then(|e| e.to_str())
                .filter(|e| *e == "snap")
                .and_then(|_| path.file_stem())
                .and_then(|s| s.to_str())
                .and_then(|s| s.parse::<u64>().ok());
            match sid {
                Some(sid) => {
                    let meta = entry.metadata()?;
                    let mtime = meta.modified().unwrap_or(std::time::UNIX_EPOCH);
                    found.push((sid, meta.len(), mtime));
                }
                None => {
                    // `.snap.tmp` debris or foreign files: GC as usual.
                    fs::remove_file(&path)?;
                    orphans_removed += 1;
                }
            }
        }
        found.sort_by_key(|&(sid, _, mtime)| (mtime, sid));
        let mut store = ColdStore {
            dir,
            max_bytes,
            total_bytes: 0,
            entries: HashMap::new(),
            seq: 0,
            evictions: 0,
            orphans_removed,
            faults,
        };
        for (sid, bytes, _) in found {
            store.seq += 1;
            store.total_bytes += bytes;
            store.entries.insert(
                sid,
                ColdEntry {
                    bytes,
                    seq: store.seq,
                },
            );
        }
        // Enforce the bound on what was adopted (a respawn may configure a
        // smaller cold tier than what the dead worker left behind).
        if store.max_bytes > 0 {
            while store.total_bytes > store.max_bytes {
                let oldest = store
                    .entries
                    .iter()
                    .min_by_key(|(_, e)| e.seq)
                    .map(|(&k, _)| k);
                let Some(victim) = oldest else { break };
                store.remove(victim)?;
                store.evictions += 1;
            }
        }
        Ok(store)
    }

    fn path(&self, sid: u64) -> PathBuf {
        self.dir.join(format!("{sid}.snap"))
    }

    /// Spill a session's snapshot frame. Evicts the oldest cold sessions
    /// as needed to respect `max_bytes`; returns `Ok(false)` (nothing
    /// stored) when the frame alone exceeds the bound.
    pub fn put(&mut self, sid: u64, frame: &[u8]) -> io::Result<bool> {
        let len = frame.len() as u64;
        if self.max_bytes > 0 {
            if len > self.max_bytes {
                return Ok(false);
            }
            // Re-spilling an existing id replaces its bytes, so exclude
            // them from the pressure calculation.
            let replaced = self.entries.get(&sid).map(|e| e.bytes).unwrap_or(0);
            while self.total_bytes - replaced + len > self.max_bytes {
                let oldest = self
                    .entries
                    .iter()
                    .filter(|(&k, _)| k != sid)
                    .min_by_key(|(_, e)| e.seq)
                    .map(|(&k, _)| k);
                let Some(victim) = oldest else { break };
                self.remove(victim)?;
                self.evictions += 1;
            }
        }
        let tmp = self.dir.join(format!("{sid}.snap.tmp"));
        if self.faults.should_fire(FaultSite::ColdPutBeforeWrite) {
            return Err(injected("put failure before tmp write", sid));
        }
        if self.faults.should_fire(FaultSite::ColdPutPartialWrite) {
            // A torn write: half the frame lands in the tmp file, then the
            // "disk" fails. The orphan tmp is GC'd by the next open, and
            // the final path was never touched.
            let part = frame.get(..frame.len() / 2).unwrap_or(&[]);
            fs::write(&tmp, part)?;
            return Err(injected("partial tmp write", sid));
        }
        fs::write(&tmp, frame)?;
        if self.faults.should_fire(FaultSite::ColdPutBeforeRename) {
            return Err(injected("put failure before rename", sid));
        }
        fs::rename(&tmp, self.path(sid))?;
        if self.faults.should_fire(FaultSite::ColdPutAfterRename) {
            // The snapshot is durable but the index update below never
            // runs — the crash point right after the atomic rename. The
            // file is unreachable (not in `entries`) and is GC'd by the
            // next open.
            return Err(injected("put failure after rename", sid));
        }
        if let Some(old) = self.entries.remove(&sid) {
            self.total_bytes -= old.bytes;
        }
        self.seq += 1;
        self.total_bytes += len;
        self.entries.insert(
            sid,
            ColdEntry {
                bytes: len,
                seq: self.seq,
            },
        );
        Ok(true)
    }

    /// Read and remove a session's snapshot. `Ok(None)` if the session is
    /// not in the cold tier.
    pub fn take(&mut self, sid: u64) -> io::Result<Option<Vec<u8>>> {
        let Some(e) = self.entries.remove(&sid) else {
            return Ok(None);
        };
        self.total_bytes -= e.bytes;
        if self.faults.should_fire(FaultSite::ColdTakeRead) {
            // The index entry is already gone (mirroring a real read
            // failure below): the caller sees a structured error now and
            // `session_not_found` on retry; the unreachable file is GC'd
            // by the next open.
            return Err(injected("snapshot read failure", sid));
        }
        let p = self.path(sid);
        let bytes = fs::read(&p)?;
        fs::remove_file(&p)?;
        Ok(Some(bytes))
    }

    /// Drop a session's snapshot without reading it. Returns whether it
    /// existed.
    pub fn remove(&mut self, sid: u64) -> io::Result<bool> {
        let Some(e) = self.entries.remove(&sid) else {
            return Ok(false);
        };
        self.total_bytes -= e.bytes;
        fs::remove_file(self.path(sid))?;
        Ok(true)
    }

    pub fn contains(&self, sid: u64) -> bool {
        self.entries.contains_key(&sid)
    }

    /// Number of spilled sessions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes currently on disk across all snapshots.
    pub fn bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Cold-tier evictions (capacity pressure) since open — each one is a
    /// lost session context.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Leftover files from previous runs removed at open.
    pub fn orphans_removed(&self) -> u64 {
        self.orphans_removed
    }

    /// The namespace directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::faults::FaultRule;
    use std::sync::atomic::{AtomicU64, Ordering};

    static TEST_SEQ: AtomicU64 = AtomicU64::new(0);

    /// Unique per-test scratch root under the OS temp dir.
    fn tmp_root(tag: &str) -> PathBuf {
        let n = TEST_SEQ.fetch_add(1, Ordering::SeqCst);
        let p = std::env::temp_dir().join(format!(
            "mikv-cold-test-{}-{n}-{tag}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn put_take_round_trip_with_accounting() {
        let root = tmp_root("roundtrip");
        let mut c = ColdStore::open(&root, 0, 0).unwrap();
        assert!(c.is_empty());
        assert!(c.put(7, b"snapshot-seven").unwrap());
        assert!(c.put(9, b"nine").unwrap());
        assert_eq!(c.len(), 2);
        assert_eq!(c.bytes(), 14 + 4);
        assert!(c.contains(7));
        assert!(c.dir().join("7.snap").exists());
        assert!(!c.dir().join("7.snap.tmp").exists(), "tmp renamed away");

        assert_eq!(c.take(7).unwrap().as_deref(), Some(&b"snapshot-seven"[..]));
        assert_eq!(c.bytes(), 4);
        assert!(!c.contains(7));
        assert!(!c.dir().join("7.snap").exists());
        assert_eq!(c.take(7).unwrap(), None, "take is destructive");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn replacing_a_snapshot_does_not_double_count() {
        let root = tmp_root("replace");
        let mut c = ColdStore::open(&root, 0, 0).unwrap();
        assert!(c.put(1, &[0u8; 100]).unwrap());
        assert!(c.put(1, &[0u8; 40]).unwrap());
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 40);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn capacity_bound_evicts_oldest_first() {
        let root = tmp_root("bound");
        let mut c = ColdStore::open(&root, 0, 100).unwrap();
        assert!(c.put(1, &[0u8; 40]).unwrap());
        assert!(c.put(2, &[0u8; 40]).unwrap());
        // 40+40+40 > 100 → session 1 (oldest) is evicted
        assert!(c.put(3, &[0u8; 40]).unwrap());
        assert_eq!(c.evictions(), 1);
        assert!(!c.contains(1));
        assert!(c.contains(2) && c.contains(3));
        assert_eq!(c.bytes(), 80);

        // a frame larger than the whole bound is refused, nothing evicted
        assert!(!c.put(4, &[0u8; 200]).unwrap());
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn open_gcs_orphans_and_namespaces_by_worker() {
        let root = tmp_root("gc");
        {
            let mut a = ColdStore::open(&root, 0, 0).unwrap();
            let mut b = ColdStore::open(&root, 1, 0).unwrap();
            a.put(5, b"stale").unwrap();
            b.put(5, b"other-worker").unwrap();
        }
        // same root, same worker id: the stale snapshot must be GC'd
        let c = ColdStore::open(&root, 0, 0).unwrap();
        assert_eq!(c.orphans_removed(), 1);
        assert!(c.is_empty());
        assert!(!c.dir().join("5.snap").exists());
        // the other worker's namespace was untouched
        assert!(root.join("worker-1").join("5.snap").exists());
        let _ = fs::remove_dir_all(&root);
    }

    /// Crash-consistency sweep over every injected `put` fault point:
    /// each failure surfaces as a structured error, never tears the final
    /// snapshot path, and any debris is exactly what the next `open` GC
    /// removes.
    #[test]
    fn put_fault_points_fail_clean_and_gc_recovers() {
        // (site, tmp file left behind?, final file left behind?)
        let cases = [
            (FaultSite::ColdPutBeforeWrite, false, false),
            (FaultSite::ColdPutPartialWrite, true, false),
            (FaultSite::ColdPutBeforeRename, true, false),
            (FaultSite::ColdPutAfterRename, false, true),
        ];
        for (site, tmp_left, final_left) in cases {
            let root = tmp_root(site.as_str());
            let plan = FaultPlan::builder().every(site, 1).build();
            let mut c =
                ColdStore::open_with_faults(&root, 0, 0, plan.clone()).unwrap();
            let err = c.put(3, b"frame-bytes").unwrap_err();
            assert!(
                err.to_string().contains("fault plan"),
                "{site:?}: {err}"
            );
            assert_eq!(plan.fired(site), 1);
            // the failed put never entered the index or the accounting
            assert!(!c.contains(3), "{site:?}");
            assert_eq!(c.bytes(), 0, "{site:?}");
            assert_eq!(
                c.dir().join("3.snap.tmp").exists(),
                tmp_left,
                "{site:?} tmp debris"
            );
            assert_eq!(
                c.dir().join("3.snap").exists(),
                final_left,
                "{site:?} final file"
            );
            // the session is cleanly absent, not torn: take reports None
            assert_eq!(c.take(3).unwrap(), None, "{site:?}");
            // a fresh open GCs every piece of debris in the namespace
            drop(c);
            let c = ColdStore::open(&root, 0, 0).unwrap();
            let want_orphans = u64::from(tmp_left) + u64::from(final_left);
            assert_eq!(c.orphans_removed(), want_orphans, "{site:?}");
            assert!(!c.dir().join("3.snap.tmp").exists(), "{site:?}");
            assert!(!c.dir().join("3.snap").exists(), "{site:?}");
            let _ = fs::remove_dir_all(&root);
        }
    }

    /// An injected `take` read failure maps to a structured error; the
    /// session then cleanly reports not-found (never a torn restore), and
    /// the unreachable file is debris for the next open's GC.
    #[test]
    fn take_read_fault_degrades_to_not_found() {
        let root = tmp_root("take-fault");
        let plan = FaultPlan::builder()
            .site(
                FaultSite::ColdTakeRead,
                FaultRule {
                    limit: 1,
                    ..FaultRule::default()
                },
            )
            .build();
        let mut c = ColdStore::open_with_faults(&root, 0, 0, plan.clone()).unwrap();
        assert!(c.put(11, b"snapshot").unwrap());
        let err = c.take(11).unwrap_err();
        assert!(err.to_string().contains("fault plan"), "{err}");
        assert_eq!(plan.fired(FaultSite::ColdTakeRead), 1);
        // retry: cleanly absent, not torn (the limit=1 rule is spent)
        assert_eq!(c.take(11).unwrap(), None);
        assert!(!c.contains(11));
        assert_eq!(c.bytes(), 0);
        drop(c);
        let c = ColdStore::open(&root, 0, 0).unwrap();
        assert_eq!(c.orphans_removed(), 1, "unreachable snapshot GC'd");
        let _ = fs::remove_dir_all(&root);
    }

    /// Supervised respawn: `open_recover` adopts intact snapshots (they
    /// stay restorable), GCs tmp debris, and enforces the byte bound on
    /// what it adopted.
    #[test]
    fn open_recover_adopts_snapshots_and_gcs_tmp_debris() {
        let root = tmp_root("recover");
        {
            let mut c = ColdStore::open(&root, 0, 0).unwrap();
            assert!(c.put(4, b"four-bytes!").unwrap());
            assert!(c.put(8, b"eight").unwrap());
            // simulated crash debris
            fs::write(c.dir().join("9.snap.tmp"), b"torn").unwrap();
            fs::write(c.dir().join("junk.snap"), b"alien").unwrap();
        }
        let mut c =
            ColdStore::open_recover(&root, 0, 0, FaultPlan::disabled()).unwrap();
        assert_eq!(c.len(), 2, "both intact snapshots adopted");
        assert_eq!(c.orphans_removed(), 2, "tmp + unparseable GC'd");
        assert_eq!(c.bytes(), 11 + 5);
        assert_eq!(c.take(4).unwrap().as_deref(), Some(&b"four-bytes!"[..]));
        assert_eq!(c.take(8).unwrap().as_deref(), Some(&b"eight"[..]));

        // a tighter bound on respawn evicts adopted snapshots oldest-first
        let root2 = tmp_root("recover-bound");
        {
            let mut c = ColdStore::open(&root2, 0, 0).unwrap();
            assert!(c.put(1, &[0u8; 40]).unwrap());
            std::thread::sleep(std::time::Duration::from_millis(20));
            assert!(c.put(2, &[0u8; 40]).unwrap());
        }
        let c = ColdStore::open_recover(&root2, 0, 50, FaultPlan::disabled()).unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(c.evictions(), 1);
        assert!(c.contains(2), "newest snapshot survives the bound");
        let _ = fs::remove_dir_all(&root);
        let _ = fs::remove_dir_all(&root2);
    }
}
