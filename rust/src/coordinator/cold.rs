//! The cold tier's on-disk store: a capacity-bounded directory of
//! per-session snapshot files.
//!
//! Each worker owns one [`ColdStore`] rooted at `<dir>/worker-<id>/` —
//! workers assign session ids from disjoint strides
//! ([`super::scheduler::worker_of_session`]), so a per-worker namespace
//! never sees another worker's files and needs no cross-thread locking.
//! Files are written atomically (write to `<sid>.snap.tmp`, then rename to
//! `<sid>.snap`), so a crash mid-spill leaves either the old snapshot or
//! none — never a torn frame (and torn frames would still be caught by the
//! codec checksum, see [`crate::kvcache::spill`]).
//!
//! The store is bounded by `max_bytes`: when a new snapshot would push the
//! running total past the bound, the **oldest** spilled sessions (by spill
//! order) are evicted until it fits — cold eviction is the real context
//! loss the paper warns against, so it is counted and surfaced in `stats`.
//! Session ids restart at every process launch, so snapshots from a
//! previous run could alias fresh ids; [`ColdStore::open`] therefore
//! removes every leftover file in its namespace (orphan GC) before
//! serving.

use std::collections::HashMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

struct ColdEntry {
    bytes: u64,
    /// Monotone spill order — the eviction clock.
    seq: u64,
}

/// Capacity-bounded directory of spilled session snapshots (one worker's
/// cold-tier namespace).
pub struct ColdStore {
    dir: PathBuf,
    /// Byte bound on the directory (0 = unbounded).
    max_bytes: u64,
    total_bytes: u64,
    entries: HashMap<u64, ColdEntry>,
    seq: u64,
    evictions: u64,
    orphans_removed: u64,
}

impl ColdStore {
    /// Open (creating if needed) the worker's namespace under `root` and
    /// GC any leftover snapshot files from a previous run.
    pub fn open(root: &Path, worker_id: usize, max_bytes: u64) -> io::Result<ColdStore> {
        let dir = root.join(format!("worker-{worker_id}"));
        fs::create_dir_all(&dir)?;
        let mut orphans_removed = 0u64;
        for entry in fs::read_dir(&dir)? {
            let entry = entry?;
            if entry.file_type()?.is_file() {
                fs::remove_file(entry.path())?;
                orphans_removed += 1;
            }
        }
        Ok(ColdStore {
            dir,
            max_bytes,
            total_bytes: 0,
            entries: HashMap::new(),
            seq: 0,
            evictions: 0,
            orphans_removed,
        })
    }

    fn path(&self, sid: u64) -> PathBuf {
        self.dir.join(format!("{sid}.snap"))
    }

    /// Spill a session's snapshot frame. Evicts the oldest cold sessions
    /// as needed to respect `max_bytes`; returns `Ok(false)` (nothing
    /// stored) when the frame alone exceeds the bound.
    pub fn put(&mut self, sid: u64, frame: &[u8]) -> io::Result<bool> {
        let len = frame.len() as u64;
        if self.max_bytes > 0 {
            if len > self.max_bytes {
                return Ok(false);
            }
            // Re-spilling an existing id replaces its bytes, so exclude
            // them from the pressure calculation.
            let replaced = self.entries.get(&sid).map(|e| e.bytes).unwrap_or(0);
            while self.total_bytes - replaced + len > self.max_bytes {
                let oldest = self
                    .entries
                    .iter()
                    .filter(|(&k, _)| k != sid)
                    .min_by_key(|(_, e)| e.seq)
                    .map(|(&k, _)| k);
                let Some(victim) = oldest else { break };
                self.remove(victim)?;
                self.evictions += 1;
            }
        }
        let tmp = self.dir.join(format!("{sid}.snap.tmp"));
        fs::write(&tmp, frame)?;
        fs::rename(&tmp, self.path(sid))?;
        if let Some(old) = self.entries.remove(&sid) {
            self.total_bytes -= old.bytes;
        }
        self.seq += 1;
        self.total_bytes += len;
        self.entries.insert(
            sid,
            ColdEntry {
                bytes: len,
                seq: self.seq,
            },
        );
        Ok(true)
    }

    /// Read and remove a session's snapshot. `Ok(None)` if the session is
    /// not in the cold tier.
    pub fn take(&mut self, sid: u64) -> io::Result<Option<Vec<u8>>> {
        let Some(e) = self.entries.remove(&sid) else {
            return Ok(None);
        };
        self.total_bytes -= e.bytes;
        let p = self.path(sid);
        let bytes = fs::read(&p)?;
        fs::remove_file(&p)?;
        Ok(Some(bytes))
    }

    /// Drop a session's snapshot without reading it. Returns whether it
    /// existed.
    pub fn remove(&mut self, sid: u64) -> io::Result<bool> {
        let Some(e) = self.entries.remove(&sid) else {
            return Ok(false);
        };
        self.total_bytes -= e.bytes;
        fs::remove_file(self.path(sid))?;
        Ok(true)
    }

    pub fn contains(&self, sid: u64) -> bool {
        self.entries.contains_key(&sid)
    }

    /// Number of spilled sessions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes currently on disk across all snapshots.
    pub fn bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Cold-tier evictions (capacity pressure) since open — each one is a
    /// lost session context.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Leftover files from previous runs removed at open.
    pub fn orphans_removed(&self) -> u64 {
        self.orphans_removed
    }

    /// The namespace directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    static TEST_SEQ: AtomicU64 = AtomicU64::new(0);

    /// Unique per-test scratch root under the OS temp dir.
    fn tmp_root(tag: &str) -> PathBuf {
        let n = TEST_SEQ.fetch_add(1, Ordering::SeqCst);
        let p = std::env::temp_dir().join(format!(
            "mikv-cold-test-{}-{n}-{tag}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn put_take_round_trip_with_accounting() {
        let root = tmp_root("roundtrip");
        let mut c = ColdStore::open(&root, 0, 0).unwrap();
        assert!(c.is_empty());
        assert!(c.put(7, b"snapshot-seven").unwrap());
        assert!(c.put(9, b"nine").unwrap());
        assert_eq!(c.len(), 2);
        assert_eq!(c.bytes(), 14 + 4);
        assert!(c.contains(7));
        assert!(c.dir().join("7.snap").exists());
        assert!(!c.dir().join("7.snap.tmp").exists(), "tmp renamed away");

        assert_eq!(c.take(7).unwrap().as_deref(), Some(&b"snapshot-seven"[..]));
        assert_eq!(c.bytes(), 4);
        assert!(!c.contains(7));
        assert!(!c.dir().join("7.snap").exists());
        assert_eq!(c.take(7).unwrap(), None, "take is destructive");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn replacing_a_snapshot_does_not_double_count() {
        let root = tmp_root("replace");
        let mut c = ColdStore::open(&root, 0, 0).unwrap();
        assert!(c.put(1, &[0u8; 100]).unwrap());
        assert!(c.put(1, &[0u8; 40]).unwrap());
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 40);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn capacity_bound_evicts_oldest_first() {
        let root = tmp_root("bound");
        let mut c = ColdStore::open(&root, 0, 100).unwrap();
        assert!(c.put(1, &[0u8; 40]).unwrap());
        assert!(c.put(2, &[0u8; 40]).unwrap());
        // 40+40+40 > 100 → session 1 (oldest) is evicted
        assert!(c.put(3, &[0u8; 40]).unwrap());
        assert_eq!(c.evictions(), 1);
        assert!(!c.contains(1));
        assert!(c.contains(2) && c.contains(3));
        assert_eq!(c.bytes(), 80);

        // a frame larger than the whole bound is refused, nothing evicted
        assert!(!c.put(4, &[0u8; 200]).unwrap());
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn open_gcs_orphans_and_namespaces_by_worker() {
        let root = tmp_root("gc");
        {
            let mut a = ColdStore::open(&root, 0, 0).unwrap();
            let mut b = ColdStore::open(&root, 1, 0).unwrap();
            a.put(5, b"stale").unwrap();
            b.put(5, b"other-worker").unwrap();
        }
        // same root, same worker id: the stale snapshot must be GC'd
        let c = ColdStore::open(&root, 0, 0).unwrap();
        assert_eq!(c.orphans_removed(), 1);
        assert!(c.is_empty());
        assert!(!c.dir().join("5.snap").exists());
        // the other worker's namespace was untouched
        assert!(root.join("worker-1").join("5.snap").exists());
        let _ = fs::remove_dir_all(&root);
    }
}
