//! Admission scheduler for the sharded serving runtime.
//!
//! The single-loop prototype funnelled every op through one
//! [`Coordinator`]; this module scales that across **N engine workers**,
//! each a [`Coordinator`] on its own thread owning its own engine and
//! [`crate::kvcache::BufferPool`]. The [`Scheduler`] is the admission
//! layer in front of them:
//!
//! * **Placement** — fresh `generate`s go to the least-loaded worker
//!   (in-flight submits tracked per worker; ties break to the lowest
//!   index, so placement is deterministic for a given arrival order).
//! * **Session→worker affinity** — workers assign session ids from
//!   disjoint strides (`(sid - 1) % n_workers == worker`), so an `append`
//!   routes to the worker holding that session's parked cache by pure
//!   arithmetic ([`worker_of_session`]) — no shared registry, no locks on
//!   the submit path.
//! * **Backpressure** — a worker with `max_waiting` submits in flight
//!   rejects further admissions with the existing `overloaded` wire error
//!   before the op ever crosses a channel (the largest cap that can never
//!   make the worker's own queue bound fire spuriously).
//! * **Fan-out ops** — `cancel` and `stats` broadcast to every worker;
//!   per-worker answers are merged by aggregate sinks into the single
//!   reply the client expects (`found` OR-ed, snapshots merged with
//!   per-worker rows, see [`StatsSnapshot::merged`]).
//!
//! Worker results flow back through each request's own [`EventSink`]
//! (for TCP: the connection's writer channel), so the scheduler is never
//! on the token-streaming path — it only places work.
//!
//! `Scheduler::start(1, ...)` is behaviourally the old single-loop
//! deployment: one worker, stride 1, every op forwarded.

use super::batcher::{Coordinator, CoordinatorConfig, StepEngine};
use super::request::{ErrorCode, EventSink, Op, Reply, Request, Response, ServeEvent, WireError};
use super::stats::StatsSnapshot;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// The worker that owns session `sid` under the stride contract
/// (`Coordinator::for_worker` assigns `w+1, w+1+N, w+1+2N, ...`).
pub fn worker_of_session(sid: u64, n_workers: usize) -> usize {
    let n = n_workers.max(1) as u64;
    (sid.max(1).wrapping_sub(1) % n) as usize
}

/// Counts a worker's in-flight submits so the Done event decrements what
/// dispatch incremented — the scheduler's only view of worker load.
struct TrackedSink {
    inner: Reply,
    loads: Arc<Vec<AtomicUsize>>,
    worker: usize,
}

impl EventSink for TrackedSink {
    fn emit(&self, ev: ServeEvent) -> bool {
        let terminal = matches!(ev, ServeEvent::Done(_));
        let ok = self.inner.emit(ev);
        if terminal {
            // `worker` was a valid index into this same `loads` vec when
            // the sink was built, and the vec is never resized.
            if let Some(load) = self.loads.get(self.worker) {
                load.fetch_sub(1, Ordering::AcqRel);
            }
        }
        ok
    }
}

/// Aggregates the per-worker answers to a broadcast `cancel` into the one
/// `CancelResult` the client expects (`found` is OR-ed across workers).
/// The client's reply sink sits behind the mutex because `Box<dyn
/// EventSink>` is `Send` but not `Sync`; the lock is taken once per worker
/// answer, never on a token path.
struct CancelFanout {
    id: u64,
    target: u64,
    state: Mutex<CancelState>,
}

struct CancelState {
    /// Taken (and consumed) by whichever worker answer arrives last.
    reply: Option<Reply>,
    remaining: usize,
    found: bool,
}

struct CancelShard(Arc<CancelFanout>);

impl EventSink for CancelShard {
    fn emit(&self, ev: ServeEvent) -> bool {
        if let ServeEvent::CancelResult { found, .. } = ev {
            // A poisoned fanout must not take the writer thread down with
            // it; the state is a counter + flag, always valid.
            let mut state = self
                .0
                .state
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            state.found |= found;
            state.remaining -= 1;
            if state.remaining == 0 {
                let found = state.found;
                if let Some(reply) = state.reply.take() {
                    return reply.emit(ServeEvent::CancelResult {
                        id: self.0.id,
                        target: self.0.target,
                        found,
                    });
                }
            }
        }
        true
    }
}

/// Aggregates the per-worker answers to a broadcast `stats` into one
/// merged snapshot carrying the per-worker rows.
struct StatsFanout {
    id: u64,
    state: Mutex<StatsState>,
}

struct StatsState {
    reply: Option<Reply>,
    parts: Vec<StatsSnapshot>,
    remaining: usize,
}

struct StatsShard(Arc<StatsFanout>);

impl EventSink for StatsShard {
    fn emit(&self, ev: ServeEvent) -> bool {
        if let ServeEvent::Stats { snapshot, .. } = ev {
            // Same poison policy as CancelShard: merged stats stay
            // answerable even if another emitter panicked mid-lock.
            let mut state = self
                .0
                .state
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            state.parts.push(snapshot);
            state.remaining -= 1;
            if state.remaining == 0 {
                let merged = StatsSnapshot::merged(std::mem::take(&mut state.parts));
                if let Some(reply) = state.reply.take() {
                    return reply.emit(ServeEvent::Stats {
                        id: self.0.id,
                        snapshot: merged,
                    });
                }
            }
        }
        true
    }
}

/// The sharded serving runtime: N worker threads behind one admission
/// loop. Build with [`Scheduler::start`], then hand the op channel to
/// [`Scheduler::run`] (or [`Scheduler::run_until`]) on the calling thread.
pub struct Scheduler {
    txs: Vec<Sender<Op>>,
    /// In-flight submits per worker (incremented at dispatch, decremented
    /// by the [`TrackedSink`] when the terminal event passes through).
    loads: Arc<Vec<AtomicUsize>>,
    handles: Vec<JoinHandle<()>>,
    cfg: CoordinatorConfig,
}

impl Scheduler {
    /// Spawn `n_workers` engine workers. `factory(w)` runs **on worker
    /// `w`'s own thread** — engines whose handles are not `Send` (PJRT)
    /// are constructed where they live. `start` returns once every worker
    /// reported its engine ready, or the first construction error.
    pub fn start<E, F>(
        n_workers: usize,
        cfg: CoordinatorConfig,
        factory: F,
    ) -> crate::Result<Scheduler>
    where
        E: StepEngine + 'static,
        F: Fn(usize) -> crate::Result<E> + Send + Sync + 'static,
    {
        anyhow::ensure!(n_workers >= 1, "need at least one worker");
        let factory = Arc::new(factory);
        let loads: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n_workers).map(|_| AtomicUsize::new(0)).collect());
        let (ready_tx, ready_rx) = channel::<crate::Result<()>>();
        let mut txs = Vec::with_capacity(n_workers);
        let mut handles = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = channel::<Op>();
            txs.push(tx);
            let cfg_w = cfg.clone();
            let factory = factory.clone();
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("mikv-worker-{w}"))
                .spawn(move || {
                    let engine = match factory(w) {
                        Ok(engine) => {
                            let _ = ready.send(Ok(()));
                            engine
                        }
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    Coordinator::for_worker(engine, cfg_w, w, n_workers).run(rx);
                })
                .map_err(|e| anyhow::anyhow!("spawn worker thread: {e}"))?;
            handles.push(handle);
        }
        drop(ready_tx);
        for _ in 0..n_workers {
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker exited before reporting readiness"))??;
        }
        crate::log_info!("scheduler started with {n_workers} worker(s)");
        Ok(Scheduler {
            txs,
            loads,
            handles,
            cfg,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.txs.len()
    }

    /// Serve until the op channel closes, then drain and join the workers.
    pub fn run(self, rx: Receiver<Op>) {
        self.run_until(rx, || false)
    }

    /// Like [`Self::run`], but also stops once `stop()` returns true
    /// (checked between ops) — used when the shutdown signal is something
    /// other than channel closure (e.g. a finished test client).
    pub fn run_until(mut self, rx: Receiver<Op>, stop: impl Fn() -> bool) {
        let idle = self.cfg.idle_poll;
        loop {
            match rx.recv_timeout(idle) {
                Ok(op) => self.dispatch(op),
                Err(RecvTimeoutError::Timeout) => {
                    if stop() {
                        // Dispatch anything that raced the stop signal so
                        // no accepted op is silently dropped.
                        while let Ok(op) = rx.try_recv() {
                            self.dispatch(op);
                        }
                        break;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Closing the worker channels lets each worker drain its in-flight
        // turns and exit.
        self.txs.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
        crate::log_info!("scheduler drained, all workers joined");
    }

    /// Place one op. Submits go to one worker (affinity for appends,
    /// least-loaded otherwise); cancel/stats broadcast with aggregation.
    fn dispatch(&self, op: Op) {
        match op {
            Op::Submit(req) => self.dispatch_submit(req),
            Op::Cancel { id, target, reply } => {
                let fanout = Arc::new(CancelFanout {
                    id,
                    target,
                    state: Mutex::new(CancelState {
                        reply: Some(reply),
                        remaining: self.txs.len(),
                        found: false,
                    }),
                });
                for tx in &self.txs {
                    if let Err(send_err) = tx.send(Op::Cancel {
                        id,
                        target,
                        reply: Box::new(CancelShard(fanout.clone())),
                    }) {
                        // Worker gone: account it as answered-not-found so
                        // the aggregate reply still fires.
                        if let Op::Cancel { reply, .. } = send_err.0 {
                            let _ = reply.emit(ServeEvent::CancelResult {
                                id,
                                target,
                                found: false,
                            });
                        }
                    }
                }
            }
            Op::Stats { id, reply } => {
                let fanout = Arc::new(StatsFanout {
                    id,
                    state: Mutex::new(StatsState {
                        reply: Some(reply),
                        parts: Vec::new(),
                        remaining: self.txs.len(),
                    }),
                });
                for tx in &self.txs {
                    if let Err(send_err) = tx.send(Op::Stats {
                        id,
                        reply: Box::new(StatsShard(fanout.clone())),
                    }) {
                        if let Op::Stats { reply, .. } = send_err.0 {
                            let _ = reply.emit(ServeEvent::Stats {
                                id,
                                snapshot: StatsSnapshot::default(),
                            });
                        }
                    }
                }
            }
        }
    }

    fn dispatch_submit(&self, req: Request) {
        let w = match req.session {
            // Affinity: the append must land on the worker holding the
            // session's parked cache.
            Some(sid) => worker_of_session(sid, self.txs.len()),
            None => self.least_loaded(),
        };
        // Cap in-flight at `max_waiting` per worker. This is the largest
        // bound that can never trip the worker's own queue check
        // spuriously: with ≤ max_waiting ops in flight (channel + queued +
        // active), the worker's waiting queue is strictly below
        // `max_waiting` whenever a new op is drained, so a client is never
        // told `overloaded` while the runtime is under its advertised
        // capacity. (A cap of max_waiting + max_active would over-admit
        // right after a retire wave: retires free scheduler slots before
        // the worker's next admit pass shrinks its queue.) With a single
        // worker the scheduler imposes no cap of its own — the worker's
        // queue bound alone governs, exactly as in the pre-sharding
        // deployment.
        let cap = self.cfg.max_waiting;
        // `w` comes from `worker_of_session` / `least_loaded`, both of
        // which only produce indices below the worker count; answer a
        // structured error rather than indexing on faith.
        let Some(tx) = self.txs.get(w) else {
            let err = WireError::internal(format!("worker {w} unavailable"));
            let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
            return;
        };
        let at_capacity = self.txs.len() > 1
            && self
                .loads
                .get(w)
                .is_some_and(|l| l.load(Ordering::Acquire) >= cap);
        if at_capacity {
            let err = WireError::new(
                ErrorCode::Overloaded,
                format!("worker {w} at capacity ({cap} requests in flight)"),
            );
            let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
            return;
        }
        if let Some(load) = self.loads.get(w) {
            load.fetch_add(1, Ordering::AcqRel);
        }
        let req = Request {
            reply: Box::new(TrackedSink {
                inner: req.reply,
                loads: self.loads.clone(),
                worker: w,
            }),
            ..req
        };
        if let Err(send_err) = tx.send(Op::Submit(req)) {
            // Worker gone (only during shutdown). Answer through the
            // tracked sink so the load count is released.
            if let Op::Submit(r) = send_err.0 {
                let err = WireError::internal(format!("worker {w} unavailable"));
                let _ = r.reply.emit(ServeEvent::Done(Response::error(r.id, err)));
            }
        }
    }

    /// Deterministic placement: least in-flight submits, ties to the
    /// lowest worker index.
    fn least_loaded(&self) -> usize {
        let mut best = 0usize;
        let mut best_load = usize::MAX;
        for (w, load) in self.loads.iter().enumerate() {
            let l = load.load(Ordering::Acquire);
            if l < best_load {
                best = w;
                best_load = l;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CompressionSpec, Response};
    use crate::model::StubEngine;
    use std::sync::mpsc;
    use std::time::Instant;

    fn start(n_workers: usize, cfg: CoordinatorConfig) -> Scheduler {
        let base = StubEngine::new(StubEngine::test_dims(64));
        Scheduler::start(n_workers, cfg, move |w| Ok(base.fork(w))).unwrap()
    }

    fn submit(
        id: u64,
        session: Option<u64>,
        keep: bool,
        reply: &mpsc::Sender<ServeEvent>,
    ) -> Op {
        Op::Submit(Request {
            id,
            prompt: vec![1, 2, 3],
            max_new: 3,
            stop: None,
            spec: CompressionSpec::mikv(0.5, "int4"),
            session,
            keep,
            submitted_at: Instant::now(),
            reply: Box::new(reply.clone()),
        })
    }

    fn wait_done(rx: &mpsc::Receiver<ServeEvent>) -> Response {
        loop {
            if let ServeEvent::Done(r) = rx.recv().unwrap() {
                return r;
            }
        }
    }

    #[test]
    fn owner_arithmetic_matches_worker_stride() {
        // worker w of N assigns w+1, w+1+N, ... — invert it.
        for n in 1..=5usize {
            for w in 0..n {
                for k in 0..4u64 {
                    let sid = w as u64 + 1 + k * n as u64;
                    assert_eq!(worker_of_session(sid, n), w, "sid {sid} of {n}");
                }
            }
        }
        // degenerate inputs stay in range
        assert_eq!(worker_of_session(0, 4), 0);
        assert_eq!(worker_of_session(1, 1), 0);
    }

    /// End to end across 2 workers: a kept generate parks on some worker,
    /// the follow-up append routes back to it by session-id arithmetic and
    /// continues the same cache.
    #[test]
    fn append_routes_to_the_owning_worker() {
        let sched = start(2, CoordinatorConfig::default());
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit(1, None, true, &etx)).unwrap();
            let turn1 = wait_done(&erx);
            assert!(turn1.error.is_none(), "{:?}", turn1.error);
            let sid = turn1.session.expect("kept session");
            let occ1 = turn1.metrics.hi_slots + turn1.metrics.lo_slots;

            tx.send(submit(2, Some(sid), false, &etx)).unwrap();
            let turn2 = wait_done(&erx);
            assert!(turn2.error.is_none(), "{:?}", turn2.error);
            assert_eq!(turn2.session, Some(sid));
            let occ2 = turn2.metrics.hi_slots + turn2.metrics.lo_slots;
            assert!(occ2 > occ1, "cache carried over: {occ1} -> {occ2}");
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// Cancel of an unknown target broadcasts to every worker and folds
    /// into exactly one `found: false` answer.
    #[test]
    fn cancel_fanout_aggregates_to_one_answer() {
        let sched = start(4, CoordinatorConfig::default());
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(Op::Cancel {
                id: 1,
                target: 999,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            match erx.recv().unwrap() {
                ServeEvent::CancelResult { id, target, found } => {
                    assert_eq!((id, target, found), (1, 999, false));
                }
                other => panic!("unexpected {other:?}"),
            }
            drop(etx);
            // exactly one aggregated answer, not one per worker
            assert!(erx.recv().is_err(), "no second cancel answer");
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// Stats broadcasts merge into one snapshot with one row per worker.
    #[test]
    fn stats_fanout_merges_worker_rows() {
        let sched = start(3, CoordinatorConfig::default());
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit(1, None, false, &etx)).unwrap();
            let done = wait_done(&erx);
            assert!(done.error.is_none());

            tx.send(Op::Stats {
                id: 7,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            let snapshot = loop {
                if let ServeEvent::Stats { id, snapshot } = erx.recv().unwrap() {
                    assert_eq!(id, 7);
                    break snapshot;
                }
            };
            assert_eq!(snapshot.workers.len(), 3);
            let ids: Vec<usize> = snapshot.workers.iter().map(|w| w.worker).collect();
            assert_eq!(ids, vec![0, 1, 2]);
            assert_eq!(snapshot.completed, 1);
            let sum: usize = snapshot.workers.iter().map(|w| w.completed).sum();
            assert_eq!(sum, snapshot.completed);
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// Scheduler-side backpressure: with a zero-capacity config every
    /// submit is rejected `overloaded` before reaching a worker.
    #[test]
    fn backpressure_rejects_overloaded_at_admission() {
        let cfg = CoordinatorConfig {
            max_active: 0,
            max_waiting: 0,
            ..CoordinatorConfig::default()
        };
        let sched = start(2, cfg);
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit(1, None, false, &etx)).unwrap();
            let done = wait_done(&erx);
            let err = done.error.expect("rejected");
            assert_eq!(err.code, ErrorCode::Overloaded);
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }
}
