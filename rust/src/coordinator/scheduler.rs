//! Admission scheduler for the sharded serving runtime.
//!
//! The single-loop prototype funnelled every op through one
//! [`Coordinator`]; this module scales that across **N engine workers**,
//! each a [`Coordinator`] on its own thread owning its own engine and
//! [`crate::kvcache::BufferPool`]. The [`Scheduler`] is the admission
//! layer in front of them:
//!
//! * **Placement** — fresh `generate`s go to the least-loaded worker
//!   (in-flight submits tracked per worker; ties break to the lowest
//!   index, so placement is deterministic for a given arrival order).
//! * **Session→worker affinity** — workers assign session ids from
//!   disjoint strides (`(sid - 1) % n_workers == worker`), so an `append`
//!   routes to the worker holding that session's parked cache by pure
//!   arithmetic ([`worker_of_session`]) — no shared registry, no locks on
//!   the submit path.
//! * **Backpressure** — a worker with `max_waiting` submits in flight
//!   rejects further admissions with the existing `overloaded` wire error
//!   before the op ever crosses a channel (the largest cap that can never
//!   make the worker's own queue bound fire spuriously).
//! * **Fan-out ops** — `cancel` and `stats` broadcast to every worker;
//!   per-worker answers are merged by aggregate sinks into the single
//!   reply the client expects (`found` OR-ed, snapshots merged with
//!   per-worker rows, see [`StatsSnapshot::merged`]).
//! * **Supervision** — every worker loop runs under `catch_unwind` inside
//!   a respawn loop. A panicking worker (engine bug, injected fault) does
//!   not strand its clients: each in-flight op on that worker is tracked
//!   in a [`FlightRegistry`] and answered with a structured `internal`
//!   error by the supervisor's sweep, then a fresh engine takes over the
//!   same op channel. The replacement shares the dead life's
//!   [`WorkerVitals`], so its sid allocator resumes past the high-water
//!   mark and the cold tier re-opens in recovery mode — spilled sessions
//!   survive the crash and stay appendable. `worker_restarts` /
//!   `sessions_lost` (plus the workers' own `sessions_recovered`) surface
//!   through merged stats.
//!
//! Worker results flow back through each request's own [`EventSink`]
//! (for TCP: the connection's writer channel), so the scheduler is never
//! on the token-streaming path — it only places work.
//!
//! * **Multi-tenant QoS (opt-in)** — with a [`QosConfig`]
//!   ([`Scheduler::start_with_qos`]), admission runs through per-worker
//!   [`qos::DrrQueue`]s: deficit round-robin fair queuing keyed by tenant
//!   (the TCP connection id), an interactive lane strictly ahead of a
//!   batch lane, per-tenant token-bucket rate limits, and graceful
//!   shedding under backlog pressure — the newest *batch*-lane waiting
//!   turn is rejected first, then the newest interactive waiting turn,
//!   and active work is never evicted. QoS rejections reuse the
//!   `overloaded` error and carry a `retry_after_ms` backoff hint.
//!   Without a `QosConfig` (the default), none of this machinery is even
//!   constructed: admission is the historical FCFS forward, byte-identical
//!   on the wire — regression-locked by
//!   `backpressure_rejects_overloaded_at_admission`.
//!
//! `Scheduler::start(1, ...)` is behaviourally the old single-loop
//! deployment: one worker, stride 1, every op forwarded.

use super::batcher::{Coordinator, CoordinatorConfig, StepEngine, WorkerVitals};
use super::qos::{self, DrrQueue, QosConfig, RateLimiter};
use super::request::{
    ErrorCode, EventSink, Op, Priority, Reply, Request, Response, ServeEvent, WireError,
};
use super::stats::StatsSnapshot;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// The worker that owns session `sid` under the stride contract
/// (`Coordinator::for_worker` assigns `w+1, w+1+N, w+1+2N, ...`).
pub fn worker_of_session(sid: u64, n_workers: usize) -> usize {
    let n = n_workers.max(1) as u64;
    (sid.max(1).wrapping_sub(1) % n) as usize
}

/// Answer one op with the structured event a permanently dead worker owes
/// it — the supervisor's degraded terminal mode when an engine rebuild
/// fails (clients get errors, never silence).
fn fail_op(op: Op, worker: usize) {
    match op {
        Op::Submit(req) => {
            let err = WireError::internal(format!("worker {worker} unavailable"));
            let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
        }
        Op::Cancel { id, target, reply } => {
            let _ = reply.emit(ServeEvent::CancelResult {
                id,
                target,
                found: false,
            });
        }
        Op::Stats { id, reply } => {
            let _ = reply.emit(ServeEvent::Stats {
                id,
                snapshot: StatsSnapshot::default(),
            });
        }
    }
}

/// Counts a worker's in-flight submits so the Done event decrements what
/// dispatch incremented — the scheduler's only view of worker load.
struct TrackedSink {
    inner: Reply,
    loads: Arc<Vec<AtomicUsize>>,
    worker: usize,
}

impl EventSink for TrackedSink {
    fn emit(&self, ev: ServeEvent) -> bool {
        let terminal = matches!(ev, ServeEvent::Done(_));
        let ok = self.inner.emit(ev);
        if terminal {
            // `worker` was a valid index into this same `loads` vec when
            // the sink was built, and the vec is never resized.
            if let Some(load) = self.loads.get(self.worker) {
                load.fetch_sub(1, Ordering::AcqRel);
            }
        }
        ok
    }
}

/// What a supervised in-flight op owes its client, so the supervisor can
/// synthesize the right terminal event if the worker dies first.
enum FlightKind {
    Submit { id: u64 },
    Cancel { id: u64, target: u64 },
    Stats { id: u64 },
}

/// One op currently at (or en route to) a worker. The client's reply sink
/// lives in the shared `slot`: whoever takes it — the worker's terminal
/// event or the supervisor's post-panic sweep — answers the client, and
/// the other side finds the slot empty and stays silent. That exchange is
/// what guarantees exactly one terminal event per op across a crash.
struct Flight {
    what: FlightKind,
    slot: Arc<Mutex<Option<Reply>>>,
}

/// Per-worker ledger of supervised in-flight ops. Registered by the
/// dispatch paths, deregistered as terminal events pass through, drained
/// wholesale by [`Self::fail_all`] when the worker panics.
#[derive(Default)]
struct FlightRegistry {
    next_key: AtomicU64,
    flights: Mutex<HashMap<u64, Flight>>,
}

impl FlightRegistry {
    /// Wrap `reply` in a sink registered under a fresh key.
    fn track(self: &Arc<Self>, what: FlightKind, reply: Reply) -> Reply {
        let key = self.next_key.fetch_add(1, Ordering::AcqRel);
        let slot = Arc::new(Mutex::new(Some(reply)));
        self.flights
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .insert(
                key,
                Flight {
                    what,
                    slot: slot.clone(),
                },
            );
        Box::new(SupervisedSink {
            reg: self.clone(),
            key,
            slot,
        })
    }

    fn deregister(&self, key: u64) {
        self.flights
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .remove(&key);
    }

    /// Answer every still-open flight with a structured terminal event —
    /// the supervisor's post-panic sweep, so no client ever hangs on a
    /// dead worker. Returns how many flights were actually answered here
    /// (flights whose terminal already passed through are skipped).
    fn fail_all(&self, worker: usize) -> usize {
        let drained: Vec<Flight> = {
            let mut map = self
                .flights
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            map.drain().map(|(_, f)| f).collect()
        };
        let mut failed = 0usize;
        for f in drained {
            let taken = f
                .slot
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .take();
            let Some(reply) = taken else { continue };
            failed += 1;
            let ev = match f.what {
                FlightKind::Submit { id } => ServeEvent::Done(Response::error(
                    id,
                    WireError::internal(format!("worker {worker} restarted mid-request")),
                )),
                FlightKind::Cancel { id, target } => ServeEvent::CancelResult {
                    id,
                    target,
                    found: false,
                },
                FlightKind::Stats { id } => ServeEvent::Stats {
                    id,
                    snapshot: StatsSnapshot::default(),
                },
            };
            let _ = reply.emit(ev);
        }
        failed
    }
}

/// The sink a supervised op streams through. Non-terminal events forward
/// to the reply while it is still in the slot; the terminal event takes
/// the reply out (deregistering the flight) so the supervisor's sweep can
/// never answer the same op twice.
struct SupervisedSink {
    reg: Arc<FlightRegistry>,
    key: u64,
    slot: Arc<Mutex<Option<Reply>>>,
}

impl EventSink for SupervisedSink {
    fn emit(&self, ev: ServeEvent) -> bool {
        let terminal = matches!(
            ev,
            ServeEvent::Done(_) | ServeEvent::CancelResult { .. } | ServeEvent::Stats { .. }
        );
        if terminal {
            let taken = self
                .slot
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .take();
            self.reg.deregister(self.key);
            match taken {
                Some(reply) => reply.emit(ev),
                // The supervisor already answered after a worker panic;
                // swallow the late duplicate.
                None => false,
            }
        } else {
            let guard = self
                .slot
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            match guard.as_ref() {
                Some(reply) => reply.emit(ev),
                None => false,
            }
        }
    }
}

/// Aggregates the per-worker answers to a broadcast `cancel` into the one
/// `CancelResult` the client expects (`found` is OR-ed across workers).
/// The client's reply sink sits behind the mutex because `Box<dyn
/// EventSink>` is `Send` but not `Sync`; the lock is taken once per worker
/// answer, never on a token path.
struct CancelFanout {
    id: u64,
    target: u64,
    state: Mutex<CancelState>,
}

struct CancelState {
    /// Taken (and consumed) by whichever worker answer arrives last.
    reply: Option<Reply>,
    remaining: usize,
    found: bool,
}

struct CancelShard(Arc<CancelFanout>);

impl EventSink for CancelShard {
    fn emit(&self, ev: ServeEvent) -> bool {
        if let ServeEvent::CancelResult { found, .. } = ev {
            // A poisoned fanout must not take the writer thread down with
            // it; the state is a counter + flag, always valid.
            let mut state = self
                .0
                .state
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            state.found |= found;
            state.remaining -= 1;
            if state.remaining == 0 {
                let found = state.found;
                if let Some(reply) = state.reply.take() {
                    return reply.emit(ServeEvent::CancelResult {
                        id: self.0.id,
                        target: self.0.target,
                        found,
                    });
                }
            }
        }
        true
    }
}

/// Aggregates the per-worker answers to a broadcast `stats` into one
/// merged snapshot carrying the per-worker rows. The scheduler's own
/// admission-side view (in-flight submits per worker, queued QoS turns,
/// shed/rate-limit counters) is injected at fold time — workers cannot see
/// ops still between the scheduler and their channel, which is exactly the
/// window that matters when overloaded.
struct StatsFanout {
    id: u64,
    loads: Arc<Vec<AtomicUsize>>,
    counters: Arc<SchedCounters>,
    /// Turns waiting in the scheduler's DRR queues at broadcast time
    /// (0 without QoS).
    qos_queued: usize,
    state: Mutex<StatsState>,
}

struct StatsState {
    reply: Option<Reply>,
    parts: Vec<StatsSnapshot>,
    remaining: usize,
}

struct StatsShard(Arc<StatsFanout>);

impl EventSink for StatsShard {
    fn emit(&self, ev: ServeEvent) -> bool {
        if let ServeEvent::Stats { snapshot, .. } = ev {
            // Same poison policy as CancelShard: merged stats stay
            // answerable even if another emitter panicked mid-lock.
            let mut state = self
                .0
                .state
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            state.parts.push(snapshot);
            state.remaining -= 1;
            if state.remaining == 0 {
                let mut merged = StatsSnapshot::merged(std::mem::take(&mut state.parts));
                for row in &mut merged.workers {
                    row.admitted_in_flight = self
                        .0
                        .loads
                        .get(row.worker)
                        .map_or(0, |l| l.load(Ordering::Acquire));
                }
                merged.admitted_in_flight = self
                    .0
                    .loads
                    .iter()
                    .map(|l| l.load(Ordering::Acquire))
                    .sum();
                merged.qos_queued = self.0.qos_queued;
                merged.shed_batch = self.0.counters.shed_batch.load(Ordering::Acquire);
                merged.shed_interactive =
                    self.0.counters.shed_interactive.load(Ordering::Acquire);
                merged.rate_limited = self.0.counters.rate_limited.load(Ordering::Acquire);
                merged.worker_restarts =
                    self.0.counters.worker_restarts.load(Ordering::Acquire);
                merged.sessions_lost = self.0.counters.sessions_lost.load(Ordering::Acquire);
                if let Some(reply) = state.reply.take() {
                    return reply.emit(ServeEvent::Stats {
                        id: self.0.id,
                        snapshot: merged,
                    });
                }
            }
        }
        true
    }
}

/// Monotonic QoS shed/rate-limit counters, surfaced through merged stats
/// snapshots. All-zero (and never incremented) without a [`QosConfig`].
#[derive(Default)]
struct SchedCounters {
    shed_batch: AtomicU64,
    shed_interactive: AtomicU64,
    rate_limited: AtomicU64,
    /// Worker panics survived: each is one `catch_unwind` + engine rebuild
    /// + cold-tier recovery cycle in a supervisor loop.
    worker_restarts: AtomicU64,
    /// Hot-parked sessions unwound with a panicking worker (their KV state
    /// is gone; a later `append` reports `session_not_found`).
    sessions_lost: AtomicU64,
}

/// QoS admission state — only constructed when a [`QosConfig`] was
/// supplied at start. One DRR queue per worker; one rate limiter shared
/// across workers (tenant buckets are global, placement is not).
struct QosState {
    cfg: QosConfig,
    queues: Vec<DrrQueue>,
    limiter: Option<RateLimiter>,
}

/// The sharded serving runtime: N worker threads behind one admission
/// loop. Build with [`Scheduler::start`], then hand the op channel to
/// [`Scheduler::run`] (or [`Scheduler::run_until`]) on the calling thread.
pub struct Scheduler {
    txs: Vec<Sender<Op>>,
    /// In-flight submits per worker (incremented at dispatch, decremented
    /// by the [`TrackedSink`] when the terminal event passes through).
    loads: Arc<Vec<AtomicUsize>>,
    handles: Vec<JoinHandle<()>>,
    cfg: CoordinatorConfig,
    /// `Some` = QoS admission (DRR fair queuing, lanes, shedding, rate
    /// limits); `None` = historical FCFS forward, regression-locked.
    qos: Option<QosState>,
    counters: Arc<SchedCounters>,
    /// Per-worker ledgers of supervised in-flight ops (see
    /// [`FlightRegistry`]): every op dispatched to worker `w` is tracked in
    /// `flights[w]` until its terminal event passes through.
    flights: Vec<Arc<FlightRegistry>>,
}

impl Scheduler {
    /// Spawn `n_workers` engine workers. `factory(w)` runs **on worker
    /// `w`'s own thread** — engines whose handles are not `Send` (PJRT)
    /// are constructed where they live. `start` returns once every worker
    /// reported its engine ready, or the first construction error.
    pub fn start<E, F>(
        n_workers: usize,
        cfg: CoordinatorConfig,
        factory: F,
    ) -> crate::Result<Scheduler>
    where
        E: StepEngine + 'static,
        F: Fn(usize) -> crate::Result<E> + Send + Sync + 'static,
    {
        Self::start_with_qos(n_workers, cfg, None, factory)
    }

    /// [`Self::start`] plus an optional multi-tenant QoS layer. `None`
    /// is exactly `start`: the QoS machinery is not even constructed and
    /// admission stays byte-identical FCFS.
    pub fn start_with_qos<E, F>(
        n_workers: usize,
        cfg: CoordinatorConfig,
        qos: Option<QosConfig>,
        factory: F,
    ) -> crate::Result<Scheduler>
    where
        E: StepEngine + 'static,
        F: Fn(usize) -> crate::Result<E> + Send + Sync + 'static,
    {
        anyhow::ensure!(n_workers >= 1, "need at least one worker");
        let factory = Arc::new(factory);
        let loads: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n_workers).map(|_| AtomicUsize::new(0)).collect());
        let counters = Arc::new(SchedCounters::default());
        let (ready_tx, ready_rx) = channel::<crate::Result<()>>();
        let mut txs = Vec::with_capacity(n_workers);
        let mut handles = Vec::with_capacity(n_workers);
        let mut flights = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = channel::<Op>();
            txs.push(tx);
            let reg = Arc::new(FlightRegistry::default());
            flights.push(reg.clone());
            let vitals = Arc::new(WorkerVitals::default());
            let cfg_w = cfg.clone();
            let factory = factory.clone();
            let ready = ready_tx.clone();
            let counters_w = counters.clone();
            let handle = std::thread::Builder::new()
                .name(format!("mikv-worker-{w}"))
                .spawn(move || {
                    let engine = match factory(w) {
                        Ok(engine) => {
                            let _ = ready.send(Ok(()));
                            engine
                        }
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    // The supervisor loop: each pass runs one coordinator
                    // life over the SAME op channel. A panic (engine bug,
                    // injected fault) is caught, every in-flight client is
                    // answered with a structured `internal` error, and a
                    // fresh engine takes over the channel — with the dead
                    // life's vitals, so the sid allocator resumes past its
                    // high-water mark and the cold tier is re-opened in
                    // recovery mode (spilled sessions stay appendable).
                    let mut engine = Some(engine);
                    loop {
                        let Some(e) = engine.take() else { break };
                        let coord = Coordinator::for_worker(e, cfg_w.clone(), w, n_workers)
                            .with_vitals(vitals.clone());
                        let life = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || coord.run_ref(&rx),
                        ));
                        drop(coord);
                        match life {
                            // Channel closed and drained: normal shutdown.
                            Ok(()) => break,
                            Err(_) => {
                                counters_w.worker_restarts.fetch_add(1, Ordering::AcqRel);
                                let lost = vitals.hot_parked.swap(0, Ordering::AcqRel);
                                counters_w
                                    .sessions_lost
                                    .fetch_add(lost as u64, Ordering::AcqRel);
                                let failed = reg.fail_all(w);
                                vitals.recover.store(true, Ordering::Release);
                                crate::log_error!(
                                    "worker {w} panicked; failed {failed} in-flight op(s), \
                                     lost {lost} hot-parked session(s), respawning"
                                );
                                match factory(w) {
                                    Ok(fresh) => engine = Some(fresh),
                                    Err(e) => {
                                        crate::log_error!(
                                            "worker {w} respawn failed: {e}; serving \
                                             structured errors until shutdown"
                                        );
                                        // Degraded terminal mode: never let
                                        // clients hang on a dead worker.
                                        while let Ok(op) = rx.recv() {
                                            fail_op(op, w);
                                        }
                                        break;
                                    }
                                }
                            }
                        }
                    }
                })
                .map_err(|e| anyhow::anyhow!("spawn worker thread: {e}"))?;
            handles.push(handle);
        }
        drop(ready_tx);
        for _ in 0..n_workers {
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker exited before reporting readiness"))??;
        }
        let qos = qos.map(|qcfg| QosState {
            queues: (0..n_workers).map(|_| DrrQueue::new()).collect(),
            limiter: qcfg.rate.map(|r| RateLimiter::new(r, qcfg.burst)),
            cfg: qcfg,
        });
        crate::log_info!(
            "scheduler started with {n_workers} worker(s), qos {}",
            if qos.is_some() { "on" } else { "off" }
        );
        Ok(Scheduler {
            txs,
            loads,
            handles,
            cfg,
            qos,
            counters,
            flights,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.txs.len()
    }

    /// Serve until the op channel closes, then drain and join the workers.
    pub fn run(self, rx: Receiver<Op>) {
        self.run_until(rx, || false)
    }

    /// Like [`Self::run`], but also stops once `stop()` returns true
    /// (checked between ops) — used when the shutdown signal is something
    /// other than channel closure (e.g. a finished test client).
    pub fn run_until(mut self, rx: Receiver<Op>, stop: impl Fn() -> bool) {
        let idle = self.cfg.idle_poll;
        // While QoS queues hold work the loop polls fast, so a worker slot
        // freed by a Done is refilled within ~a millisecond instead of
        // waiting out a full idle tick. Without QoS the queues are always
        // empty and the historical cadence is unchanged.
        let busy = idle.min(Duration::from_millis(1));
        loop {
            let timeout = if self.queued_total() > 0 { busy } else { idle };
            match rx.recv_timeout(timeout) {
                Ok(op) => self.dispatch(op),
                Err(RecvTimeoutError::Timeout) => {
                    if stop() {
                        // Dispatch anything that raced the stop signal so
                        // no accepted op is silently dropped.
                        while let Ok(op) = rx.try_recv() {
                            self.dispatch(op);
                        }
                        break;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
            self.pump();
        }
        // Shutdown: forward whatever the DRR queues still hold so no
        // accepted turn is silently dropped (the workers' own queue bounds
        // govern from here), then close the worker channels so each worker
        // drains its in-flight turns and exits.
        self.flush_queues();
        self.txs.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
        crate::log_info!("scheduler drained, all workers joined");
    }

    /// Place one op. Submits go to one worker (affinity for appends,
    /// least-loaded otherwise); cancel/stats broadcast with aggregation.
    fn dispatch(&mut self, op: Op) {
        match op {
            Op::Submit(req) => self.dispatch_submit(req),
            Op::Cancel { id, target, reply } => {
                // A turn still waiting in a DRR queue never reached a
                // worker — answer the cancel here and release the queued
                // turn's reply, no broadcast needed.
                if let Some(queued) = self
                    .qos
                    .as_mut()
                    .and_then(|q| q.queues.iter_mut().find_map(|d| d.take_by_id(target)))
                {
                    let _ = queued
                        .reply
                        .emit(ServeEvent::Done(Response::cancelled(queued.id)));
                    let _ = reply.emit(ServeEvent::CancelResult {
                        id,
                        target,
                        found: true,
                    });
                    return;
                }
                let fanout = Arc::new(CancelFanout {
                    id,
                    target,
                    state: Mutex::new(CancelState {
                        reply: Some(reply),
                        remaining: self.txs.len(),
                        found: false,
                    }),
                });
                for (w, tx) in self.txs.iter().enumerate() {
                    let shard: Reply = Box::new(CancelShard(fanout.clone()));
                    let reply = match self.flights.get(w) {
                        Some(reg) => reg.track(FlightKind::Cancel { id, target }, shard),
                        None => shard,
                    };
                    if let Err(send_err) = tx.send(Op::Cancel { id, target, reply }) {
                        // Worker gone: account it as answered-not-found so
                        // the aggregate reply still fires.
                        if let Op::Cancel { reply, .. } = send_err.0 {
                            let _ = reply.emit(ServeEvent::CancelResult {
                                id,
                                target,
                                found: false,
                            });
                        }
                    }
                }
            }
            Op::Stats { id, reply } => {
                let fanout = Arc::new(StatsFanout {
                    id,
                    loads: self.loads.clone(),
                    counters: self.counters.clone(),
                    qos_queued: self.queued_total(),
                    state: Mutex::new(StatsState {
                        reply: Some(reply),
                        parts: Vec::new(),
                        remaining: self.txs.len(),
                    }),
                });
                for (w, tx) in self.txs.iter().enumerate() {
                    let shard: Reply = Box::new(StatsShard(fanout.clone()));
                    let reply = match self.flights.get(w) {
                        Some(reg) => reg.track(FlightKind::Stats { id }, shard),
                        None => shard,
                    };
                    if let Err(send_err) = tx.send(Op::Stats { id, reply }) {
                        if let Op::Stats { reply, .. } = send_err.0 {
                            let _ = reply.emit(ServeEvent::Stats {
                                id,
                                snapshot: StatsSnapshot::default(),
                            });
                        }
                    }
                }
            }
        }
    }

    fn dispatch_submit(&mut self, req: Request) {
        if self.qos.is_some() {
            self.qos_submit(req);
        } else {
            self.fcfs_submit(req);
        }
    }

    /// The historical admission path: forward to the worker immediately,
    /// bounded only by the per-worker in-flight cap. Regression-locked to
    /// stay byte-identical on the wire when no QoS config is supplied.
    fn fcfs_submit(&self, req: Request) {
        let w = match req.session {
            // Affinity: the append must land on the worker holding the
            // session's parked cache.
            Some(sid) => worker_of_session(sid, self.txs.len()),
            None => self.least_loaded(),
        };
        // Cap in-flight at `max_waiting` per worker. This is the largest
        // bound that can never trip the worker's own queue check
        // spuriously: with ≤ max_waiting ops in flight (channel + queued +
        // active), the worker's waiting queue is strictly below
        // `max_waiting` whenever a new op is drained, so a client is never
        // told `overloaded` while the runtime is under its advertised
        // capacity. (A cap of max_waiting + max_active would over-admit
        // right after a retire wave: retires free scheduler slots before
        // the worker's next admit pass shrinks its queue.) With a single
        // worker the scheduler imposes no cap of its own — the worker's
        // queue bound alone governs, exactly as in the pre-sharding
        // deployment.
        let cap = self.cfg.max_waiting;
        // `w` comes from `worker_of_session` / `least_loaded`, both of
        // which only produce indices below the worker count; answer a
        // structured error rather than indexing on faith.
        let Some(tx) = self.txs.get(w) else {
            let err = WireError::internal(format!("worker {w} unavailable"));
            let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
            return;
        };
        let at_capacity = self.txs.len() > 1
            && self
                .loads
                .get(w)
                .is_some_and(|l| l.load(Ordering::Acquire) >= cap);
        if at_capacity {
            let err = WireError::new(
                ErrorCode::Overloaded,
                format!("worker {w} at capacity ({cap} requests in flight)"),
            );
            let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
            return;
        }
        if let Some(load) = self.loads.get(w) {
            load.fetch_add(1, Ordering::AcqRel);
        }
        let id = req.id;
        let tracked: Reply = Box::new(TrackedSink {
            inner: req.reply,
            loads: self.loads.clone(),
            worker: w,
        });
        // Supervision wraps OUTSIDE the load tracker: a post-panic sweep
        // answers through the tracked sink, releasing the load slot too.
        let reply = match self.flights.get(w) {
            Some(reg) => reg.track(FlightKind::Submit { id }, tracked),
            None => tracked,
        };
        let req = Request { reply, ..req };
        if let Err(send_err) = tx.send(Op::Submit(req)) {
            // Worker gone (only during shutdown). Answer through the
            // tracked sink so the load count is released.
            if let Op::Submit(r) = send_err.0 {
                let err = WireError::internal(format!("worker {w} unavailable"));
                let _ = r.reply.emit(ServeEvent::Done(Response::error(r.id, err)));
            }
        }
    }

    /// Deterministic placement: least in-flight submits, ties to the
    /// lowest worker index.
    fn least_loaded(&self) -> usize {
        let mut best = 0usize;
        let mut best_load = usize::MAX;
        for (w, load) in self.loads.iter().enumerate() {
            let l = load.load(Ordering::Acquire);
            if l < best_load {
                best = w;
                best_load = l;
            }
        }
        best
    }

    /// QoS admission: token-bucket check, backlog bound with
    /// cheapest-first shedding, then DRR enqueue + an immediate pump. The
    /// shed order is: the arrival itself if it is batch-lane (or
    /// interactive with no batch work waiting — it is then the newest turn
    /// in the lane that sheds first), otherwise the newest waiting
    /// batch-lane turn. Active (dispatched) work is never evicted.
    fn qos_submit(&mut self, req: Request) {
        let w = match req.session {
            Some(sid) => worker_of_session(sid, self.txs.len()),
            None => self.least_backlogged(),
        };
        if self.txs.get(w).is_none() {
            let err = WireError::internal(format!("worker {w} unavailable"));
            let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
            return;
        }
        let (hint, max_backlog) = match self.qos.as_ref() {
            Some(q) => (q.cfg.retry_after_ms, q.cfg.max_backlog.max(1)),
            // qos_submit is only reached when QoS state exists.
            None => return,
        };
        let cost = qos::turn_cost(req.prompt.len(), req.max_new);
        let limited = self
            .qos
            .as_mut()
            .and_then(|q| q.limiter.as_mut())
            .and_then(|l| l.try_admit(req.tenant, cost, Instant::now()).err());
        if let Some(wait_ms) = limited {
            self.counters.rate_limited.fetch_add(1, Ordering::AcqRel);
            let err = WireError::new(
                ErrorCode::Overloaded,
                format!("tenant {} over admission rate limit", req.tenant),
            )
            .with_retry_after(wait_ms);
            let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
            return;
        }
        let (queued, batch_waiting) = self
            .qos
            .as_ref()
            .and_then(|q| q.queues.get(w))
            .map_or((0, 0), |d| (d.len(), d.batch_len()));
        if queued >= max_backlog {
            if req.priority == Priority::Batch || batch_waiting == 0 {
                // The arrival is itself the newest turn in the first lane
                // the shed order reaches: reject it directly.
                let counter = match req.priority {
                    Priority::Batch => &self.counters.shed_batch,
                    Priority::Interactive => &self.counters.shed_interactive,
                };
                counter.fetch_add(1, Ordering::AcqRel);
                let err = WireError::new(
                    ErrorCode::Overloaded,
                    format!("worker {w} backlog full ({queued} turns queued)"),
                )
                .with_retry_after(hint);
                let _ = req.reply.emit(ServeEvent::Done(Response::error(req.id, err)));
                return;
            }
            // Interactive arrival displaces the newest waiting batch turn.
            if let Some((victim, _)) = self
                .qos
                .as_mut()
                .and_then(|q| q.queues.get_mut(w))
                .and_then(|d| d.shed_cheapest())
            {
                self.counters.shed_batch.fetch_add(1, Ordering::AcqRel);
                let err = WireError::new(
                    ErrorCode::Overloaded,
                    format!("worker {w} backlog full ({queued} turns queued)"),
                )
                .with_retry_after(hint);
                let _ = victim
                    .reply
                    .emit(ServeEvent::Done(Response::error(victim.id, err)));
            }
        }
        if let Some(d) = self.qos.as_mut().and_then(|q| q.queues.get_mut(w)) {
            d.push(req);
        }
        self.pump_worker(w);
    }

    /// Placement under QoS: least (in-flight + queued), ties to the
    /// lowest index — a worker's DRR backlog counts against it, not just
    /// work already dispatched.
    fn least_backlogged(&self) -> usize {
        let mut best = 0usize;
        let mut best_load = usize::MAX;
        for (w, load) in self.loads.iter().enumerate() {
            let queued = self
                .qos
                .as_ref()
                .and_then(|q| q.queues.get(w))
                .map_or(0, DrrQueue::len);
            let l = load.load(Ordering::Acquire).saturating_add(queued);
            if l < best_load {
                best = w;
                best_load = l;
            }
        }
        best
    }

    /// Turns waiting in the DRR queues across all workers (0 without QoS).
    fn queued_total(&self) -> usize {
        self.qos
            .as_ref()
            .map_or(0, |q| q.queues.iter().map(DrrQueue::len).sum())
    }

    /// Refill every worker's in-flight slots from its DRR queue (no-op
    /// without QoS).
    fn pump(&mut self) {
        for w in 0..self.txs.len() {
            self.pump_worker(w);
        }
    }

    /// Dispatch queued turns to worker `w` in DRR order while it is under
    /// the QoS in-flight cap.
    fn pump_worker(&mut self, w: usize) {
        let Scheduler {
            txs,
            loads,
            qos,
            flights,
            ..
        } = self;
        let Some(qos) = qos.as_mut() else { return };
        let quantum = qos.cfg.quantum;
        let cap = qos.cfg.inflight_per_worker.max(1);
        let (Some(tx), Some(load)) = (txs.get(w), loads.get(w)) else {
            return;
        };
        while load.load(Ordering::Acquire) < cap {
            let Some(req) = qos.queues.get_mut(w).and_then(|d| d.pop_next(quantum)) else {
                return;
            };
            load.fetch_add(1, Ordering::AcqRel);
            let id = req.id;
            let tracked: Reply = Box::new(TrackedSink {
                inner: req.reply,
                loads: loads.clone(),
                worker: w,
            });
            let reply = match flights.get(w) {
                Some(reg) => reg.track(FlightKind::Submit { id }, tracked),
                None => tracked,
            };
            let req = Request { reply, ..req };
            if let Err(send_err) = tx.send(Op::Submit(req)) {
                // Worker gone (only during shutdown). Answer through the
                // tracked sink so the load count is released.
                if let Op::Submit(r) = send_err.0 {
                    let err = WireError::internal(format!("worker {w} unavailable"));
                    let _ = r.reply.emit(ServeEvent::Done(Response::error(r.id, err)));
                }
            }
        }
    }

    /// Shutdown path: forward everything still queued, ignoring the
    /// in-flight cap — the workers' own queue bounds govern from here and
    /// no accepted turn is silently dropped.
    fn flush_queues(&mut self) {
        let Scheduler {
            txs,
            loads,
            qos,
            flights,
            ..
        } = self;
        let Some(qos) = qos.as_mut() else { return };
        let quantum = qos.cfg.quantum;
        for (w, queue) in qos.queues.iter_mut().enumerate() {
            let (Some(tx), Some(load)) = (txs.get(w), loads.get(w)) else {
                continue;
            };
            while let Some(req) = queue.pop_next(quantum) {
                load.fetch_add(1, Ordering::AcqRel);
                let id = req.id;
                let tracked: Reply = Box::new(TrackedSink {
                    inner: req.reply,
                    loads: loads.clone(),
                    worker: w,
                });
                let reply = match flights.get(w) {
                    Some(reg) => reg.track(FlightKind::Submit { id }, tracked),
                    None => tracked,
                };
                let req = Request { reply, ..req };
                if let Err(send_err) = tx.send(Op::Submit(req)) {
                    if let Op::Submit(r) = send_err.0 {
                        let err = WireError::internal(format!("worker {w} unavailable"));
                        let _ = r.reply.emit(ServeEvent::Done(Response::error(r.id, err)));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CompressionSpec, Response};
    use crate::model::StubEngine;
    use crate::util::faults::{FaultPlan, FaultRule, FaultSite};
    use std::sync::mpsc;
    use std::time::Instant;

    fn start(n_workers: usize, cfg: CoordinatorConfig) -> Scheduler {
        let base = StubEngine::new(StubEngine::test_dims(64));
        Scheduler::start(n_workers, cfg, move |w| Ok(base.fork(w))).unwrap()
    }

    /// QoS-enabled stack with an artificial per-decode-step delay so a
    /// turn can be held in flight long enough to queue work behind it.
    fn start_qos(
        n_workers: usize,
        cfg: CoordinatorConfig,
        qos: QosConfig,
        delay: Duration,
    ) -> Scheduler {
        let mut base = StubEngine::new(StubEngine::test_dims(64));
        base.decode_delay = delay;
        Scheduler::start_with_qos(n_workers, cfg, Some(qos), move |w| Ok(base.fork(w))).unwrap()
    }

    fn submit(
        id: u64,
        session: Option<u64>,
        keep: bool,
        reply: &mpsc::Sender<ServeEvent>,
    ) -> Op {
        Op::Submit(Request {
            id,
            prompt: vec![1, 2, 3],
            max_new: 3,
            stop: None,
            spec: CompressionSpec::mikv(0.5, "int4"),
            session,
            keep,
            tenant: 0,
            priority: Priority::Interactive,
            submitted_at: Instant::now(),
            reply: Box::new(reply.clone()),
        })
    }

    /// A submit with explicit tenant/priority/size, for QoS tests.
    fn submit_qos(
        id: u64,
        tenant: u64,
        priority: Priority,
        max_new: usize,
        reply: &mpsc::Sender<ServeEvent>,
    ) -> Op {
        Op::Submit(Request {
            id,
            prompt: vec![1, 2, 3],
            max_new,
            stop: None,
            spec: CompressionSpec::mikv(0.5, "int4"),
            session: None,
            keep: false,
            tenant,
            priority,
            submitted_at: Instant::now(),
            reply: Box::new(reply.clone()),
        })
    }

    fn wait_done(rx: &mpsc::Receiver<ServeEvent>) -> Response {
        loop {
            if let ServeEvent::Done(r) = rx.recv().unwrap() {
                return r;
            }
        }
    }

    #[test]
    fn owner_arithmetic_matches_worker_stride() {
        // worker w of N assigns w+1, w+1+N, ... — invert it.
        for n in 1..=5usize {
            for w in 0..n {
                for k in 0..4u64 {
                    let sid = w as u64 + 1 + k * n as u64;
                    assert_eq!(worker_of_session(sid, n), w, "sid {sid} of {n}");
                }
            }
        }
        // degenerate inputs stay in range
        assert_eq!(worker_of_session(0, 4), 0);
        assert_eq!(worker_of_session(1, 1), 0);
    }

    /// End to end across 2 workers: a kept generate parks on some worker,
    /// the follow-up append routes back to it by session-id arithmetic and
    /// continues the same cache.
    #[test]
    fn append_routes_to_the_owning_worker() {
        let sched = start(2, CoordinatorConfig::default());
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit(1, None, true, &etx)).unwrap();
            let turn1 = wait_done(&erx);
            assert!(turn1.error.is_none(), "{:?}", turn1.error);
            let sid = turn1.session.expect("kept session");
            let occ1 = turn1.metrics.hi_slots + turn1.metrics.lo_slots;

            tx.send(submit(2, Some(sid), false, &etx)).unwrap();
            let turn2 = wait_done(&erx);
            assert!(turn2.error.is_none(), "{:?}", turn2.error);
            assert_eq!(turn2.session, Some(sid));
            let occ2 = turn2.metrics.hi_slots + turn2.metrics.lo_slots;
            assert!(occ2 > occ1, "cache carried over: {occ1} -> {occ2}");
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// Cancel of an unknown target broadcasts to every worker and folds
    /// into exactly one `found: false` answer.
    #[test]
    fn cancel_fanout_aggregates_to_one_answer() {
        let sched = start(4, CoordinatorConfig::default());
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(Op::Cancel {
                id: 1,
                target: 999,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            match erx.recv().unwrap() {
                ServeEvent::CancelResult { id, target, found } => {
                    assert_eq!((id, target, found), (1, 999, false));
                }
                other => panic!("unexpected {other:?}"),
            }
            drop(etx);
            // exactly one aggregated answer, not one per worker
            assert!(erx.recv().is_err(), "no second cancel answer");
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// Stats broadcasts merge into one snapshot with one row per worker.
    #[test]
    fn stats_fanout_merges_worker_rows() {
        let sched = start(3, CoordinatorConfig::default());
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit(1, None, false, &etx)).unwrap();
            let done = wait_done(&erx);
            assert!(done.error.is_none());

            tx.send(Op::Stats {
                id: 7,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            let snapshot = loop {
                if let ServeEvent::Stats { id, snapshot } = erx.recv().unwrap() {
                    assert_eq!(id, 7);
                    break snapshot;
                }
            };
            assert_eq!(snapshot.workers.len(), 3);
            let ids: Vec<usize> = snapshot.workers.iter().map(|w| w.worker).collect();
            assert_eq!(ids, vec![0, 1, 2]);
            assert_eq!(snapshot.completed, 1);
            let sum: usize = snapshot.workers.iter().map(|w| w.completed).sum();
            assert_eq!(sum, snapshot.completed);
            // the submit completed before the stats op, so the scheduler's
            // admission-side in-flight view is quiescent — present, zero.
            assert_eq!(snapshot.admitted_in_flight, 0);
            assert!(snapshot.workers.iter().all(|w| w.admitted_in_flight == 0));
            assert_eq!(snapshot.qos_queued, 0);
            assert_eq!(
                (
                    snapshot.shed_batch,
                    snapshot.shed_interactive,
                    snapshot.rate_limited
                ),
                (0, 0, 0)
            );
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// Scheduler-side backpressure: with a zero-capacity config every
    /// submit is rejected `overloaded` before reaching a worker.
    #[test]
    fn backpressure_rejects_overloaded_at_admission() {
        let cfg = CoordinatorConfig {
            max_active: 0,
            max_waiting: 0,
            ..CoordinatorConfig::default()
        };
        let sched = start(2, cfg);
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit(1, None, false, &etx)).unwrap();
            let done = wait_done(&erx);
            let err = done.error.expect("rejected");
            assert_eq!(err.code, ErrorCode::Overloaded);
            // Regression lock: without a QoS config the rejection is the
            // historical FCFS shape — same message, no retry hint.
            assert_eq!(err.message, "worker 0 at capacity (0 requests in flight)");
            assert_eq!(err.retry_after_ms, None);
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// A QoS stack with default knobs and a single tenant serves a normal
    /// generate/append conversation exactly like the FCFS path.
    #[test]
    fn qos_default_knobs_serve_a_conversation() {
        let sched = start_qos(
            2,
            CoordinatorConfig::default(),
            QosConfig::default(),
            Duration::ZERO,
        );
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit(1, None, true, &etx)).unwrap();
            let turn1 = wait_done(&erx);
            assert!(turn1.error.is_none(), "{:?}", turn1.error);
            let sid = turn1.session.expect("kept session");
            tx.send(submit(2, Some(sid), false, &etx)).unwrap();
            let turn2 = wait_done(&erx);
            assert!(turn2.error.is_none(), "{:?}", turn2.error);
            assert_eq!(turn2.session, Some(sid));
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// Backlog pressure sheds the batch lane first, every shed rejection
    /// carries the configured `retry_after_ms`, queued interactive work
    /// survives and completes, and the shed counters surface in stats.
    #[test]
    fn qos_sheds_batch_lane_first_with_retry_hint() {
        let qos = QosConfig {
            inflight_per_worker: 1,
            max_backlog: 2,
            retry_after_ms: 25,
            ..QosConfig::default()
        };
        // One worker; the active turn decodes 20 steps at 2ms each, so the
        // whole submit sequence below lands while it is still in flight.
        let sched = start_qos(
            1,
            CoordinatorConfig::default(),
            qos,
            Duration::from_millis(2),
        );
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            // A occupies the worker (in-flight cap 1).
            tx.send(submit_qos(1, 1, Priority::Interactive, 20, &etx))
                .unwrap();
            // B (interactive) and C (batch) fill the backlog of 2.
            tx.send(submit_qos(2, 2, Priority::Interactive, 1, &etx))
                .unwrap();
            tx.send(submit_qos(3, 3, Priority::Batch, 1, &etx)).unwrap();
            // D (batch) arrives over the bound: it is itself the newest
            // batch turn — rejected directly.
            tx.send(submit_qos(4, 4, Priority::Batch, 1, &etx)).unwrap();
            // E (interactive) arrives over the bound: the newest *waiting
            // batch* turn (C) is shed to make room.
            tx.send(submit_qos(5, 5, Priority::Interactive, 1, &etx))
                .unwrap();
            let mut ok = Vec::new();
            let mut shed = Vec::new();
            for _ in 0..5 {
                let done = wait_done(&erx);
                match done.error {
                    None => ok.push(done.id),
                    Some(err) => {
                        assert_eq!(err.code, ErrorCode::Overloaded, "id {}", done.id);
                        assert_eq!(err.retry_after_ms, Some(25), "id {}", done.id);
                        shed.push(done.id);
                    }
                }
            }
            ok.sort_unstable();
            shed.sort_unstable();
            assert_eq!(ok, vec![1, 2, 5], "batch shed before interactive");
            assert_eq!(shed, vec![3, 4]);
            // Both sheds were batch-lane; the counters say so.
            tx.send(Op::Stats {
                id: 9,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            let snapshot = loop {
                if let ServeEvent::Stats { snapshot, .. } = erx.recv().unwrap() {
                    break snapshot;
                }
            };
            assert_eq!(snapshot.shed_batch, 2);
            assert_eq!(snapshot.shed_interactive, 0);
            assert_eq!(snapshot.rate_limited, 0);
            assert_eq!(snapshot.qos_queued, 0);
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// Per-tenant token bucket: a tenant that exhausts its burst is
    /// rejected `overloaded` with a positive retry hint while the work it
    /// already admitted still completes.
    #[test]
    fn qos_rate_limit_rejects_with_retry_hint() {
        let qos = QosConfig {
            // burst covers exactly one small turn (prompt 3 + max_new 1);
            // the refill rate is negligible on test timescales.
            rate: Some(0.001),
            burst: 4.0,
            ..QosConfig::default()
        };
        let sched = start_qos(1, CoordinatorConfig::default(), qos, Duration::ZERO);
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit_qos(1, 7, Priority::Interactive, 1, &etx))
                .unwrap();
            tx.send(submit_qos(2, 7, Priority::Interactive, 1, &etx))
                .unwrap();
            // A different tenant has its own bucket and is unaffected.
            tx.send(submit_qos(3, 8, Priority::Interactive, 1, &etx))
                .unwrap();
            let mut ok = Vec::new();
            let mut limited = Vec::new();
            for _ in 0..3 {
                let done = wait_done(&erx);
                match done.error {
                    None => ok.push(done.id),
                    Some(err) => {
                        assert_eq!(err.code, ErrorCode::Overloaded);
                        assert!(
                            err.retry_after_ms.is_some_and(|ms| ms >= 1),
                            "hint: {:?}",
                            err.retry_after_ms
                        );
                        limited.push(done.id);
                    }
                }
            }
            ok.sort_unstable();
            assert_eq!(ok, vec![1, 3]);
            assert_eq!(limited, vec![2]);
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// Cancel finds a turn still waiting in the DRR queue: the queued turn
    /// is answered `cancelled` and the cancel reports `found` without a
    /// worker broadcast.
    #[test]
    fn qos_cancel_reaches_queued_turn() {
        let qos = QosConfig {
            inflight_per_worker: 1,
            ..QosConfig::default()
        };
        let sched = start_qos(
            1,
            CoordinatorConfig::default(),
            qos,
            Duration::from_millis(2),
        );
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            // A occupies the worker; B waits in the queue.
            tx.send(submit_qos(1, 1, Priority::Interactive, 20, &etx))
                .unwrap();
            tx.send(submit_qos(2, 2, Priority::Interactive, 1, &etx))
                .unwrap();
            tx.send(Op::Cancel {
                id: 10,
                target: 2,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            let mut saw_cancel_result = false;
            let mut b_cancelled = false;
            let mut a_done = false;
            while !(saw_cancel_result && b_cancelled && a_done) {
                match erx.recv().unwrap() {
                    ServeEvent::CancelResult { id, target, found } => {
                        assert_eq!((id, target, found), (10, 2, true));
                        saw_cancel_result = true;
                    }
                    ServeEvent::Done(r) if r.id == 2 => {
                        assert!(r.cancelled, "queued turn answered as cancelled");
                        b_cancelled = true;
                    }
                    ServeEvent::Done(r) if r.id == 1 => {
                        assert!(r.error.is_none());
                        a_done = true;
                    }
                    _ => {}
                }
            }
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// Supervision: an injected engine panic mid-turn never strands the
    /// client — it gets a structured `internal` terminal event — and the
    /// respawned worker serves the next turn normally, with the restart
    /// visible in merged stats.
    #[test]
    fn worker_panic_errors_in_flight_and_respawns() {
        let plan = FaultPlan::builder()
            .site(
                FaultSite::EngineStepPanic,
                FaultRule {
                    every: 1,
                    after: 0,
                    limit: 1,
                    ms: 0,
                },
            )
            .build();
        let mut base = StubEngine::new(StubEngine::test_dims(64));
        base.faults = plan;
        let sched =
            Scheduler::start(1, CoordinatorConfig::default(), move |w| Ok(base.fork(w))).unwrap();
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit(1, None, false, &etx)).unwrap();
            let done = wait_done(&erx);
            let err = done.error.expect("the panicked turn must error, not hang");
            assert_eq!(err.code, ErrorCode::Internal);
            assert!(err.message.contains("restarted mid-request"), "{err}");

            tx.send(submit(2, None, false, &etx)).unwrap();
            let done = wait_done(&erx);
            assert!(done.error.is_none(), "respawned worker serves: {:?}", done.error);

            tx.send(Op::Stats {
                id: 9,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            let snapshot = loop {
                if let ServeEvent::Stats { snapshot, .. } = erx.recv().unwrap() {
                    break snapshot;
                }
            };
            assert_eq!(snapshot.worker_restarts, 1);
            assert_eq!(snapshot.sessions_lost, 0);
            assert_eq!(snapshot.completed, 1, "only the post-respawn turn completed");
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// A panic with a hot-parked session loses exactly that session: the
    /// loss is counted, and a follow-up `append` gets the clean
    /// `session_not_found` (never a hang or a bogus restore).
    #[test]
    fn worker_panic_counts_lost_hot_sessions() {
        // Turn 1 (prompt 3, max_new 3) takes 2 decode steps; arm the panic
        // for the 3rd step, i.e. the first step of turn 2.
        let plan = FaultPlan::builder()
            .site(
                FaultSite::EngineStepPanic,
                FaultRule {
                    every: 1,
                    after: 2,
                    limit: 1,
                    ms: 0,
                },
            )
            .build();
        let mut base = StubEngine::new(StubEngine::test_dims(64));
        base.faults = plan;
        let sched =
            Scheduler::start(1, CoordinatorConfig::default(), move |w| Ok(base.fork(w))).unwrap();
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit(1, None, true, &etx)).unwrap();
            let turn1 = wait_done(&erx);
            assert!(turn1.error.is_none(), "{:?}", turn1.error);
            let sid = turn1.session.expect("kept session parked hot");

            tx.send(submit(2, None, false, &etx)).unwrap();
            let turn2 = wait_done(&erx);
            let err = turn2.error.expect("turn 2 dies with the worker");
            assert!(err.message.contains("restarted mid-request"), "{err}");

            // The parked session unwound with the dead worker.
            tx.send(submit(3, Some(sid), false, &etx)).unwrap();
            let turn3 = wait_done(&erx);
            let err = turn3.error.expect("lost session must not restore");
            assert_eq!(err.code, ErrorCode::SessionNotFound);

            tx.send(Op::Stats {
                id: 9,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            let snapshot = loop {
                if let ServeEvent::Stats { snapshot, .. } = erx.recv().unwrap() {
                    break snapshot;
                }
            };
            assert_eq!(snapshot.worker_restarts, 1);
            assert_eq!(snapshot.sessions_lost, 1);
            assert_eq!(snapshot.sessions_recovered, 0, "no cold tier configured");
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }

    /// While a turn is in flight, the scheduler-side `admitted_in_flight`
    /// gauge is visible in the merged snapshot and the owning worker's row
    /// — the queue-depth window workers themselves cannot see.
    #[test]
    fn stats_surface_admitted_in_flight_mid_turn() {
        let mut base = StubEngine::new(StubEngine::test_dims(64));
        base.decode_delay = Duration::from_millis(2);
        let sched =
            Scheduler::start(2, CoordinatorConfig::default(), move |w| Ok(base.fork(w))).unwrap();
        let (tx, rx) = mpsc::channel::<Op>();
        let driver = std::thread::spawn(move || {
            let (etx, erx) = mpsc::channel::<ServeEvent>();
            tx.send(submit_qos(1, 0, Priority::Interactive, 20, &etx))
                .unwrap();
            // First token proves the turn was admitted and is in flight.
            loop {
                if let Ok(ServeEvent::Token { .. }) = erx.recv() {
                    break;
                }
            }
            tx.send(Op::Stats {
                id: 5,
                reply: Box::new(etx.clone()),
            })
            .unwrap();
            let snapshot = loop {
                if let ServeEvent::Stats { snapshot, .. } = erx.recv().unwrap() {
                    break snapshot;
                }
            };
            assert_eq!(snapshot.admitted_in_flight, 1);
            let per_worker: usize = snapshot.workers.iter().map(|w| w.admitted_in_flight).sum();
            assert_eq!(per_worker, 1);
            let done = wait_done(&erx);
            assert!(done.error.is_none());
            drop(tx);
        });
        sched.run(rx);
        driver.join().unwrap();
    }
}
