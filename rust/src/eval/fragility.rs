//! Fragility scenario grid: race every importance policy × every retention
//! arm on the failure modes that matter, without compiled artifacts.
//!
//! The engine-based harness needs a trained model; this module instead
//! builds a *content-addressable memory* directly on [`CacheManager`]:
//! token `t` gets a deterministic ±1/√d embedding, slot `i` of a transcript
//! stores `K_i = embed(tok_i)`, `V_i = embed(tok_{i+1})` (the induction-head
//! association), and a probe for key `k` is a sharpened-softmax attention
//! readout over the cache's *effective* (dequantized / surviving) KV rows,
//! decoded by argmax against the vocabulary embeddings. Retrieval therefore
//! degrades exactly the way the cache does: an evicted needle cannot be
//! read back, a lo-tier needle survives through its quantized rows, and a
//! merged needle survives only as attention-weighted mass in its neighbor.
//!
//! Scenarios come from the fragility task families in
//! [`super::corpus`] / [`super::harness`] (needle-at-depth, keyed recall,
//! multi-turn drift); drift transcripts are driven through the *real*
//! session lifecycle — prefill, per-token appends with honest attention
//! rows, a probe of the turn-0 fact at the end of every turn, and a
//! park/unpark (spill-to-bytes + restore) every other turn.
//!
//! Every grid cell (task × policy × arm) is seeded independently via
//! [`SplitMix64`] from the cell index, so [`run_grid`] and
//! [`run_grid_workers`] produce **byte-identical** scores for any worker
//! count — the determinism contract `benches/fragility_grid.rs` and CI
//! depend on.

use super::corpus::{self, QUERY, SEP};
use super::harness::{depth_bucket, p10_score, worst_bucket_score, EvalTask, DEPTH_BUCKETS};
use crate::kvcache::spill::{decode_session, encode_session};
use crate::kvcache::{BufferPool, CacheConfig, CacheManager, MergeConfig, RetentionMode};
use crate::model::{CacheMode, Session, SessionCache};
use crate::quant::Precision;
use crate::runtime::ModelDims;
use crate::util::rng::{Pcg32, SplitMix64};

const LAYERS: usize = 2;
const KV_HEADS: usize = 2;
const D_HEAD: usize = 32;
/// Softmax sharpness of the honest attention rows fed to the policies.
const PRE_SCALE: f32 = 4.0;
/// Softmax sharpness of the retrieval probe (match sim ≈ 1, noise ≈ ±1/√d,
/// so scale 8 makes the matching slot dominate the readout).
const PROBE_SCALE: f32 = 8.0;
const EMBED_SALT: u64 = 0xE11B_ED5A;

/// How demoted (non-important) tokens are handled — the race's third axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arm {
    /// Hi-only eviction baseline: demoted tokens are dropped.
    EvictOnly,
    /// MiKV mixed precision: demoted tokens are retained in the lo tier.
    MixedPrecision,
    /// WeightedKV-style merge: demoted tokens fold into a retained
    /// neighbor ([`MergeConfig`]).
    MergeInsteadOfDrop,
}

impl Arm {
    pub fn name(&self) -> &'static str {
        match self {
            Arm::EvictOnly => "evict",
            Arm::MixedPrecision => "mikv",
            Arm::MergeInsteadOfDrop => "merge",
        }
    }
}

/// One fragility grid: the cross product of tasks × policies × arms.
#[derive(Debug, Clone)]
pub struct GridSpec {
    pub seed: u64,
    /// Samples per cell (drift samples contribute one probe per turn).
    pub samples: usize,
    pub max_seq: usize,
    /// Hi-tier importance ratio shared by every arm.
    pub ratio: f64,
    pub recent_window: usize,
    pub tasks: Vec<EvalTask>,
    pub policies: Vec<String>,
    pub arms: Vec<Arm>,
}

impl GridSpec {
    /// The full grid raced by `benches/fragility_grid.rs`.
    pub fn full_grid(seed: u64) -> Self {
        GridSpec {
            seed,
            samples: 6,
            max_seq: 192,
            ratio: 0.2,
            recent_window: 8,
            tasks: vec![
                EvalTask::NeedleAtDepth { depth_pct: 0, haystack: 120 },
                EvalTask::NeedleAtDepth { depth_pct: 25, haystack: 120 },
                EvalTask::NeedleAtDepth { depth_pct: 50, haystack: 120 },
                EvalTask::NeedleAtDepth { depth_pct: 75, haystack: 120 },
                EvalTask::NeedleAtDepth { depth_pct: 95, haystack: 120 },
                EvalTask::KeyedRecall { n_keys: 24 },
                EvalTask::MultiTurnDrift { turns: 10, probe_every: 2 },
            ],
            policies: ["h2o", "local", "random", "lagkv"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            arms: vec![Arm::EvictOnly, Arm::MixedPrecision, Arm::MergeInsteadOfDrop],
        }
    }

    /// CI-sized grid: same axes, smaller contexts and sample counts.
    pub fn smoke(seed: u64) -> Self {
        GridSpec {
            samples: 3,
            max_seq: 128,
            tasks: vec![
                EvalTask::NeedleAtDepth { depth_pct: 0, haystack: 72 },
                EvalTask::NeedleAtDepth { depth_pct: 50, haystack: 72 },
                EvalTask::NeedleAtDepth { depth_pct: 95, haystack: 72 },
                EvalTask::KeyedRecall { n_keys: 16 },
                EvalTask::MultiTurnDrift { turns: 6, probe_every: 2 },
            ],
            ..Self::full_grid(seed)
        }
    }
}

/// Scores of one grid cell. Floats are deterministic down to the bit for a
/// given [`GridSpec`] — the determinism regression tests compare `to_bits`.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResult {
    pub cell: usize,
    /// Task label, e.g. `needle@75`.
    pub task: String,
    /// Task family ([`EvalTask::name`]).
    pub family: &'static str,
    /// The pinned needle depth for needle cells.
    pub depth_pct: Option<u8>,
    pub policy: String,
    pub arm: &'static str,
    pub n_probes: usize,
    pub mean: f64,
    pub worst_bucket: f64,
    pub p10: f64,
    /// Mean probe score per depth bucket (0.0 where the bucket is empty).
    pub bucket_scores: [f64; DEPTH_BUCKETS],
    pub bucket_counts: [usize; DEPTH_BUCKETS],
    pub cache_pct: f64,
    /// Total merge-ledger folds across the cell's sessions (merge arm only).
    pub merges: u64,
}

/// Deterministic ±1/√d embedding per vocabulary token.
pub struct EmbedTable {
    d: usize,
    rows: Vec<f32>,
}

impl EmbedTable {
    pub fn new(seed: u64, d: usize) -> Self {
        let n = corpus::VOCAB as usize;
        let mut rows = vec![0.0f32; n * d];
        let a = 1.0 / (d as f32).sqrt();
        for t in 0..n {
            let mut rng = Pcg32::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            for x in &mut rows[t * d..(t + 1) * d] {
                *x = if rng.gen_bool(0.5) { a } else { -a };
            }
        }
        EmbedTable { d, rows }
    }

    fn row(&self, tok: i64) -> &[f32] {
        &self.rows[tok as usize * self.d..(tok as usize + 1) * self.d]
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn model_dims(max_seq: usize) -> ModelDims {
    ModelDims {
        vocab: corpus::VOCAB as usize,
        d_model: LAYERS * D_HEAD,
        n_layers: LAYERS,
        n_q_heads: 2 * KV_HEADS,
        n_kv_heads: KV_HEADS,
        d_head: D_HEAD,
        d_ff: 2 * LAYERS * D_HEAD,
        max_seq,
        quant_group: D_HEAD / 2,
        params: 0,
    }
}

fn manager(sess: &Session) -> &CacheManager {
    match &sess.cache {
        SessionCache::Mikv(m) => m,
        SessionCache::Full(_) => unreachable!("fragility sessions are MiKV"),
    }
}

fn build_session(
    spec: &GridSpec,
    policy: &str,
    arm: Arm,
    id: u64,
    dims: &ModelDims,
) -> crate::Result<Session> {
    let mut cfg = CacheConfig::mikv(
        LAYERS,
        KV_HEADS,
        D_HEAD,
        spec.max_seq,
        spec.ratio,
        Precision::Int2,
    );
    cfg.recent_window = spec.recent_window;
    match arm {
        Arm::MixedPrecision => {}
        Arm::EvictOnly => cfg.retention = RetentionMode::Evict,
        Arm::MergeInsteadOfDrop => {
            cfg.retention = RetentionMode::Evict;
            cfg.merge = Some(MergeConfig::default());
        }
    }
    Session::new(
        id,
        dims,
        CacheMode::Mikv {
            cfg,
            policy: policy.to_string(),
        },
    )
}

/// Accumulated causal attention over the stream (one plane; replicated):
/// position `j` attends content-addressably over `0..j` with its own
/// embedding as the query — the honest importance signal policies rank by.
fn causal_attention_acc(et: &EmbedTable, stream: &[i64]) -> Vec<f32> {
    let t = stream.len();
    let mut acc = vec![0.0f32; t];
    let mut sims = vec![0.0f32; t];
    for j in 1..t {
        let q = et.row(stream[j]);
        let mut mx = f32::NEG_INFINITY;
        for i in 0..j {
            sims[i] = PRE_SCALE * dot(q, et.row(stream[i]));
            if sims[i] > mx {
                mx = sims[i];
            }
        }
        let mut z = 0.0f32;
        for i in 0..j {
            sims[i] = (sims[i] - mx).exp();
            z += sims[i];
        }
        for i in 0..j {
            acc[i] += sims[i] / z;
        }
    }
    acc
}

/// One append step's attention row (softmax over the `i` existing slots).
fn append_attention_row(et: &EmbedTable, stream: &[i64], i: usize) -> Vec<f32> {
    let q = et.row(stream[i]);
    let mut w = vec![0.0f32; i];
    let mut mx = f32::NEG_INFINITY;
    for (s, ws) in w.iter_mut().enumerate() {
        *ws = PRE_SCALE * dot(q, et.row(stream[s]));
        if *ws > mx {
            mx = *ws;
        }
    }
    let mut z = 0.0f32;
    for ws in w.iter_mut() {
        *ws = (*ws - mx).exp();
        z += *ws;
    }
    for ws in w.iter_mut() {
        *ws /= z;
    }
    w
}

/// Prefill the stream's induction associations into the session's cache:
/// `K_i = embed(stream[i])`, `V_i = embed(prompt[i+1])` (the prompt always
/// extends one token past the stream, so the last association is defined).
fn ingest_prefill_stream(
    et: &EmbedTable,
    dims: &ModelDims,
    sess: &mut Session,
    stream: &[i64],
    prompt: &[i64],
) {
    let t0 = stream.len();
    let planes = dims.planes();
    let d = D_HEAD;
    let mut k = vec![0.0f32; planes * t0 * d];
    let mut v = vec![0.0f32; planes * t0 * d];
    for (s, &tok) in stream.iter().enumerate() {
        let krow = et.row(tok);
        let vrow = et.row(prompt[s + 1]);
        for p in 0..planes {
            k[(p * t0 + s) * d..(p * t0 + s + 1) * d].copy_from_slice(krow);
            v[(p * t0 + s) * d..(p * t0 + s + 1) * d].copy_from_slice(vrow);
        }
    }
    let acc1 = causal_attention_acc(et, stream);
    let mut acc = vec![0.0f32; planes * t0];
    for p in 0..planes {
        acc[p * t0..(p + 1) * t0].copy_from_slice(&acc1);
    }
    let a = 1.0 / (d as f32).sqrt();
    let qmax = vec![a; planes * d];
    let kmax = vec![a; planes * d];
    match &mut sess.cache {
        SessionCache::Mikv(m) => m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax),
        SessionCache::Full(_) => unreachable!("fragility sessions are MiKV"),
    }
    sess.tokens = stream.to_vec();
    sess.prompt_len = t0;
    sess.last_token = stream[t0 - 1];
}

/// Sharpened-softmax retrieval probe through the cache's *effective* KV
/// rows, decoded against the vocabulary embeddings. Pure readout — policy
/// and tier state are untouched.
fn probe_argmax(m: &CacheManager, et: &EmbedTable, q_tok: i64, planes: usize) -> i64 {
    let d = D_HEAD;
    let t = m.seq_len();
    let q = et.row(q_tok);
    let mut kb = vec![0.0f32; d];
    let mut vb = vec![0.0f32; d];
    let mut read = vec![0.0f32; d];
    let mut sims: Vec<(usize, f32)> = Vec::with_capacity(t);
    for p in 0..planes {
        sims.clear();
        let mut mx = f32::NEG_INFINITY;
        for s in 0..t {
            if m.effective_kv_into(p, s, &mut kb, &mut vb) {
                let x = PROBE_SCALE * dot(q, &kb);
                sims.push((s, x));
                if x > mx {
                    mx = x;
                }
            }
        }
        if sims.is_empty() {
            continue;
        }
        let mut z = 0.0f32;
        for (_, x) in sims.iter_mut() {
            *x = (*x - mx).exp();
            z += *x;
        }
        for &(s, w) in sims.iter() {
            let _ = m.effective_kv_into(p, s, &mut kb, &mut vb);
            for (r, &x) in read.iter_mut().zip(vb.iter()) {
                *r += (w / z) * x;
            }
        }
    }
    let mut best = 0i64;
    let mut best_v = f32::NEG_INFINITY;
    for tok in 0..corpus::VOCAB {
        let s = dot(et.row(tok), &read);
        if s > best_v {
            best_v = s;
            best = tok;
        }
    }
    best
}

/// Split a sample into its ingestible stream and the queried key token.
fn split_query(sample: &corpus::EvalSample) -> crate::Result<(&[i64], i64)> {
    let qpos = sample.prompt.len() - 1 - corpus::KEY_TOKS;
    anyhow::ensure!(
        sample.prompt[qpos] == QUERY,
        "fragility samples must end [QUERY, key]"
    );
    Ok((&sample.prompt[..qpos], sample.prompt[qpos + 1]))
}

/// Single-shot scenario: prefill the whole stream, probe once.
fn run_single_sample(
    et: &EmbedTable,
    dims: &ModelDims,
    sess: &mut Session,
    sample: &corpus::EvalSample,
) -> crate::Result<(f64, Option<u8>)> {
    let (stream, key_tok) = split_query(sample)?;
    ingest_prefill_stream(et, dims, sess, stream, &sample.prompt);
    let got = probe_argmax(manager(sess), et, key_tok, dims.planes());
    let score = if got == sample.answer[0] { 1.0 } else { 0.0 };
    Ok((score, sample.depth_pct))
}

/// Multi-turn drift scenario through the real session lifecycle: prefill
/// turn 0, append each later turn token-by-token with honest attention
/// rows, probe the turn-0 fact at the end of every turn, and park/unpark
/// (spill + restore) the session every other turn.
fn run_drift_sample(
    et: &EmbedTable,
    dims: &ModelDims,
    sess: &mut Session,
    sample: &corpus::EvalSample,
    scores: &mut Vec<f64>,
    depths: &mut Vec<Option<u8>>,
) -> crate::Result<()> {
    let (stream, key_tok) = split_query(sample)?;
    let t0 = stream.iter().position(|&t| t == SEP).unwrap_or(stream.len());
    // the target fact's key sits at slot 2: [BOS, REC, k0, v0…]
    anyhow::ensure!(stream[2] == key_tok, "drift query must target turn 0");
    ingest_prefill_stream(et, dims, sess, &stream[..t0], &sample.prompt);

    let planes = dims.planes();
    let d = D_HEAD;
    let pool = BufferPool::new();
    let mut turn = 0usize;
    for i in t0..stream.len() {
        let mut k_new = vec![0.0f32; planes * d];
        let mut v_new = vec![0.0f32; planes * d];
        for p in 0..planes {
            k_new[p * d..(p + 1) * d].copy_from_slice(et.row(stream[i]));
            v_new[p * d..(p + 1) * d].copy_from_slice(et.row(sample.prompt[i + 1]));
        }
        let w = append_attention_row(et, stream, i);
        let mut attn_prev = vec![0.0f32; planes * dims.max_seq];
        for p in 0..planes {
            attn_prev[p * dims.max_seq..p * dims.max_seq + i].copy_from_slice(&w);
        }
        let attn_self = vec![0.02f32; planes];
        sess.try_ingest_step(&k_new, &v_new, &attn_prev, &attn_self)?;
        sess.tokens.push(stream[i]);
        sess.last_token = stream[i];

        let end_of_turn = i + 1 == stream.len() || stream[i + 1] == SEP;
        if end_of_turn {
            turn += 1;
            let got = probe_argmax(manager(sess), et, key_tok, planes);
            let t_now = sess.cache.seq_len();
            scores.push(if got == sample.answer[0] { 1.0 } else { 0.0 });
            depths.push(Some((100 * 2 / t_now) as u8));
            if turn % 2 == 0 {
                let frame =
                    encode_session(sess).map_err(|e| anyhow::anyhow!("park: {e}"))?;
                *sess = decode_session(&frame, dims, &pool)
                    .map_err(|e| anyhow::anyhow!("unpark: {e}"))?;
            }
        }
    }
    Ok(())
}

fn task_label(task: &EvalTask) -> String {
    match task {
        EvalTask::NeedleAtDepth { depth_pct, .. } => format!("needle@{depth_pct}"),
        other => other.name().to_string(),
    }
}

fn enumerate_cells(spec: &GridSpec) -> Vec<(EvalTask, String, Arm)> {
    let mut cells = Vec::with_capacity(spec.tasks.len() * spec.policies.len() * spec.arms.len());
    for task in &spec.tasks {
        for policy in &spec.policies {
            for &arm in &spec.arms {
                cells.push((task.clone(), policy.clone(), arm));
            }
        }
    }
    cells
}

fn cell_seeds(seed: u64, n: usize) -> Vec<u64> {
    let mut sm = SplitMix64::new(seed);
    (0..n).map(|_| sm.split()).collect()
}

fn run_cell(
    spec: &GridSpec,
    et: &EmbedTable,
    idx: usize,
    task: &EvalTask,
    policy: &str,
    arm: Arm,
    seed: u64,
) -> crate::Result<CellResult> {
    let dims = model_dims(spec.max_seq);
    let mut rng = Pcg32::new(seed);
    let mut scores: Vec<f64> = Vec::new();
    let mut depths: Vec<Option<u8>> = Vec::new();
    let mut cache_pct_sum = 0.0f64;
    let mut merges = 0u64;
    for i in 0..spec.samples {
        let sample = task.gen(&mut rng);
        anyhow::ensure!(
            sample.prompt.len() + 2 <= spec.max_seq,
            "task {} sample ({} tokens) exceeds max_seq {}",
            task.name(),
            sample.prompt.len(),
            spec.max_seq
        );
        let mut sess = build_session(spec, policy, arm, (idx * spec.samples + i) as u64, &dims)?;
        match task {
            EvalTask::MultiTurnDrift { .. } => {
                run_drift_sample(et, &dims, &mut sess, &sample, &mut scores, &mut depths)?
            }
            _ => {
                let (s, dp) = run_single_sample(et, &dims, &mut sess, &sample)?;
                scores.push(s);
                depths.push(dp);
            }
        }
        cache_pct_sum += sess.cache.cache_size_pct();
        merges += manager(&sess).merge_ledger().merges;
    }

    let mut bsum = [0.0f64; DEPTH_BUCKETS];
    let mut bn = [0usize; DEPTH_BUCKETS];
    for (&s, &dp) in scores.iter().zip(&depths) {
        if let Some(dp) = dp {
            let b = depth_bucket(dp);
            bsum[b] += s;
            bn[b] += 1;
        }
    }
    let mut bucket_scores = [0.0f64; DEPTH_BUCKETS];
    for b in 0..DEPTH_BUCKETS {
        if bn[b] > 0 {
            bucket_scores[b] = bsum[b] / bn[b] as f64;
        }
    }
    Ok(CellResult {
        cell: idx,
        task: task_label(task),
        family: task.name(),
        depth_pct: match *task {
            EvalTask::NeedleAtDepth { depth_pct, .. } => Some(depth_pct),
            _ => None,
        },
        policy: policy.to_string(),
        arm: arm.name(),
        n_probes: scores.len(),
        mean: scores.iter().sum::<f64>() / (scores.len().max(1)) as f64,
        worst_bucket: worst_bucket_score(&scores, &depths),
        p10: p10_score(&scores),
        bucket_scores,
        bucket_counts: bn,
        cache_pct: cache_pct_sum / spec.samples as f64,
        merges,
    })
}

/// Run the grid in-process, cell by cell.
pub fn run_grid(spec: &GridSpec) -> crate::Result<Vec<CellResult>> {
    let cells = enumerate_cells(spec);
    let seeds = cell_seeds(spec.seed, cells.len());
    let et = EmbedTable::new(spec.seed ^ EMBED_SALT, D_HEAD);
    cells
        .iter()
        .enumerate()
        .map(|(i, (task, policy, arm))| run_cell(spec, &et, i, task, policy, *arm, seeds[i]))
        .collect()
}

/// Run the grid across `workers` threads. Cells are independently seeded
/// by index and reassembled in cell order, so the result is byte-identical
/// to [`run_grid`] for every worker count.
pub fn run_grid_workers(spec: &GridSpec, workers: usize) -> crate::Result<Vec<CellResult>> {
    let workers = workers.max(1);
    let cells = enumerate_cells(spec);
    let seeds = cell_seeds(spec.seed, cells.len());
    let et = EmbedTable::new(spec.seed ^ EMBED_SALT, D_HEAD);
    let mut slots: Vec<Option<CellResult>> = Vec::new();
    slots.resize_with(cells.len(), || None);
    let chunks: Vec<crate::Result<Vec<(usize, CellResult)>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let (cells, seeds, et) = (&cells, &seeds, &et);
                s.spawn(move || -> crate::Result<Vec<(usize, CellResult)>> {
                    let mut out = Vec::new();
                    for i in (w..cells.len()).step_by(workers) {
                        let (task, policy, arm) = &cells[i];
                        out.push((i, run_cell(spec, et, i, task, policy, *arm, seeds[i])?));
                    }
                    Ok(out)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow::anyhow!("fragility worker panicked")))
            })
            .collect()
    });
    for chunk in chunks {
        for (i, r) in chunk? {
            slots[i] = Some(r);
        }
    }
    Ok(slots
        .into_iter()
        .map(|o| o.expect("every cell runs exactly once"))
        .collect())
}

/// Probe-weighted per-bucket score aggregated over every cell of one task
/// family under one arm — the numbers the bench gates compare.
pub fn aggregate_buckets(
    results: &[CellResult],
    family: &str,
    arm: &str,
) -> ([f64; DEPTH_BUCKETS], [usize; DEPTH_BUCKETS]) {
    let mut sum = [0.0f64; DEPTH_BUCKETS];
    let mut n = [0usize; DEPTH_BUCKETS];
    for r in results.iter().filter(|r| r.family == family && r.arm == arm) {
        for b in 0..DEPTH_BUCKETS {
            sum[b] += r.bucket_scores[b] * r.bucket_counts[b] as f64;
            n[b] += r.bucket_counts[b];
        }
    }
    let mut mean = [0.0f64; DEPTH_BUCKETS];
    for b in 0..DEPTH_BUCKETS {
        if n[b] > 0 {
            mean[b] = sum[b] / n[b] as f64;
        }
    }
    (mean, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> GridSpec {
        GridSpec {
            seed: 0xF7A6,
            samples: 2,
            max_seq: 64,
            ratio: 0.25,
            recent_window: 4,
            tasks: vec![
                EvalTask::NeedleAtDepth { depth_pct: 0, haystack: 40 },
                EvalTask::NeedleAtDepth { depth_pct: 90, haystack: 40 },
                EvalTask::KeyedRecall { n_keys: 8 },
                EvalTask::MultiTurnDrift { turns: 4, probe_every: 2 },
            ],
            policies: vec!["h2o".into(), "local".into()],
            arms: vec![Arm::EvictOnly, Arm::MixedPrecision, Arm::MergeInsteadOfDrop],
        }
    }

    fn fingerprint(results: &[CellResult]) -> Vec<(usize, String, u64, u64, u64)> {
        results
            .iter()
            .map(|r| {
                (
                    r.cell,
                    format!("{}/{}/{}", r.task, r.policy, r.arm),
                    r.mean.to_bits(),
                    r.worst_bucket.to_bits(),
                    r.cache_pct.to_bits(),
                )
            })
            .collect()
    }

    /// Satellite: same seed ⇒ byte-identical grid scores across two runs
    /// and across in-process vs 1 vs 2 workers.
    #[test]
    fn grid_is_deterministic_across_runs_and_workers() {
        let spec = tiny_spec();
        let a = run_grid(&spec).unwrap();
        let b = run_grid(&spec).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b), "two in-process runs");
        let w1 = run_grid_workers(&spec, 1).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&w1), "in-process vs 1 worker");
        let w2 = run_grid_workers(&spec, 2).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&w2), "1 worker vs 2 workers");
        assert_eq!(a.len(), spec.tasks.len() * 2 * 3);
    }

    /// A full-budget hi-only cache retrieves the needle at every depth —
    /// the probe machinery itself is sound.
    #[test]
    fn full_budget_cache_retrieves_every_depth() {
        let spec = GridSpec {
            ratio: 1.0,
            tasks: vec![
                EvalTask::NeedleAtDepth { depth_pct: 0, haystack: 40 },
                EvalTask::NeedleAtDepth { depth_pct: 50, haystack: 40 },
                EvalTask::NeedleAtDepth { depth_pct: 95, haystack: 40 },
            ],
            policies: vec!["h2o".into()],
            arms: vec![Arm::MixedPrecision],
            ..tiny_spec()
        };
        for cell in run_grid(&spec).unwrap() {
            assert_eq!(cell.mean, 1.0, "cell {}: {:?}", cell.task, cell);
        }
    }

    /// The headline contrast at a compressed budget: a recency policy with
    /// hi-only eviction destroys the oldest needle; MiKV mixed precision
    /// retrieves it through the lo tier.
    #[test]
    fn eviction_destroys_deep_needle_mixed_precision_recovers() {
        let spec = GridSpec {
            tasks: vec![EvalTask::NeedleAtDepth { depth_pct: 0, haystack: 40 }],
            policies: vec!["local".into()],
            arms: vec![Arm::EvictOnly, Arm::MixedPrecision],
            samples: 3,
            ..tiny_spec()
        };
        let results = run_grid(&spec).unwrap();
        let evict = results.iter().find(|r| r.arm == "evict").unwrap();
        let mikv = results.iter().find(|r| r.arm == "mikv").unwrap();
        assert!(
            evict.mean < 0.5,
            "recency eviction must lose the oldest needle: {evict:?}"
        );
        assert_eq!(
            mikv.mean, 1.0,
            "mixed precision must retrieve through the lo tier: {mikv:?}"
        );
        // worst_bucket == mean here: every probe lands in bucket 0
        assert_eq!(mikv.worst_bucket, mikv.mean);
    }

    /// The merge arm actually folds (ledger moves) and drift parking
    /// round-trips merge state through the snapshot codec.
    #[test]
    fn merge_arm_folds_and_survives_parking() {
        let spec = GridSpec {
            tasks: vec![EvalTask::MultiTurnDrift { turns: 4, probe_every: 2 }],
            policies: vec!["h2o".into()],
            arms: vec![Arm::MergeInsteadOfDrop, Arm::EvictOnly],
            ..tiny_spec()
        };
        let results = run_grid(&spec).unwrap();
        let merge = results.iter().find(|r| r.arm == "merge").unwrap();
        let evict = results.iter().find(|r| r.arm == "evict").unwrap();
        assert!(merge.merges > 0, "merge arm must fold at least once");
        assert_eq!(evict.merges, 0, "evict arm must never fold");
        assert!(merge.n_probes == evict.n_probes && merge.n_probes > 0);
    }

    #[test]
    fn aggregate_buckets_weights_by_probe_count() {
        let spec = tiny_spec();
        let results = run_grid(&spec).unwrap();
        let (mean, n) = aggregate_buckets(&results, "needle", "mikv");
        // needle@0 populates bucket 0, needle@90 bucket 3
        assert!(n[0] > 0 && n[3] > 0, "needle buckets populated: {n:?}");
        for b in 0..DEPTH_BUCKETS {
            assert!((0.0..=1.0).contains(&mean[b]), "bucket {b}: {}", mean[b]);
        }
        let (_, none) = aggregate_buckets(&results, "nosuch", "mikv");
        assert_eq!(none, [0usize; DEPTH_BUCKETS]);
    }
}
