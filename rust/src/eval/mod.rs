//! Evaluation suites: synthetic benchmarks mirroring the paper's tasks.
//!
//! * [`corpus`] — the task/token definitions, mirroring
//!   `python/compile/corpus.py` exactly (cross-checked against the
//!   manifest's corpus constants at engine load).
//! * [`harness`] — shared experiment runner: one prefill per sample fanned
//!   out to many cache configurations (prefill is cache-agnostic, so
//!   strategies share it — crucial on a 1-core testbed).
//! * [`agreement`] — generation-agreement metric vs the full-cache output
//!   (the deterministic stand-in for the paper's GPT-4-judged AlpacaEval
//!   win rate, Table 4).
//! * [`fragility`] — the artifact-free fragility scenario grid: every
//!   importance policy × every retention arm (evict / mixed-precision /
//!   merge) raced on needle-at-depth, keyed recall, and multi-turn drift,
//!   with deterministic multi-worker execution.

pub mod agreement;
pub mod corpus;
pub mod fragility;
pub mod harness;

pub use fragility::{Arm, CellResult, GridSpec};
pub use harness::{EvalOutcome, EvalTask, Harness};
