//! Shared experiment harness: one prefill, many cache configurations.
//!
//! Prefill is cache-agnostic — its outputs (K/V, attention accumulator,
//! q/k maxima) feed *any* cache configuration. The harness exploits this:
//! each sample is prefilled once, then fanned out to every strategy under
//! test, so a Fig. 6-style sweep over N strategies costs `1× prefill +
//! N× decode` instead of `N×` everything. Decode steps are batched across
//! samples up to the compiled batch size.

use super::corpus::{self, EvalSample};
use crate::model::{sampler, CacheMode, Engine, PrefillOutput, Session};
use crate::util::rng::Pcg32;

/// A task family to evaluate, with its generation parameters.
#[derive(Debug, Clone)]
pub enum EvalTask {
    /// Paper's line retrieval: `n_lines` records (+ filler tokens between).
    LineRet { n_lines: usize, filler: usize },
    /// 2-hop retrieval (GSM8k reasoning proxy).
    MultiHop { n_lines: usize },
    /// Exact motif continuation (HumanEval proxy).
    Pattern { motif: usize, repeats: usize },
    /// Markov continuation (MMLU proxy — scored by agreement vs full cache).
    Lm { context: usize, answer: usize },
}

impl EvalTask {
    pub fn name(&self) -> &'static str {
        match self {
            EvalTask::LineRet { .. } => "lineret",
            EvalTask::MultiHop { .. } => "multihop",
            EvalTask::Pattern { .. } => "pattern",
            EvalTask::Lm { .. } => "lm",
        }
    }

    pub fn gen(&self, rng: &mut Pcg32) -> EvalSample {
        match *self {
            EvalTask::LineRet { n_lines, filler } => corpus::gen_lineret(rng, n_lines, filler),
            EvalTask::MultiHop { n_lines } => corpus::gen_multihop(rng, n_lines),
            EvalTask::Pattern { motif, repeats } => corpus::gen_pattern(rng, motif, repeats),
            EvalTask::Lm { context, answer } => corpus::gen_lm(rng, context, answer),
        }
    }

    /// LM-family tasks are scored by agreement against the full-cache
    /// generation (their target is stochastic); the rest by exact match.
    pub fn scored_by_agreement(&self) -> bool {
        matches!(self, EvalTask::Lm { .. })
    }
}

/// Result of evaluating one cache mode on one task.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    pub mode_name: String,
    pub task: &'static str,
    pub n_samples: usize,
    /// Mean per-sample score in [0, 1] (exact match or agreement).
    pub accuracy: f64,
    /// Mean token agreement with the FULL-cache generation in [0, 1] —
    /// measures how faithfully the compressed cache preserves the model's
    /// behaviour, independent of task accuracy.
    pub fidelity: f64,
    /// Mean logical cache size (% of full FP16) at the end of generation.
    pub cache_pct: f64,
    /// Per-sample generations (answer-length prefix).
    pub generations: Vec<Vec<i64>>,
}

/// The experiment harness bound to one engine.
pub struct Harness<'e> {
    pub engine: &'e Engine,
    pub seed: u64,
}

impl<'e> Harness<'e> {
    pub fn new(engine: &'e Engine) -> Self {
        Harness {
            engine,
            seed: 0xE7A1,
        }
    }

    pub fn with_seed(engine: &'e Engine, seed: u64) -> Self {
        Harness { engine, seed }
    }

    /// Generate `n_samples` of a task, bounded by the model's max_seq
    /// (prompt + answer + slack must fit).
    pub fn samples(&self, task: &EvalTask, n_samples: usize) -> Vec<EvalSample> {
        let mut rng = Pcg32::new(self.seed ^ task.name().len() as u64);
        let budget = self.engine.dims().max_seq - 1;
        let mut out = Vec::with_capacity(n_samples);
        while out.len() < n_samples {
            let s = task.gen(&mut rng);
            if s.prompt.len() + s.answer.len() < budget {
                out.push(s);
            }
        }
        out
    }

    /// Evaluate several cache modes on the same samples with shared
    /// prefills. Returns one outcome per mode, in order.
    pub fn run(
        &self,
        task: &EvalTask,
        modes: &[(String, CacheMode)],
        n_samples: usize,
    ) -> crate::Result<Vec<EvalOutcome>> {
        let samples = self.samples(task, n_samples);
        let prompts: Vec<Vec<i64>> = samples.iter().map(|s| s.prompt.clone()).collect();
        let prefills = self.engine.prefill_raw(&prompts)?;

        // Full-cache reference generations: the fidelity anchor for every
        // mode, and the accuracy target for agreement-scored tasks.
        let reference = self.generate_mode(&samples, &prefills, &CacheMode::Full)?.0;

        let mut outcomes = Vec::with_capacity(modes.len());
        for (name, mode) in modes {
            let (gens, cache_pct) = self.generate_mode(&samples, &prefills, mode)?;
            let fidelity: f64 = gens
                .iter()
                .zip(&reference)
                .map(|(g, r)| super::agreement::token_agreement(g, r))
                .sum::<f64>()
                / samples.len() as f64;
            let accuracy: f64 = if task.scored_by_agreement() {
                fidelity
            } else {
                gens.iter()
                    .zip(&samples)
                    .map(|(g, s)| if g[..] == s.answer[..] { 1.0 } else { 0.0 })
                    .sum::<f64>()
                    / samples.len() as f64
            };
            outcomes.push(EvalOutcome {
                mode_name: name.clone(),
                task: task.name(),
                n_samples: samples.len(),
                accuracy,
                fidelity,
                cache_pct,
                generations: gens,
            });
            crate::log_info!(
                "eval {} / {}: acc {:.1}% fidelity {:.1}% cache {:.1}%",
                task.name(),
                name,
                100.0 * accuracy,
                100.0 * fidelity,
                cache_pct
            );
        }
        Ok(outcomes)
    }

    /// Generate answer-length continuations for all samples under one mode,
    /// reusing precomputed prefills. Returns (generations, mean cache %).
    pub fn generate_mode(
        &self,
        samples: &[EvalSample],
        prefills: &[PrefillOutput],
        mode: &CacheMode,
    ) -> crate::Result<(Vec<Vec<i64>>, f64)> {
        let dims = self.engine.dims().clone();
        let mut sessions: Vec<Session> = Vec::with_capacity(samples.len());
        for (i, (s, pf)) in samples.iter().zip(prefills).enumerate() {
            let mut sess = Session::new(i as u64, &dims, mode.clone())?;
            self.engine.ingest_prefill(&mut sess, &s.prompt, pf);
            sessions.push(sess);
        }
        let need: Vec<usize> = samples.iter().map(|s| s.answer.len()).collect();

        // Batched decode until every session has its answer tokens.
        loop {
            let mut pending: Vec<&mut Session> = sessions
                .iter_mut()
                .enumerate()
                .filter(|(i, s)| s.tokens.len() - s.prompt_len < need[*i])
                .map(|(_, s)| s)
                .collect();
            if pending.is_empty() {
                break;
            }
            let rows = self.engine.decode_step(&mut pending)?;
            for (sess, row) in pending.iter_mut().zip(rows) {
                let tok = sampler::greedy(&row);
                sess.last_token = tok;
                sess.tokens.push(tok);
            }
        }

        let mut cache_sum = 0.0;
        let gens = sessions
            .iter()
            .enumerate()
            .map(|(i, s)| {
                cache_sum += s.cache.cache_size_pct();
                s.generated()[..need[i]].to_vec()
            })
            .collect();
        Ok((gens, cache_sum / sessions.len() as f64))
    }
}
