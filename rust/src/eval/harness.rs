//! Shared experiment harness: one prefill, many cache configurations.
//!
//! Prefill is cache-agnostic — its outputs (K/V, attention accumulator,
//! q/k maxima) feed *any* cache configuration. The harness exploits this:
//! each sample is prefilled once, then fanned out to every strategy under
//! test, so a Fig. 6-style sweep over N strategies costs `1× prefill +
//! N× decode` instead of `N×` everything. Decode steps are batched across
//! samples up to the compiled batch size.

use super::corpus::{self, EvalSample};
use crate::model::{sampler, CacheMode, Engine, PrefillOutput, Session};
use crate::util::rng::Pcg32;

/// A task family to evaluate, with its generation parameters.
#[derive(Debug, Clone)]
pub enum EvalTask {
    /// Paper's line retrieval: `n_lines` records (+ filler tokens between).
    LineRet { n_lines: usize, filler: usize },
    /// 2-hop retrieval (GSM8k reasoning proxy).
    MultiHop { n_lines: usize },
    /// Exact motif continuation (HumanEval proxy).
    Pattern { motif: usize, repeats: usize },
    /// Markov continuation (MMLU proxy — scored by agreement vs full cache).
    Lm { context: usize, answer: usize },
    /// Fragility: needle-in-a-haystack with the needle pinned at
    /// `depth_pct`% of the context (0 = oldest — the position eviction
    /// destroys first).
    NeedleAtDepth { depth_pct: u8, haystack: usize },
    /// Fragility: a long multi-turn transcript; the query asks for the
    /// turn-0 fact after `turns` turns of drift, with recency-rehearsal
    /// probes every `probe_every` turns competing for the budget.
    MultiTurnDrift { turns: usize, probe_every: usize },
    /// Fragility: `n_keys` keyed facts, query a uniformly random one —
    /// samples populate every depth bucket, so the worst bucket exposes
    /// positional failure the mean hides.
    KeyedRecall { n_keys: usize },
}

impl EvalTask {
    pub fn name(&self) -> &'static str {
        match self {
            EvalTask::LineRet { .. } => "lineret",
            EvalTask::MultiHop { .. } => "multihop",
            EvalTask::Pattern { .. } => "pattern",
            EvalTask::Lm { .. } => "lm",
            EvalTask::NeedleAtDepth { .. } => "needle",
            EvalTask::MultiTurnDrift { .. } => "drift",
            EvalTask::KeyedRecall { .. } => "keyedrecall",
        }
    }

    pub fn gen(&self, rng: &mut Pcg32) -> EvalSample {
        match *self {
            EvalTask::LineRet { n_lines, filler } => corpus::gen_lineret(rng, n_lines, filler),
            EvalTask::MultiHop { n_lines } => corpus::gen_multihop(rng, n_lines),
            EvalTask::Pattern { motif, repeats } => corpus::gen_pattern(rng, motif, repeats),
            EvalTask::Lm { context, answer } => corpus::gen_lm(rng, context, answer),
            EvalTask::NeedleAtDepth { depth_pct, haystack } => {
                corpus::gen_needle_at_depth(rng, depth_pct, haystack)
            }
            EvalTask::MultiTurnDrift { turns, probe_every } => {
                corpus::gen_multiturn_drift(rng, turns, probe_every)
            }
            EvalTask::KeyedRecall { n_keys } => corpus::gen_keyed_recall(rng, n_keys),
        }
    }

    /// LM-family tasks are scored by agreement against the full-cache
    /// generation (their target is stochastic); the rest by exact match.
    pub fn scored_by_agreement(&self) -> bool {
        matches!(self, EvalTask::Lm { .. })
    }
}

// ----------------------------------------------------------------------
// Fragility scoring: mean accuracy hides positional failure (a cache that
// answers every recent query and no deep one still scores 75% on a uniform
// mix). Scores are therefore also bucketed by fact depth, and the *worst*
// bucket is reported alongside the mean.
// ----------------------------------------------------------------------

/// Number of depth buckets: [0,25) [25,50) [50,75) [75,100].
pub const DEPTH_BUCKETS: usize = 4;

/// Bucket index for a fact depth percentage.
pub fn depth_bucket(depth_pct: u8) -> usize {
    ((depth_pct as usize) / 25).min(DEPTH_BUCKETS - 1)
}

/// Mean score of the worst-scoring populated depth bucket. Samples with no
/// recorded depth share one extra bucket, so for depth-less task families
/// this degenerates to the overall mean. Returns 0.0 for an empty slice.
pub fn worst_bucket_score(scores: &[f64], depths: &[Option<u8>]) -> f64 {
    debug_assert_eq!(scores.len(), depths.len());
    let mut sum = [0.0f64; DEPTH_BUCKETS + 1];
    let mut n = [0usize; DEPTH_BUCKETS + 1];
    for (&s, &d) in scores.iter().zip(depths) {
        let b = d.map_or(DEPTH_BUCKETS, |d| depth_bucket(d));
        sum[b] += s;
        n[b] += 1;
    }
    let worst = (0..=DEPTH_BUCKETS)
        .filter(|&b| n[b] > 0)
        .map(|b| sum[b] / n[b] as f64)
        .fold(f64::INFINITY, f64::min);
    if worst.is_finite() {
        worst
    } else {
        0.0
    }
}

/// 10th-percentile per-sample score (lower tail of the distribution — the
/// reliability number the paper's "no token left behind" claim is about).
pub fn p10_score(scores: &[f64]) -> f64 {
    if scores.is_empty() {
        return 0.0;
    }
    let mut sorted = scores.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite scores"));
    sorted[(sorted.len() - 1) * 10 / 100]
}

/// Result of evaluating one cache mode on one task.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    pub mode_name: String,
    pub task: &'static str,
    pub n_samples: usize,
    /// Mean per-sample score in [0, 1] (exact match or agreement).
    pub accuracy: f64,
    /// Mean score of the worst-scoring populated depth bucket
    /// ([`worst_bucket_score`]) — equals `accuracy` for task families that
    /// don't record fact depth.
    pub worst_bucket: f64,
    /// 10th-percentile per-sample score ([`p10_score`]).
    pub p10_score: f64,
    /// Mean token agreement with the FULL-cache generation in [0, 1] —
    /// measures how faithfully the compressed cache preserves the model's
    /// behaviour, independent of task accuracy.
    pub fidelity: f64,
    /// Mean logical cache size (% of full FP16) at the end of generation.
    pub cache_pct: f64,
    /// Per-sample generations (answer-length prefix).
    pub generations: Vec<Vec<i64>>,
}

/// The experiment harness bound to one engine.
pub struct Harness<'e> {
    pub engine: &'e Engine,
    pub seed: u64,
}

impl<'e> Harness<'e> {
    pub fn new(engine: &'e Engine) -> Self {
        Harness {
            engine,
            seed: 0xE7A1,
        }
    }

    pub fn with_seed(engine: &'e Engine, seed: u64) -> Self {
        Harness { engine, seed }
    }

    /// Generate `n_samples` of a task, bounded by the model's max_seq
    /// (prompt + answer + slack must fit).
    pub fn samples(&self, task: &EvalTask, n_samples: usize) -> Vec<EvalSample> {
        let mut rng = Pcg32::new(self.seed ^ task.name().len() as u64);
        let budget = self.engine.dims().max_seq - 1;
        let mut out = Vec::with_capacity(n_samples);
        while out.len() < n_samples {
            let s = task.gen(&mut rng);
            if s.prompt.len() + s.answer.len() < budget {
                out.push(s);
            }
        }
        out
    }

    /// Evaluate several cache modes on the same samples with shared
    /// prefills. Returns one outcome per mode, in order.
    pub fn run(
        &self,
        task: &EvalTask,
        modes: &[(String, CacheMode)],
        n_samples: usize,
    ) -> crate::Result<Vec<EvalOutcome>> {
        let samples = self.samples(task, n_samples);
        let prompts: Vec<Vec<i64>> = samples.iter().map(|s| s.prompt.clone()).collect();
        let prefills = self.engine.prefill_raw(&prompts)?;

        // Full-cache reference generations: the fidelity anchor for every
        // mode, and the accuracy target for agreement-scored tasks.
        let reference = self.generate_mode(&samples, &prefills, &CacheMode::Full)?.0;

        let depths: Vec<Option<u8>> = samples.iter().map(|s| s.depth_pct).collect();
        let mut outcomes = Vec::with_capacity(modes.len());
        for (name, mode) in modes {
            let (gens, cache_pct) = self.generate_mode(&samples, &prefills, mode)?;
            // Per-sample scores: agreement-vs-reference for stochastic
            // tasks, exact match otherwise.
            let scores: Vec<f64> = if task.scored_by_agreement() {
                gens.iter()
                    .zip(&reference)
                    .map(|(g, r)| super::agreement::token_agreement(g, r))
                    .collect()
            } else {
                gens.iter()
                    .zip(&samples)
                    .map(|(g, s)| if g[..] == s.answer[..] { 1.0 } else { 0.0 })
                    .collect()
            };
            let fidelity: f64 = gens
                .iter()
                .zip(&reference)
                .map(|(g, r)| super::agreement::token_agreement(g, r))
                .sum::<f64>()
                / samples.len() as f64;
            let accuracy = scores.iter().sum::<f64>() / samples.len() as f64;
            outcomes.push(EvalOutcome {
                mode_name: name.clone(),
                task: task.name(),
                n_samples: samples.len(),
                accuracy,
                worst_bucket: worst_bucket_score(&scores, &depths),
                p10_score: p10_score(&scores),
                fidelity,
                cache_pct,
                generations: gens,
            });
            crate::log_info!(
                "eval {} / {}: acc {:.1}% worst-bucket {:.1}% fidelity {:.1}% cache {:.1}%",
                task.name(),
                name,
                100.0 * accuracy,
                100.0 * worst_bucket_score(&scores, &depths),
                100.0 * fidelity,
                cache_pct
            );
        }
        Ok(outcomes)
    }

    /// Generate answer-length continuations for all samples under one mode,
    /// reusing precomputed prefills. Returns (generations, mean cache %).
    pub fn generate_mode(
        &self,
        samples: &[EvalSample],
        prefills: &[PrefillOutput],
        mode: &CacheMode,
    ) -> crate::Result<(Vec<Vec<i64>>, f64)> {
        let dims = self.engine.dims().clone();
        let mut sessions: Vec<Session> = Vec::with_capacity(samples.len());
        for (i, (s, pf)) in samples.iter().zip(prefills).enumerate() {
            let mut sess = Session::new(i as u64, &dims, mode.clone())?;
            self.engine.ingest_prefill(&mut sess, &s.prompt, pf);
            sessions.push(sess);
        }
        let need: Vec<usize> = samples.iter().map(|s| s.answer.len()).collect();

        // Batched decode until every session has its answer tokens.
        loop {
            let mut pending: Vec<&mut Session> = sessions
                .iter_mut()
                .enumerate()
                .filter(|(i, s)| s.tokens.len() - s.prompt_len < need[*i])
                .map(|(_, s)| s)
                .collect();
            if pending.is_empty() {
                break;
            }
            let rows = self.engine.decode_step(&mut pending)?;
            for (sess, row) in pending.iter_mut().zip(rows) {
                let tok = sampler::greedy(&row);
                sess.last_token = tok;
                sess.tokens.push(tok);
            }
        }

        let mut cache_sum = 0.0;
        let gens = sessions
            .iter()
            .enumerate()
            .map(|(i, s)| {
                cache_sum += s.cache.cache_size_pct();
                s.generated()[..need[i]].to_vec()
            })
            .collect();
        Ok((gens, cache_sum / sessions.len() as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pinned values for the fragility scoring helpers: one perfect bucket
    /// must not rescue a destroyed one.
    #[test]
    fn worst_bucket_pinned_values() {
        // one sample per bucket: buckets score 1.0 / 1.0 / 0.5 / 0.0
        let scores = [1.0, 1.0, 0.5, 0.0];
        let depths = [Some(0u8), Some(30), Some(60), Some(90)];
        assert_eq!(worst_bucket_score(&scores, &depths), 0.0);

        // same scores, all depth-less → single bucket → the plain mean
        let none = [None; 4];
        assert_eq!(worst_bucket_score(&scores, &none), 0.625);

        // bucket boundaries: 24 → bucket 0, 25 → bucket 1, 100 → bucket 3
        assert_eq!(depth_bucket(24), 0);
        assert_eq!(depth_bucket(25), 1);
        assert_eq!(depth_bucket(74), 2);
        assert_eq!(depth_bucket(75), 3);
        assert_eq!(depth_bucket(100), 3);

        // two samples in one bucket average before the min is taken
        let scores = [0.0, 1.0, 1.0];
        let depths = [Some(10u8), Some(12), Some(80)];
        assert_eq!(worst_bucket_score(&scores, &depths), 0.5);

        assert_eq!(worst_bucket_score(&[], &[]), 0.0);
    }

    #[test]
    fn p10_pinned_values() {
        // 10 samples: p10 lands on the 2nd-smallest ((10-1)*10/100 = 0 → min)
        let scores = [1.0, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        assert_eq!(p10_score(&scores), 0.0);
        // 21 samples: index (21-1)*10/100 = 2 → third smallest
        let mut scores: Vec<f64> = (0..21).map(|i| i as f64 / 20.0).collect();
        scores.reverse();
        assert_eq!(p10_score(&scores), 0.1);
        assert_eq!(p10_score(&[]), 0.0);
        assert_eq!(p10_score(&[0.7]), 0.7);
    }

    /// The `EvalOutcome` fields thread through hand-built construction —
    /// the reporting fix locked as a regression test: `worst_bucket` and
    /// `p10_score` exist alongside the mean and need not agree with it.
    #[test]
    fn outcome_reports_worst_bucket_alongside_mean() {
        let scores = [1.0, 1.0, 1.0, 0.0];
        let depths = [Some(5u8), Some(40), Some(60), Some(95)];
        let o = EvalOutcome {
            mode_name: "mikv:0.2:int2".into(),
            task: "needle",
            n_samples: scores.len(),
            accuracy: scores.iter().sum::<f64>() / scores.len() as f64,
            worst_bucket: worst_bucket_score(&scores, &depths),
            p10_score: p10_score(&scores),
            fidelity: 1.0,
            cache_pct: 32.0,
            generations: Vec::new(),
        };
        assert_eq!(o.accuracy, 0.75);
        assert_eq!(o.worst_bucket, 0.0, "the deep-needle failure must surface");
        assert!(o.accuracy > o.worst_bucket, "mean hides what worst exposes");
    }
}
