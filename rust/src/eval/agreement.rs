//! Generation-agreement metrics — the AlpacaEval proxy (paper Table 4).
//!
//! The paper measures a GPT-4-judged win rate of MiKV generations against
//! full-cache generations (≈50% ⇒ no quality drop). Without a judge model,
//! we report the deterministic analogue: token agreement between the
//! compressed-cache generation and the full-cache generation from the same
//! prompt under greedy decoding. A *proxy win rate* maps agreement onto the
//! paper's 50%-means-parity scale: identical generations are a tie (0.5);
//! divergent generations earn `0.5 × agreement`, so 50% ⇔ indistinguishable
//! from the full cache.

/// Fraction of positions where the two generations emit the same token
/// (over the longer length; missing positions count as disagreement).
pub fn token_agreement(a: &[i64], b: &[i64]) -> f64 {
    let n = a.len().max(b.len());
    if n == 0 {
        return 1.0;
    }
    let same = a.iter().zip(b.iter()).filter(|(x, y)| x == y).count();
    same as f64 / n as f64
}

/// Length of the longest common prefix.
pub fn prefix_match(a: &[i64], b: &[i64]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

/// Aggregated agreement over many prompt pairs.
#[derive(Debug, Clone, Default)]
pub struct AgreementStats {
    pub n: usize,
    pub identical: usize,
    pub sum_agreement: f64,
    pub sum_prefix_frac: f64,
}

impl AgreementStats {
    pub fn add(&mut self, compressed: &[i64], full: &[i64]) {
        self.n += 1;
        let agree = token_agreement(compressed, full);
        self.sum_agreement += agree;
        let n = compressed.len().max(full.len()).max(1);
        self.sum_prefix_frac += prefix_match(compressed, full) as f64 / n as f64;
        if agree == 1.0 {
            self.identical += 1;
        }
    }

    pub fn mean_agreement(&self) -> f64 {
        if self.n == 0 {
            return 1.0;
        }
        self.sum_agreement / self.n as f64
    }

    pub fn identical_rate(&self) -> f64 {
        if self.n == 0 {
            return 1.0;
        }
        self.identical as f64 / self.n as f64
    }

    /// Proxy win rate on the paper's scale: 50% ⇔ parity with full cache.
    pub fn proxy_win_rate(&self) -> f64 {
        50.0 * self.mean_agreement()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn agreement_basics() {
        assert_eq!(token_agreement(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(token_agreement(&[1, 2, 3], &[1, 9, 3]), 2.0 / 3.0);
        assert_eq!(token_agreement(&[], &[]), 1.0);
        // length mismatch counts against agreement
        assert_eq!(token_agreement(&[1, 2], &[1, 2, 3, 4]), 0.5);
    }

    #[test]
    fn prefix_basics() {
        assert_eq!(prefix_match(&[1, 2, 3], &[1, 2, 9]), 2);
        assert_eq!(prefix_match(&[5], &[1]), 0);
        assert_eq!(prefix_match(&[], &[1]), 0);
    }

    #[test]
    fn stats_aggregate() {
        let mut s = AgreementStats::default();
        s.add(&[1, 2, 3], &[1, 2, 3]); // identical
        s.add(&[1, 0, 0], &[1, 2, 3]); // 1/3 agreement
        assert_eq!(s.n, 2);
        assert_eq!(s.identical, 1);
        assert!((s.mean_agreement() - (1.0 + 1.0 / 3.0) / 2.0).abs() < 1e-9);
        assert!((s.proxy_win_rate() - 50.0 * s.mean_agreement()).abs() < 1e-9);
    }

    #[test]
    fn perfect_parity_is_fifty_percent() {
        let mut s = AgreementStats::default();
        for _ in 0..10 {
            s.add(&[4, 4, 4], &[4, 4, 4]);
        }
        assert_eq!(s.proxy_win_rate(), 50.0);
        assert_eq!(s.identical_rate(), 1.0);
    }
}
