//! Synthetic task corpus — rust mirror of `python/compile/corpus.py`.
//!
//! The evaluation side generates *fresh held-out samples* from the same
//! distribution the model was trained on. Token layout constants must match
//! the python side bit-for-bit; [`check_manifest_constants`] verifies them
//! against the constants recorded in `artifacts/manifest.json`.

use crate::util::rng::Pcg32;
use std::collections::BTreeMap;

pub const PAD: i64 = 0;
pub const BOS: i64 = 1;
pub const REC: i64 = 2;
pub const SEP: i64 = 3;
pub const QUERY: i64 = 4;
pub const ANS: i64 = 5;
pub const EOS: i64 = 6;
pub const HOP: i64 = 7;

pub const KEY_BASE: i64 = 16;
pub const KEY_N: i64 = 200;
pub const VAL_BASE: i64 = 216;
pub const VAL_N: i64 = 100;
pub const FILL_BASE: i64 = 316;
pub const FILL_N: i64 = 96;
pub const PAT_BASE: i64 = 412;
pub const PAT_N: i64 = 100;

pub const VOCAB: i64 = 512;
pub const KEY_TOKS: usize = 1;
pub const VAL_TOKS: usize = 2;

/// Verify the manifest's corpus constants match this module.
pub fn check_manifest_constants(consts: &BTreeMap<String, i64>) -> crate::Result<()> {
    let ours: &[(&str, i64)] = &[
        ("PAD", PAD), ("BOS", BOS), ("REC", REC), ("SEP", SEP),
        ("QUERY", QUERY), ("ANS", ANS), ("EOS", EOS), ("HOP", HOP),
        ("KEY_BASE", KEY_BASE), ("KEY_N", KEY_N),
        ("VAL_BASE", VAL_BASE), ("VAL_N", VAL_N),
        ("FILL_BASE", FILL_BASE), ("FILL_N", FILL_N),
        ("PAT_BASE", PAT_BASE), ("PAT_N", PAT_N),
        ("VOCAB", VOCAB),
        ("KEY_TOKS", KEY_TOKS as i64), ("VAL_TOKS", VAL_TOKS as i64),
    ];
    for (name, v) in ours {
        match consts.get(*name) {
            Some(m) if m == v => {}
            Some(m) => anyhow::bail!("corpus constant {name}: rust {v} != manifest {m}"),
            None => anyhow::bail!("corpus constant {name} missing from manifest"),
        }
    }
    Ok(())
}

/// One evaluation sample: a prompt and its expected continuation.
#[derive(Debug, Clone)]
pub struct EvalSample {
    pub prompt: Vec<i64>,
    pub answer: Vec<i64>,
    pub family: &'static str,
}

fn key(rng: &mut Pcg32) -> Vec<i64> {
    (0..KEY_TOKS)
        .map(|_| KEY_BASE + rng.gen_below(KEY_N as u32) as i64)
        .collect()
}

fn val(rng: &mut Pcg32) -> Vec<i64> {
    (0..VAL_TOKS)
        .map(|_| VAL_BASE + rng.gen_below(VAL_N as u32) as i64)
        .collect()
}

fn distinct_keys(rng: &mut Pcg32, n: usize) -> Vec<Vec<i64>> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let k = key(rng);
        if seen.insert(k.clone()) {
            out.push(k);
        }
    }
    out
}

/// Order-2 Markov filler (same transition structure as the python side).
pub fn gen_filler(rng: &mut Pcg32, n: usize) -> Vec<i64> {
    let mut out = Vec::with_capacity(n);
    let mut a = rng.gen_below(FILL_N as u32) as i64;
    let mut b = rng.gen_below(FILL_N as u32) as i64;
    for _ in 0..n {
        let succ = (a * 7 + b * 13 + rng.gen_below(4) as i64 * 31) % FILL_N;
        out.push(FILL_BASE + succ);
        a = b;
        b = succ;
    }
    out
}

/// The paper's line-retrieval task. Canonical-induction format (matches
/// the python training corpus): records are `[REC, k, v…]` and the prompt
/// ends right after the query key — the answer is its value.
pub fn gen_lineret(rng: &mut Pcg32, n_lines: usize, filler_between: usize) -> EvalSample {
    let keys = distinct_keys(rng, n_lines);
    let vals: Vec<Vec<i64>> = (0..n_lines).map(|_| val(rng)).collect();
    let mut prompt = vec![BOS];
    for (k, v) in keys.iter().zip(&vals) {
        prompt.push(REC);
        prompt.extend(k);
        prompt.extend(v);
        if filler_between > 0 {
            prompt.extend(gen_filler(rng, filler_between));
        }
    }
    let qi = rng.gen_below(n_lines as u32) as usize;
    prompt.push(QUERY);
    prompt.extend(&keys[qi]);
    EvalSample {
        prompt,
        answer: vals[qi].clone(),
        family: "lineret",
    }
}

/// 2-hop retrieval (GSM8k "reasoning" proxy).
pub fn gen_multihop(rng: &mut Pcg32, n_lines: usize) -> EvalSample {
    let n_chain = (n_lines / 2).max(2);
    let keys_a = distinct_keys(rng, n_chain);
    let keys_b = distinct_keys(rng, n_chain);
    let vals: Vec<Vec<i64>> = (0..n_chain).map(|_| val(rng)).collect();
    // records: hop `[REC, ka, HOP, kb]` and value `[REC, kb, v…]`, shuffled
    let mut recs: Vec<(bool, &Vec<i64>, Vec<i64>)> = Vec::new();
    for i in 0..n_chain {
        recs.push((true, &keys_a[i], keys_b[i].clone()));
        recs.push((false, &keys_b[i], vals[i].clone()));
    }
    let mut order: Vec<usize> = (0..recs.len()).collect();
    rng.shuffle(&mut order);
    let mut prompt = vec![BOS];
    for &i in &order {
        let (is_hop, lhs, rhs) = &recs[i];
        prompt.push(REC);
        prompt.extend(*lhs);
        if *is_hop {
            prompt.push(HOP);
        }
        prompt.extend(rhs);
    }
    let qi = rng.gen_below(n_chain as u32) as usize;
    prompt.push(QUERY);
    prompt.extend(&keys_a[qi]);
    EvalSample {
        prompt,
        answer: vals[qi].clone(),
        family: "multihop",
    }
}

/// Exact motif continuation (HumanEval "syntactic agreement" proxy).
pub fn gen_pattern(rng: &mut Pcg32, motif_len: usize, repeats: usize) -> EvalSample {
    let motif: Vec<i64> = (0..motif_len)
        .map(|_| PAT_BASE + rng.gen_below(PAT_N as u32) as i64)
        .collect();
    let mut full = Vec::with_capacity(motif_len * repeats);
    for _ in 0..repeats {
        full.extend(&motif);
    }
    let cut = full.len() - motif_len;
    let mut prompt = vec![BOS];
    prompt.extend(&full[..cut]);
    EvalSample {
        prompt,
        answer: full[cut..].to_vec(),
        family: "pattern",
    }
}

/// Filler continuation (MMLU / perplexity proxy): predict the next chunk of
/// a Markov stream. Scored as next-token agreement vs the full-cache model
/// rather than exact match (the chain is stochastic).
pub fn gen_lm(rng: &mut Pcg32, n_context: usize, n_answer: usize) -> EvalSample {
    let stream = gen_filler(rng, n_context + n_answer);
    let mut prompt = vec![BOS];
    prompt.extend(&stream[..n_context]);
    EvalSample {
        prompt,
        answer: stream[n_context..].to_vec(),
        family: "filler",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lineret_structure() {
        let mut rng = Pcg32::new(1);
        let s = gen_lineret(&mut rng, 6, 0);
        assert_eq!(s.prompt[0], BOS);
        // prompt ends with the query key
        let qpos = s.prompt.iter().position(|&t| t == QUERY).unwrap();
        assert_eq!(qpos + KEY_TOKS, s.prompt.len() - 1);
        assert_eq!(s.answer.len(), VAL_TOKS);
        assert!(s.answer.iter().all(|&t| (VAL_BASE..VAL_BASE + VAL_N).contains(&t)));
        // queried key appears exactly once in the records; value follows it
        let qkey = &s.prompt[qpos + 1..qpos + 1 + KEY_TOKS];
        let mut found = 0;
        for i in 0..qpos {
            if s.prompt[i] == REC && &s.prompt[i + 1..i + 1 + KEY_TOKS] == qkey {
                let v = &s.prompt[i + 1 + KEY_TOKS..i + 1 + KEY_TOKS + VAL_TOKS];
                assert_eq!(v, &s.answer[..]);
                found += 1;
            }
        }
        assert_eq!(found, 1);
    }

    #[test]
    fn multihop_chain_resolves() {
        let mut rng = Pcg32::new(2);
        let s = gen_multihop(&mut rng, 10);
        let qpos = s.prompt.iter().position(|&t| t == QUERY).unwrap();
        let ka = s.prompt[qpos + 1..qpos + 1 + KEY_TOKS].to_vec();
        // hop record: [REC, lhs, HOP, kb]; value record: [REC, lhs, v...]
        let find_hop = |lhs: &[i64]| -> Option<Vec<i64>> {
            (0..qpos).find_map(|i| {
                (s.prompt[i] == REC
                    && &s.prompt[i + 1..i + 1 + KEY_TOKS] == lhs
                    && s.prompt[i + 1 + KEY_TOKS] == HOP)
                    .then(|| s.prompt[i + 2 + KEY_TOKS..i + 2 + 2 * KEY_TOKS].to_vec())
            })
        };
        let find_val = |lhs: &[i64]| -> Option<Vec<i64>> {
            (0..qpos).find_map(|i| {
                (s.prompt[i] == REC
                    && &s.prompt[i + 1..i + 1 + KEY_TOKS] == lhs
                    && s.prompt[i + 1 + KEY_TOKS] != HOP)
                    .then(|| s.prompt[i + 1 + KEY_TOKS..i + 1 + KEY_TOKS + VAL_TOKS].to_vec())
            })
        };
        let kb = find_hop(&ka).expect("hop record");
        let v = find_val(&kb).expect("value record");
        assert_eq!(v, s.answer);
    }

    #[test]
    fn pattern_answer_continues_motif() {
        let mut rng = Pcg32::new(3);
        let s = gen_pattern(&mut rng, 5, 4);
        assert_eq!(s.answer.len(), 5);
        // the answer equals the first 5 non-BOS prompt tokens (motif)
        assert_eq!(&s.prompt[1..6], &s.answer[..]);
    }

    #[test]
    fn filler_tokens_in_range() {
        let mut rng = Pcg32::new(4);
        let s = gen_lm(&mut rng, 30, 5);
        for &t in s.prompt[1..].iter().chain(&s.answer) {
            assert!((FILL_BASE..FILL_BASE + FILL_N).contains(&t));
        }
    }

    #[test]
    fn constants_check_catches_mismatch() {
        let mut m: BTreeMap<String, i64> = [("PAD", 0i64), ("BOS", 1)]
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect();
        assert!(check_manifest_constants(&m).is_err()); // missing keys
        for (k, v) in [
            ("REC", 2i64), ("SEP", 3), ("QUERY", 4), ("ANS", 5), ("EOS", 6),
            ("HOP", 7), ("KEY_BASE", 16), ("KEY_N", 200), ("VAL_BASE", 216),
            ("VAL_N", 100), ("FILL_BASE", 316), ("FILL_N", 96),
            ("PAT_BASE", 412), ("PAT_N", 100), ("VOCAB", 512),
            ("KEY_TOKS", 1), ("VAL_TOKS", 2),
        ] {
            m.insert(k.to_string(), v);
        }
        assert!(check_manifest_constants(&m).is_ok());
        m.insert("VOCAB".into(), 1024);
        assert!(check_manifest_constants(&m).is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gen_lineret(&mut Pcg32::new(9), 5, 1);
        let b = gen_lineret(&mut Pcg32::new(9), 5, 1);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.answer, b.answer);
    }
}
