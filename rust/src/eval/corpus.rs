//! Synthetic task corpus — rust mirror of `python/compile/corpus.py`.
//!
//! The evaluation side generates *fresh held-out samples* from the same
//! distribution the model was trained on. Token layout constants must match
//! the python side bit-for-bit; [`check_manifest_constants`] verifies them
//! against the constants recorded in `artifacts/manifest.json`.

use crate::util::rng::Pcg32;
use std::collections::BTreeMap;

pub const PAD: i64 = 0;
pub const BOS: i64 = 1;
pub const REC: i64 = 2;
pub const SEP: i64 = 3;
pub const QUERY: i64 = 4;
pub const ANS: i64 = 5;
pub const EOS: i64 = 6;
pub const HOP: i64 = 7;

pub const KEY_BASE: i64 = 16;
pub const KEY_N: i64 = 200;
pub const VAL_BASE: i64 = 216;
pub const VAL_N: i64 = 100;
pub const FILL_BASE: i64 = 316;
pub const FILL_N: i64 = 96;
pub const PAT_BASE: i64 = 412;
pub const PAT_N: i64 = 100;

pub const VOCAB: i64 = 512;
pub const KEY_TOKS: usize = 1;
pub const VAL_TOKS: usize = 2;

/// Verify the manifest's corpus constants match this module.
pub fn check_manifest_constants(consts: &BTreeMap<String, i64>) -> crate::Result<()> {
    let ours: &[(&str, i64)] = &[
        ("PAD", PAD), ("BOS", BOS), ("REC", REC), ("SEP", SEP),
        ("QUERY", QUERY), ("ANS", ANS), ("EOS", EOS), ("HOP", HOP),
        ("KEY_BASE", KEY_BASE), ("KEY_N", KEY_N),
        ("VAL_BASE", VAL_BASE), ("VAL_N", VAL_N),
        ("FILL_BASE", FILL_BASE), ("FILL_N", FILL_N),
        ("PAT_BASE", PAT_BASE), ("PAT_N", PAT_N),
        ("VOCAB", VOCAB),
        ("KEY_TOKS", KEY_TOKS as i64), ("VAL_TOKS", VAL_TOKS as i64),
    ];
    for (name, v) in ours {
        match consts.get(*name) {
            Some(m) if m == v => {}
            Some(m) => anyhow::bail!("corpus constant {name}: rust {v} != manifest {m}"),
            None => anyhow::bail!("corpus constant {name} missing from manifest"),
        }
    }
    Ok(())
}

/// One evaluation sample: a prompt and its expected continuation.
#[derive(Debug, Clone)]
pub struct EvalSample {
    pub prompt: Vec<i64>,
    pub answer: Vec<i64>,
    pub family: &'static str,
    /// Where the fact being queried sits in the prompt, as a percentage of
    /// the prompt length (0 = oldest context). `None` for task families
    /// without a single well-defined fact position; `Some` feeds the
    /// per-depth-bucket fragility scores ([`crate::eval::harness`]).
    pub depth_pct: Option<u8>,
}

fn key(rng: &mut Pcg32) -> Vec<i64> {
    (0..KEY_TOKS)
        .map(|_| KEY_BASE + rng.gen_below(KEY_N as u32) as i64)
        .collect()
}

fn val(rng: &mut Pcg32) -> Vec<i64> {
    (0..VAL_TOKS)
        .map(|_| VAL_BASE + rng.gen_below(VAL_N as u32) as i64)
        .collect()
}

fn distinct_keys(rng: &mut Pcg32, n: usize) -> Vec<Vec<i64>> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let k = key(rng);
        if seen.insert(k.clone()) {
            out.push(k);
        }
    }
    out
}

/// Order-2 Markov filler (same transition structure as the python side).
pub fn gen_filler(rng: &mut Pcg32, n: usize) -> Vec<i64> {
    let mut out = Vec::with_capacity(n);
    let mut a = rng.gen_below(FILL_N as u32) as i64;
    let mut b = rng.gen_below(FILL_N as u32) as i64;
    for _ in 0..n {
        let succ = (a * 7 + b * 13 + rng.gen_below(4) as i64 * 31) % FILL_N;
        out.push(FILL_BASE + succ);
        a = b;
        b = succ;
    }
    out
}

/// The paper's line-retrieval task. Canonical-induction format (matches
/// the python training corpus): records are `[REC, k, v…]` and the prompt
/// ends right after the query key — the answer is its value.
pub fn gen_lineret(rng: &mut Pcg32, n_lines: usize, filler_between: usize) -> EvalSample {
    let keys = distinct_keys(rng, n_lines);
    let vals: Vec<Vec<i64>> = (0..n_lines).map(|_| val(rng)).collect();
    let mut prompt = vec![BOS];
    for (k, v) in keys.iter().zip(&vals) {
        prompt.push(REC);
        prompt.extend(k);
        prompt.extend(v);
        if filler_between > 0 {
            prompt.extend(gen_filler(rng, filler_between));
        }
    }
    let qi = rng.gen_below(n_lines as u32) as usize;
    prompt.push(QUERY);
    prompt.extend(&keys[qi]);
    EvalSample {
        prompt,
        answer: vals[qi].clone(),
        family: "lineret",
        depth_pct: None,
    }
}

/// 2-hop retrieval (GSM8k "reasoning" proxy).
pub fn gen_multihop(rng: &mut Pcg32, n_lines: usize) -> EvalSample {
    let n_chain = (n_lines / 2).max(2);
    let keys_a = distinct_keys(rng, n_chain);
    let keys_b = distinct_keys(rng, n_chain);
    let vals: Vec<Vec<i64>> = (0..n_chain).map(|_| val(rng)).collect();
    // records: hop `[REC, ka, HOP, kb]` and value `[REC, kb, v…]`, shuffled
    let mut recs: Vec<(bool, &Vec<i64>, Vec<i64>)> = Vec::new();
    for i in 0..n_chain {
        recs.push((true, &keys_a[i], keys_b[i].clone()));
        recs.push((false, &keys_b[i], vals[i].clone()));
    }
    let mut order: Vec<usize> = (0..recs.len()).collect();
    rng.shuffle(&mut order);
    let mut prompt = vec![BOS];
    for &i in &order {
        let (is_hop, lhs, rhs) = &recs[i];
        prompt.push(REC);
        prompt.extend(*lhs);
        if *is_hop {
            prompt.push(HOP);
        }
        prompt.extend(rhs);
    }
    let qi = rng.gen_below(n_chain as u32) as usize;
    prompt.push(QUERY);
    prompt.extend(&keys_a[qi]);
    EvalSample {
        prompt,
        answer: vals[qi].clone(),
        family: "multihop",
        depth_pct: None,
    }
}

/// Exact motif continuation (HumanEval "syntactic agreement" proxy).
pub fn gen_pattern(rng: &mut Pcg32, motif_len: usize, repeats: usize) -> EvalSample {
    let motif: Vec<i64> = (0..motif_len)
        .map(|_| PAT_BASE + rng.gen_below(PAT_N as u32) as i64)
        .collect();
    let mut full = Vec::with_capacity(motif_len * repeats);
    for _ in 0..repeats {
        full.extend(&motif);
    }
    let cut = full.len() - motif_len;
    let mut prompt = vec![BOS];
    prompt.extend(&full[..cut]);
    EvalSample {
        prompt,
        answer: full[cut..].to_vec(),
        family: "pattern",
        depth_pct: None,
    }
}

/// Filler continuation (MMLU / perplexity proxy): predict the next chunk of
/// a Markov stream. Scored as next-token agreement vs the full-cache model
/// rather than exact match (the chain is stochastic).
pub fn gen_lm(rng: &mut Pcg32, n_context: usize, n_answer: usize) -> EvalSample {
    let stream = gen_filler(rng, n_context + n_answer);
    let mut prompt = vec![BOS];
    prompt.extend(&stream[..n_context]);
    EvalSample {
        prompt,
        answer: stream[n_context..].to_vec(),
        family: "filler",
        depth_pct: None,
    }
}

// ----------------------------------------------------------------------
// Fragility tasks: the scenarios where compression schemes actually break
// (needle position, long-session drift, uniform keyed recall). Each sample
// records `depth_pct` so scores can be bucketed by fact position.
// ----------------------------------------------------------------------

/// Needle-in-a-haystack at a controlled depth: one `[REC, k, v…]` record
/// inside `haystack` filler tokens, with `depth_pct`% of the filler before
/// it (0 = oldest context — the position eviction policies destroy first).
/// The prompt ends `[QUERY, k]`; the answer is the needle's value.
pub fn gen_needle_at_depth(rng: &mut Pcg32, depth_pct: u8, haystack: usize) -> EvalSample {
    let depth_pct = depth_pct.min(100);
    let k = key(rng);
    let v = val(rng);
    let before = haystack * depth_pct as usize / 100;
    let mut prompt = vec![BOS];
    prompt.extend(gen_filler(rng, before));
    prompt.push(REC);
    prompt.extend(&k);
    prompt.extend(&v);
    prompt.extend(gen_filler(rng, haystack - before));
    prompt.push(QUERY);
    prompt.extend(&k);
    EvalSample {
        prompt,
        answer: v,
        family: "needle",
        depth_pct: Some(depth_pct),
    }
}

/// Keyed recall: `n_keys` back-to-back records, query a uniformly random
/// one. Per-sample `depth_pct` is the queried record's position, so a run
/// of samples populates every depth bucket — the mean hides positional
/// failure, the worst bucket exposes it.
pub fn gen_keyed_recall(rng: &mut Pcg32, n_keys: usize) -> EvalSample {
    let keys = distinct_keys(rng, n_keys);
    let vals: Vec<Vec<i64>> = (0..n_keys).map(|_| val(rng)).collect();
    let mut prompt = vec![BOS];
    let mut starts = Vec::with_capacity(n_keys);
    for (k, v) in keys.iter().zip(&vals) {
        starts.push(prompt.len());
        prompt.push(REC);
        prompt.extend(k);
        prompt.extend(v);
    }
    let qi = rng.gen_below(n_keys as u32) as usize;
    prompt.push(QUERY);
    prompt.extend(&keys[qi]);
    let depth = 100 * starts[qi] / prompt.len();
    EvalSample {
        prompt,
        answer: vals[qi].clone(),
        family: "keyedrecall",
        depth_pct: Some(depth as u8),
    }
}

/// Multi-turn drift transcript: turn 0 plants the target record; every
/// later turn opens with `SEP`, plants its *own* record, and adds filler
/// chatter; every `probe_every`-th turn additionally rehearses the current
/// turn's record as `[QUERY, k_t, ANS, v_t…]` — recency traffic that
/// competes for the importance budget exactly the way live sessions do.
/// The final query asks for the turn-0 record, whose depth drifts toward
/// 0% as turns accumulate.
pub fn gen_multiturn_drift(rng: &mut Pcg32, turns: usize, probe_every: usize) -> EvalSample {
    let turns = turns.max(1);
    let keys = distinct_keys(rng, turns + 1);
    let vals: Vec<Vec<i64>> = (0..turns + 1).map(|_| val(rng)).collect();
    let mut prompt = vec![BOS, REC];
    let target_pos = prompt.len();
    prompt.extend(&keys[0]);
    prompt.extend(&vals[0]);
    prompt.extend(gen_filler(rng, 3));
    for t in 1..=turns {
        prompt.push(SEP);
        prompt.push(REC);
        prompt.extend(&keys[t]);
        prompt.extend(&vals[t]);
        prompt.extend(gen_filler(rng, 3));
        if probe_every > 0 && t % probe_every == 0 {
            prompt.push(QUERY);
            prompt.extend(&keys[t]);
            prompt.push(ANS);
            prompt.extend(&vals[t]);
        }
    }
    prompt.push(QUERY);
    prompt.extend(&keys[0]);
    let depth = 100 * target_pos / prompt.len();
    EvalSample {
        prompt,
        answer: vals[0].clone(),
        family: "drift",
        depth_pct: Some(depth as u8),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lineret_structure() {
        let mut rng = Pcg32::new(1);
        let s = gen_lineret(&mut rng, 6, 0);
        assert_eq!(s.prompt[0], BOS);
        // prompt ends with the query key
        let qpos = s.prompt.iter().position(|&t| t == QUERY).unwrap();
        assert_eq!(qpos + KEY_TOKS, s.prompt.len() - 1);
        assert_eq!(s.answer.len(), VAL_TOKS);
        assert!(s.answer.iter().all(|&t| (VAL_BASE..VAL_BASE + VAL_N).contains(&t)));
        // queried key appears exactly once in the records; value follows it
        let qkey = &s.prompt[qpos + 1..qpos + 1 + KEY_TOKS];
        let mut found = 0;
        for i in 0..qpos {
            if s.prompt[i] == REC && &s.prompt[i + 1..i + 1 + KEY_TOKS] == qkey {
                let v = &s.prompt[i + 1 + KEY_TOKS..i + 1 + KEY_TOKS + VAL_TOKS];
                assert_eq!(v, &s.answer[..]);
                found += 1;
            }
        }
        assert_eq!(found, 1);
    }

    #[test]
    fn multihop_chain_resolves() {
        let mut rng = Pcg32::new(2);
        let s = gen_multihop(&mut rng, 10);
        let qpos = s.prompt.iter().position(|&t| t == QUERY).unwrap();
        let ka = s.prompt[qpos + 1..qpos + 1 + KEY_TOKS].to_vec();
        // hop record: [REC, lhs, HOP, kb]; value record: [REC, lhs, v...]
        let find_hop = |lhs: &[i64]| -> Option<Vec<i64>> {
            (0..qpos).find_map(|i| {
                (s.prompt[i] == REC
                    && &s.prompt[i + 1..i + 1 + KEY_TOKS] == lhs
                    && s.prompt[i + 1 + KEY_TOKS] == HOP)
                    .then(|| s.prompt[i + 2 + KEY_TOKS..i + 2 + 2 * KEY_TOKS].to_vec())
            })
        };
        let find_val = |lhs: &[i64]| -> Option<Vec<i64>> {
            (0..qpos).find_map(|i| {
                (s.prompt[i] == REC
                    && &s.prompt[i + 1..i + 1 + KEY_TOKS] == lhs
                    && s.prompt[i + 1 + KEY_TOKS] != HOP)
                    .then(|| s.prompt[i + 1 + KEY_TOKS..i + 1 + KEY_TOKS + VAL_TOKS].to_vec())
            })
        };
        let kb = find_hop(&ka).expect("hop record");
        let v = find_val(&kb).expect("value record");
        assert_eq!(v, s.answer);
    }

    #[test]
    fn pattern_answer_continues_motif() {
        let mut rng = Pcg32::new(3);
        let s = gen_pattern(&mut rng, 5, 4);
        assert_eq!(s.answer.len(), 5);
        // the answer equals the first 5 non-BOS prompt tokens (motif)
        assert_eq!(&s.prompt[1..6], &s.answer[..]);
    }

    #[test]
    fn filler_tokens_in_range() {
        let mut rng = Pcg32::new(4);
        let s = gen_lm(&mut rng, 30, 5);
        for &t in s.prompt[1..].iter().chain(&s.answer) {
            assert!((FILL_BASE..FILL_BASE + FILL_N).contains(&t));
        }
    }

    #[test]
    fn constants_check_catches_mismatch() {
        let mut m: BTreeMap<String, i64> = [("PAD", 0i64), ("BOS", 1)]
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect();
        assert!(check_manifest_constants(&m).is_err()); // missing keys
        for (k, v) in [
            ("REC", 2i64), ("SEP", 3), ("QUERY", 4), ("ANS", 5), ("EOS", 6),
            ("HOP", 7), ("KEY_BASE", 16), ("KEY_N", 200), ("VAL_BASE", 216),
            ("VAL_N", 100), ("FILL_BASE", 316), ("FILL_N", 96),
            ("PAT_BASE", 412), ("PAT_N", 100), ("VOCAB", 512),
            ("KEY_TOKS", 1), ("VAL_TOKS", 2),
        ] {
            m.insert(k.to_string(), v);
        }
        assert!(check_manifest_constants(&m).is_ok());
        m.insert("VOCAB".into(), 1024);
        assert!(check_manifest_constants(&m).is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gen_lineret(&mut Pcg32::new(9), 5, 1);
        let b = gen_lineret(&mut Pcg32::new(9), 5, 1);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.answer, b.answer);
    }

    #[test]
    fn needle_sits_at_requested_depth() {
        for depth in [0u8, 25, 50, 75, 100] {
            let mut rng = Pcg32::new(21 + depth as u64);
            let s = gen_needle_at_depth(&mut rng, depth, 80);
            assert_eq!(s.depth_pct, Some(depth));
            let rec = s.prompt.iter().position(|&t| t == REC).unwrap();
            // REC lands right after `depth%` of the 80 filler tokens (+BOS)
            assert_eq!(rec, 1 + 80 * depth as usize / 100);
            // prompt ends [QUERY, k]; k's value follows the record key
            let qpos = s.prompt.len() - 1 - KEY_TOKS;
            assert_eq!(s.prompt[qpos], QUERY);
            assert_eq!(
                s.prompt[rec + 1..rec + 1 + KEY_TOKS],
                s.prompt[qpos + 1..qpos + 1 + KEY_TOKS]
            );
            assert_eq!(
                &s.prompt[rec + 1 + KEY_TOKS..rec + 1 + KEY_TOKS + VAL_TOKS],
                &s.answer[..]
            );
        }
    }

    #[test]
    fn keyed_recall_depth_matches_queried_record() {
        let mut seen_buckets = [false; 4];
        for seed in 0..40u64 {
            let s = gen_keyed_recall(&mut Pcg32::new(seed), 12);
            let depth = s.depth_pct.expect("keyed recall records depth");
            assert!(depth <= 100);
            seen_buckets[(depth as usize / 25).min(3)] = true;
            // queried key resolves to the answer
            let qpos = s.prompt.len() - 1 - KEY_TOKS;
            assert_eq!(s.prompt[qpos], QUERY);
            let qkey = &s.prompt[qpos + 1..qpos + 1 + KEY_TOKS];
            let mut found = 0;
            for i in 0..qpos {
                if s.prompt[i] == REC && &s.prompt[i + 1..i + 1 + KEY_TOKS] == qkey {
                    assert_eq!(
                        &s.prompt[i + 1 + KEY_TOKS..i + 1 + KEY_TOKS + VAL_TOKS],
                        &s.answer[..]
                    );
                    // depth_pct is the record's position percentile
                    assert_eq!(depth as usize, 100 * i / s.prompt.len());
                    found += 1;
                }
            }
            assert_eq!(found, 1);
        }
        assert!(
            seen_buckets.iter().all(|&b| b),
            "uniform queries must populate every depth bucket: {seen_buckets:?}"
        );
    }

    #[test]
    fn multiturn_drift_targets_turn_zero() {
        let mut rng = Pcg32::new(31);
        let s = gen_multiturn_drift(&mut rng, 8, 2);
        // the target record is the first one, so its depth is near zero
        assert!(s.depth_pct.unwrap() < 10, "depth {:?}", s.depth_pct);
        assert_eq!(s.prompt.iter().filter(|&&t| t == SEP).count(), 8);
        // rehearsal probes: turns 2,4,6,8 → 4 in-prompt QUERYs + the final one
        assert_eq!(s.prompt.iter().filter(|&&t| t == QUERY).count(), 5);
        // final query resolves to the turn-0 value
        let qpos = s.prompt.len() - 1 - KEY_TOKS;
        assert_eq!(s.prompt[qpos], QUERY);
        assert_eq!(
            s.prompt[qpos + 1..qpos + 1 + KEY_TOKS],
            s.prompt[2..2 + KEY_TOKS]
        );
        assert_eq!(&s.prompt[2 + KEY_TOKS..2 + KEY_TOKS + VAL_TOKS], &s.answer[..]);
        // the turn-0 key never reappears before the final query (no
        // rehearsal leak: recalling it is genuinely hard)
        let k0 = s.prompt[2];
        assert_eq!(
            s.prompt[..qpos].iter().filter(|&&t| t == k0).count(),
            1,
            "target key must appear exactly once before the final query"
        );
    }
}
