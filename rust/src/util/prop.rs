//! Mini property-testing harness (the offline image has no proptest).
//!
//! Supports the idioms the test suite needs: run a property over N random
//! cases drawn from a seeded [`Pcg32`], report the failing seed + case index
//! on failure so every failure is reproducible, and a lightweight shrinking
//! pass for integer-vector inputs.
//!
//! ```no_run
//! # // no_run: rustdoc test binaries miss the xla rpath in this image
//! use mikv::util::prop::{forall, Config};
//! use mikv::prop_assert;
//! forall(Config::default().cases(200), |rng| {
//!     let n = rng.gen_range(0, 64) as usize;
//!     let xs: Vec<f32> = (0..n).map(|_| rng.gen_normal()).collect();
//!     let s: f32 = xs.iter().sum();
//!     prop_assert!(s.is_finite(), "sum must be finite, got {s}");
//!     Ok(())
//! });
//! ```

use super::rng::{Pcg32, SplitMix64};

/// Property-run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Master seed; each case gets an independent child stream.
    pub seed: u64,
    /// Name printed on failure.
    pub name: &'static str,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 100,
            seed: 0xC0FFEE,
            name: "property",
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
    pub fn name(mut self, n: &'static str) -> Self {
        self.name = n;
        self
    }
}

/// Outcome of a single property case: `Err(msg)` fails the run.
pub type CaseResult = Result<(), String>;

/// Assert inside a property body. Returns `Err` instead of panicking so the
/// harness can report the case seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

/// Assert approximate equality of two floats with absolute + relative
/// tolerance (mirrors `numpy.testing.assert_allclose` semantics).
#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $atol:expr, $rtol:expr) => {{
        let (a, b) = ($a as f64, $b as f64);
        let tol = $atol as f64 + $rtol as f64 * b.abs();
        if (a - b).abs() > tol {
            return Err(format!(
                "not close: {} vs {} (|diff|={:.3e} > tol={:.3e}) at {}:{}",
                a,
                b,
                (a - b).abs(),
                tol,
                file!(),
                line!()
            ));
        }
    }};
}

/// Run `body` over `cfg.cases` independent random cases. Panics with the
/// failing seed + case number on first failure.
pub fn forall<F>(cfg: Config, mut body: F)
where
    F: FnMut(&mut Pcg32) -> CaseResult,
{
    let mut splitter = SplitMix64::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = splitter.split();
        let mut rng = Pcg32::new(case_seed);
        if let Err(msg) = body(&mut rng) {
            panic!(
                "property '{}' failed at case {}/{} (master_seed={:#x}, case_seed={:#x}):\n  {}",
                cfg.name, case, cfg.cases, cfg.seed, case_seed, msg
            );
        }
    }
}

/// Like [`forall`] but the case body receives the case index too (useful for
/// size-ramped generation: small cases first, like proptest's sizing).
pub fn forall_sized<F>(cfg: Config, mut body: F)
where
    F: FnMut(&mut Pcg32, usize) -> CaseResult,
{
    let mut splitter = SplitMix64::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = splitter.split();
        let mut rng = Pcg32::new(case_seed);
        if let Err(msg) = body(&mut rng, case) {
            panic!(
                "property '{}' failed at case {}/{} (master_seed={:#x}, case_seed={:#x}):\n  {}",
                cfg.name, case, cfg.cases, cfg.seed, case_seed, msg
            );
        }
    }
}

// ----------------------------------------------------------------------
// Common generators
// ----------------------------------------------------------------------

/// A vector of `n` floats ~ N(0, scale), with occasional injected outliers
/// when `outlier_p > 0` — matches the Q/K activation structure the paper's
/// §3.2 analyzes (systematic large-magnitude channels).
pub fn gen_vec_normal(rng: &mut Pcg32, n: usize, scale: f32, outlier_p: f64) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let v = rng.gen_normal() * scale;
            if outlier_p > 0.0 && rng.gen_bool(outlier_p) {
                v * rng.gen_f32_range(8.0, 40.0)
            } else {
                v
            }
        })
        .collect()
}

/// Shrink a failing `Vec<i64>` input: repeatedly try dropping halves and
/// zeroing elements while `still_fails` holds. Returns the smallest found.
pub fn shrink_ints<F>(input: Vec<i64>, mut still_fails: F) -> Vec<i64>
where
    F: FnMut(&[i64]) -> bool,
{
    let mut cur = input;
    loop {
        let mut progressed = false;
        // 1. try removing chunks (halves, quarters, ...)
        let mut chunk = cur.len() / 2;
        while chunk >= 1 {
            let mut i = 0;
            while i + chunk <= cur.len() {
                let mut cand = cur.clone();
                cand.drain(i..i + chunk);
                if still_fails(&cand) {
                    cur = cand;
                    progressed = true;
                } else {
                    i += chunk;
                }
            }
            chunk /= 2;
        }
        // 2. try shrinking individual values toward zero
        for i in 0..cur.len() {
            while cur[i] != 0 {
                let mut cand = cur.clone();
                cand[i] /= 2;
                if still_fails(&cand) {
                    cur = cand;
                    progressed = true;
                } else {
                    break;
                }
            }
        }
        if !progressed {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(Config::default().cases(50).name("trivial"), |rng| {
            let x = rng.gen_f32();
            prop_assert!((0.0..1.0).contains(&x));
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'must_fail'")]
    fn forall_reports_failures() {
        forall(Config::default().cases(10).name("must_fail"), |_rng| {
            Err("intentional".to_string())
        });
    }

    #[test]
    fn forall_is_deterministic() {
        // Capture the sequence of generated values across two identical runs.
        let mut run = || {
            let mut vals = Vec::new();
            forall(Config::default().cases(20).seed(99), |rng| {
                vals.push(rng.next_u32());
                Ok(())
            });
            vals
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn shrinker_finds_minimal_counterexample() {
        // Property: "no element is >= 100". Failing input has junk + one bad
        // element; shrinking should isolate something tiny.
        let input = vec![1, 5, 150, 7, 3, 9, 2];
        let fails = |xs: &[i64]| xs.iter().any(|&x| x >= 100);
        let min = shrink_ints(input, fails);
        assert!(fails(&min));
        assert!(min.len() == 1, "shrunk to {min:?}");
    }

    #[test]
    fn outlier_generator_injects_outliers() {
        let mut rng = Pcg32::new(5);
        let v = gen_vec_normal(&mut rng, 4096, 1.0, 0.02);
        let max = v.iter().fold(0f32, |m, x| m.max(x.abs()));
        assert!(max > 6.0, "expected injected outliers, max={max}");
    }
}
