//! Deterministic fault injection for chaos-testing the serving stack.
//!
//! A [`FaultPlan`] is a shared schedule of injected failures, threaded
//! through the stub engine (step errors / step panics), the cold-tier
//! store (IO failures around the write→rename sequence), and the TCP
//! path (accept errors, stalled writers, mid-stream disconnects).
//! Sites fire on deterministic **occurrence counts**: the k-th probe of
//! a given site fires iff that site's [`FaultRule`] selects k, so a
//! chaos run with a fixed plan injects the same faults at the same
//! structural points every run, independent of how threads interleave
//! at *other* sites.
//!
//! The default plan is **disabled**: every probe is a single `Option`
//! check against `None` — no atomics touched, no allocation — so
//! serving paths that never opt in pay nothing. Clones share the
//! underlying counters (one `Arc`), which is what lets the test that
//! built a plan reconcile [`FaultPlan::fired`] totals against what the
//! stack actually saw.
//!
//! Plans come from two places: test builders
//! (`FaultPlan::builder().every(site, n).build()`) and the
//! `mikv serve --fault-plan` CLI spec parsed by [`FaultPlan::parse`]
//! (e.g. `engine_step_error:every=7;conn_disconnect:every=11,limit=3`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Number of distinct injection sites (length of [`FaultSite::ALL`]).
const N_SITES: usize = 10;

/// One structural point in the serving stack where a fault can be
/// injected. The wire names (used by `--fault-plan`) are the snake_case
/// forms returned by [`FaultSite::as_str`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// `StubEngine::decode_step` returns an error for the whole group.
    EngineStepError,
    /// `StubEngine::decode_step` panics, killing the worker thread
    /// (exercises scheduler supervision / respawn).
    EngineStepPanic,
    /// `ColdStore::put` fails before the tmp file is written.
    ColdPutBeforeWrite,
    /// `ColdStore::put` writes a truncated tmp file, then fails
    /// (orphan `.tmp` left for the next open's GC).
    ColdPutPartialWrite,
    /// `ColdStore::put` fails after the tmp write, before the rename.
    ColdPutBeforeRename,
    /// `ColdStore::put` fails after the rename, before the index is
    /// updated (durable file, lost accounting — a crash point).
    ColdPutAfterRename,
    /// `ColdStore::take` fails reading the snapshot back.
    ColdTakeRead,
    /// The connection's writer thread stalls (for [`FaultRule::ms`])
    /// before a write, simulating a client that stops draining.
    ConnStall,
    /// The connection is dropped mid-stream (client sees EOF).
    ConnDisconnect,
    /// The listener's accept loop observes a transient accept error.
    AcceptError,
}

impl FaultSite {
    /// Every site, in declaration order (index = discriminant).
    pub const ALL: [FaultSite; N_SITES] = [
        FaultSite::EngineStepError,
        FaultSite::EngineStepPanic,
        FaultSite::ColdPutBeforeWrite,
        FaultSite::ColdPutPartialWrite,
        FaultSite::ColdPutBeforeRename,
        FaultSite::ColdPutAfterRename,
        FaultSite::ColdTakeRead,
        FaultSite::ConnStall,
        FaultSite::ConnDisconnect,
        FaultSite::AcceptError,
    ];

    /// The stable wire name used by `--fault-plan` specs.
    pub fn as_str(self) -> &'static str {
        match self {
            FaultSite::EngineStepError => "engine_step_error",
            FaultSite::EngineStepPanic => "engine_step_panic",
            FaultSite::ColdPutBeforeWrite => "cold_put_before_write",
            FaultSite::ColdPutPartialWrite => "cold_put_partial_write",
            FaultSite::ColdPutBeforeRename => "cold_put_before_rename",
            FaultSite::ColdPutAfterRename => "cold_put_after_rename",
            FaultSite::ColdTakeRead => "cold_take_read",
            FaultSite::ConnStall => "conn_stall",
            FaultSite::ConnDisconnect => "conn_disconnect",
            FaultSite::AcceptError => "accept_error",
        }
    }

    /// Inverse of [`Self::as_str`].
    pub fn parse(s: &str) -> Option<FaultSite> {
        Self::ALL.iter().copied().find(|site| site.as_str() == s)
    }
}

/// When a site fires, in occurrence counts: skip the first `after`
/// probes, then fire on every `every`-th remaining probe (`1` = each
/// one, `0` = never), at most `limit` times (`0` = unlimited). `ms` is
/// a site-specific magnitude — the stall duration for
/// [`FaultSite::ConnStall`] — ignored elsewhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultRule {
    pub every: u64,
    pub after: u64,
    pub limit: u64,
    pub ms: u64,
}

/// A rule that never fires — the builder's initial state for every
/// site, so a plan only arms the sites it names.
const DISARMED: FaultRule = FaultRule {
    every: 0,
    after: 0,
    limit: 0,
    ms: 0,
};

impl Default for FaultRule {
    /// Fire on every occurrence, unlimited, no magnitude.
    fn default() -> FaultRule {
        FaultRule {
            every: 1,
            after: 0,
            limit: 0,
            ms: 0,
        }
    }
}

#[derive(Debug)]
struct SiteState {
    rule: FaultRule,
    /// Probes observed (monotonic).
    seen: AtomicU64,
    /// Probes that actually fired (monotonic, `<= seen`).
    fired: AtomicU64,
}

#[derive(Debug)]
struct PlanInner {
    seed: u64,
    sites: [SiteState; N_SITES],
}

/// A shared, deterministic fault-injection schedule. `Default` (and
/// [`FaultPlan::disabled`]) is the always-off plan; see the module docs
/// for the firing model and the zero-cost-when-disabled contract.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    inner: Option<Arc<PlanInner>>,
}

impl FaultPlan {
    /// The always-off plan (also `Default`): every probe is one `None`
    /// check.
    pub fn disabled() -> FaultPlan {
        FaultPlan { inner: None }
    }

    /// Whether any site is armed (`false` for the default plan).
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Start building a plan; disarmed until sites are added.
    pub fn builder() -> FaultPlanBuilder {
        FaultPlanBuilder {
            seed: 0,
            rules: [DISARMED; N_SITES],
        }
    }

    /// Seed recorded when the plan was built (0 when disabled). The
    /// firing schedule itself is count-based; the seed is carried so a
    /// chaos harness can derive its traffic seed from the same knob.
    pub fn seed(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.seed)
    }

    /// Probe an injection site: returns `true` iff the site's rule
    /// selects this occurrence. Counts are shared across clones, so
    /// concurrent probers divide one global occurrence sequence.
    pub fn should_fire(&self, site: FaultSite) -> bool {
        let Some(inner) = self.inner.as_ref() else {
            return false;
        };
        let Some(st) = inner.sites.get(site as usize) else {
            return false;
        };
        if st.rule.every == 0 {
            return false;
        }
        // lint: relaxed-ordering-audit-ok: monotonic occurrence counter; no cross-site ordering is implied
        let n = st.seen.fetch_add(1, Ordering::Relaxed) + 1;
        if n <= st.rule.after || (n - st.rule.after) % st.rule.every != 0 {
            return false;
        }
        if st.rule.limit == 0 {
            // lint: relaxed-ordering-audit-ok: monotonic fired counter, read only for post-run reconciliation
            st.fired.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        // The closure keeps `fired` exact under the limit even when
        // several threads race the last slot.
        // lint: relaxed-ordering-audit-ok: counter-only CAS loop; the closure enforces the bound, ordering carries no data
        st.fired
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |f| {
                (f < st.rule.limit).then_some(f + 1)
            })
            .is_ok()
    }

    /// Stall duration (ms) configured for `site`; defaults to 50 when
    /// the rule left `ms` at 0 so an armed `conn_stall` always stalls.
    pub fn stall_ms(&self, site: FaultSite) -> u64 {
        self.inner
            .as_ref()
            .and_then(|i| i.sites.get(site as usize))
            .map_or(0, |st| if st.rule.ms == 0 { 50 } else { st.rule.ms })
    }

    /// Times `site` has fired so far (0 when disabled).
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.inner
            .as_ref()
            .and_then(|i| i.sites.get(site as usize))
            // lint: relaxed-ordering-audit-ok: reconciliation read of a monotonic counter after the run quiesced
            .map_or(0, |st| st.fired.load(Ordering::Relaxed))
    }

    /// Times `site` has been probed so far (0 when disabled).
    pub fn seen(&self, site: FaultSite) -> u64 {
        self.inner
            .as_ref()
            .and_then(|i| i.sites.get(site as usize))
            // lint: relaxed-ordering-audit-ok: reconciliation read of a monotonic counter after the run quiesced
            .map_or(0, |st| st.seen.load(Ordering::Relaxed))
    }

    /// Parse a `--fault-plan` spec. Grammar: `;`-separated segments,
    /// each either `seed=N` or `site[:key=val[,key=val...]]` with keys
    /// `every` / `after` / `limit` / `ms`; a site with no params fires
    /// on every occurrence. An empty spec builds the disabled plan.
    pub fn parse(spec: &str) -> crate::Result<FaultPlan> {
        let mut b = FaultPlan::builder();
        for seg in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some(seed) = seg.strip_prefix("seed=") {
                let seed = seed
                    .trim()
                    .parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("fault plan: bad seed '{seed}'"))?;
                b = b.seed(seed);
                continue;
            }
            let (name, params) = match seg.split_once(':') {
                Some((n, p)) => (n.trim(), p),
                None => (seg, ""),
            };
            let site = FaultSite::parse(name)
                .ok_or_else(|| anyhow::anyhow!("fault plan: unknown site '{name}'"))?;
            let mut rule = FaultRule::default();
            for kv in params.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("fault plan: expected key=value in '{kv}'"))?;
                let n: u64 = v.trim().parse().map_err(|_| {
                    anyhow::anyhow!("fault plan: bad integer '{}' for '{}'", v.trim(), k.trim())
                })?;
                match k.trim() {
                    "every" => rule.every = n,
                    "after" => rule.after = n,
                    "limit" => rule.limit = n,
                    "ms" => rule.ms = n,
                    other => anyhow::bail!("fault plan: unknown key '{other}'"),
                }
            }
            b = b.site(site, rule);
        }
        Ok(b.build())
    }
}

/// Builds a [`FaultPlan`] site by site; see [`FaultPlan::builder`].
#[derive(Debug, Clone)]
pub struct FaultPlanBuilder {
    seed: u64,
    rules: [FaultRule; N_SITES],
}

impl FaultPlanBuilder {
    /// Record a seed on the plan (carried, not consumed — see
    /// [`FaultPlan::seed`]).
    pub fn seed(mut self, seed: u64) -> FaultPlanBuilder {
        self.seed = seed;
        self
    }

    /// Arm `site` with an explicit rule (replacing any earlier one).
    pub fn site(mut self, site: FaultSite, rule: FaultRule) -> FaultPlanBuilder {
        if let Some(slot) = self.rules.get_mut(site as usize) {
            *slot = rule;
        }
        self
    }

    /// Arm `site` to fire on every `every`-th occurrence (0 disarms).
    pub fn every(self, site: FaultSite, every: u64) -> FaultPlanBuilder {
        self.site(
            site,
            FaultRule {
                every,
                ..FaultRule::default()
            },
        )
    }

    /// Finish; a builder with no armed site builds the disabled plan.
    pub fn build(self) -> FaultPlan {
        if self.rules.iter().all(|r| r.every == 0) {
            return FaultPlan::disabled();
        }
        let rules = self.rules;
        FaultPlan {
            inner: Some(Arc::new(PlanInner {
                seed: self.seed,
                sites: std::array::from_fn(|i| SiteState {
                    rule: rules.get(i).copied().unwrap_or(DISARMED),
                    seen: AtomicU64::new(0),
                    fired: AtomicU64::new(0),
                }),
            })),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_plan_never_fires() {
        let plan = FaultPlan::default();
        assert!(!plan.is_enabled());
        for site in FaultSite::ALL {
            for _ in 0..3 {
                assert!(!plan.should_fire(site));
            }
            assert_eq!(plan.seen(site), 0);
            assert_eq!(plan.fired(site), 0);
        }
        // a builder that armed nothing is also the disabled plan
        assert!(!FaultPlan::builder().seed(7).build().is_enabled());
    }

    #[test]
    fn every_after_limit_schedule_is_deterministic() {
        let plan = FaultPlan::builder()
            .site(
                FaultSite::EngineStepError,
                FaultRule {
                    every: 3,
                    after: 2,
                    limit: 2,
                    ms: 0,
                },
            )
            .build();
        // occurrences 1..=12: skip 2, then every 3rd → fires at 5, 8
        // (11 would be third, but limit=2 stops it).
        let fires: Vec<bool> = (1..=12)
            .map(|_| plan.should_fire(FaultSite::EngineStepError))
            .collect();
        let want: Vec<bool> = (1..=12).map(|n| n == 5 || n == 8).collect();
        assert_eq!(fires, want);
        assert_eq!(plan.seen(FaultSite::EngineStepError), 12);
        assert_eq!(plan.fired(FaultSite::EngineStepError), 2);
        // unarmed sites never fire and are not even counted as armed
        assert!(!plan.should_fire(FaultSite::AcceptError));
        assert_eq!(plan.fired(FaultSite::AcceptError), 0);
    }

    #[test]
    fn clones_share_one_occurrence_sequence() {
        let plan = FaultPlan::builder()
            .every(FaultSite::ConnDisconnect, 2)
            .build();
        let other = plan.clone();
        // alternating probes across the two handles still fire every
        // 2nd occurrence globally
        assert!(!plan.should_fire(FaultSite::ConnDisconnect));
        assert!(other.should_fire(FaultSite::ConnDisconnect));
        assert!(!plan.should_fire(FaultSite::ConnDisconnect));
        assert!(other.should_fire(FaultSite::ConnDisconnect));
        assert_eq!(plan.fired(FaultSite::ConnDisconnect), 2);
        assert_eq!(other.seen(FaultSite::ConnDisconnect), 4);
    }

    #[test]
    fn stall_ms_defaults_when_unset() {
        let plan = FaultPlan::builder().every(FaultSite::ConnStall, 1).build();
        assert_eq!(plan.stall_ms(FaultSite::ConnStall), 50);
        let plan = FaultPlan::builder()
            .site(
                FaultSite::ConnStall,
                FaultRule {
                    ms: 120,
                    ..FaultRule::default()
                },
            )
            .build();
        assert_eq!(plan.stall_ms(FaultSite::ConnStall), 120);
        assert_eq!(FaultPlan::disabled().stall_ms(FaultSite::ConnStall), 0);
    }

    #[test]
    fn parse_roundtrips_the_cli_grammar() {
        let plan = FaultPlan::parse(
            "engine_step_error:every=7; conn_disconnect:every=11,limit=3; \
             conn_stall:every=5,ms=20; accept_error; seed=42",
        )
        .unwrap();
        assert!(plan.is_enabled());
        assert_eq!(plan.seed(), 42);
        // every=7 → first fire on the 7th probe
        for n in 1..=7 {
            assert_eq!(
                plan.should_fire(FaultSite::EngineStepError),
                n == 7,
                "probe {n}"
            );
        }
        // bare site name = fire every time
        assert!(plan.should_fire(FaultSite::AcceptError));
        assert_eq!(plan.stall_ms(FaultSite::ConnStall), 20);
        // empty spec = disabled
        assert!(!FaultPlan::parse("").unwrap().is_enabled());
        // errors are structured, not panics
        assert!(FaultPlan::parse("warp_core:every=1").is_err());
        assert!(FaultPlan::parse("engine_step_error:every=x").is_err());
        assert!(FaultPlan::parse("engine_step_error:often=1").is_err());
        assert!(FaultPlan::parse("seed=banana").is_err());
    }

    #[test]
    fn site_names_roundtrip() {
        for site in FaultSite::ALL {
            assert_eq!(FaultSite::parse(site.as_str()), Some(site));
        }
        assert_eq!(FaultSite::parse("nope"), None);
    }
}
