//! Substrate utilities built in-tree because the offline image ships no
//! serde / clap / proptest / rand: a JSON codec, deterministic RNGs, a mini
//! property-testing harness, a CLI argument parser, a leveled logger, and a
//! deterministic fault-injection seam for chaos testing.

pub mod cli;
pub mod faults;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
