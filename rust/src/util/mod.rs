//! Substrate utilities built in-tree because the offline image ships no
//! serde / clap / proptest / rand: a JSON codec, deterministic RNGs, a mini
//! property-testing harness, a CLI argument parser, and a leveled logger.

pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
