//! Minimal JSON codec (the offline image has no serde).
//!
//! Implements RFC 8259 parsing and serialization for the crate's needs:
//! the artifact manifest written by `python/compile/aot.py`, the TCP server
//! wire protocol, benchmark result files, and config files. Numbers are
//! held as `f64` plus an exact `i64` fast path; object key order is
//! preserved (insertion order) so round-trips are stable.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integer fast path — parsed when the literal has no '.', 'e', 'E'.
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects preserve insertion order via a parallel key list.
    Obj(JsonObj),
}

/// Insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert or replace a key.
    pub fn set(&mut self, key: impl Into<String>, value: impl Into<Json>) -> &mut Self {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value.into());
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Iterate entries in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.keys.iter().map(move |k| (k.as_str(), &self.map[k]))
    }
}

/// Parse / access errors.
#[derive(Debug)]
pub enum JsonError {
    Parse { pos: usize, msg: String },
    Missing(String),
    WrongType { field: String, expected: &'static str },
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { pos, msg } => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Missing(name) => write!(f, "json: missing field '{name}'"),
            JsonError::WrongType { field, expected } => {
                write!(f, "json: field '{field}' has wrong type (expected {expected})")
            }
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------------
    // Typed accessors (used pervasively by manifest / wire decoding).
    // ------------------------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["field"]` with a descriptive error.
    pub fn field(&self, name: &str) -> Result<&Json, JsonError> {
        self.as_obj()
            .and_then(|o| o.get(name))
            .ok_or_else(|| JsonError::Missing(name.to_string()))
    }

    pub fn field_str(&self, name: &str) -> Result<&str, JsonError> {
        self.field(name)?.as_str().ok_or(JsonError::WrongType {
            field: name.to_string(),
            expected: "string",
        })
    }

    pub fn field_i64(&self, name: &str) -> Result<i64, JsonError> {
        self.field(name)?.as_i64().ok_or(JsonError::WrongType {
            field: name.to_string(),
            expected: "integer",
        })
    }

    pub fn field_f64(&self, name: &str) -> Result<f64, JsonError> {
        self.field(name)?.as_f64().ok_or(JsonError::WrongType {
            field: name.to_string(),
            expected: "number",
        })
    }

    pub fn field_arr(&self, name: &str) -> Result<&[Json], JsonError> {
        self.field(name)?.as_arr().ok_or(JsonError::WrongType {
            field: name.to_string(),
            expected: "array",
        })
    }

    // ------------------------------------------------------------------
    // Parsing
    // ------------------------------------------------------------------

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let bytes = input.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------------
    // Serialization
    // ------------------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(i) => {
                out.push_str(&i.to_string());
            }
            Json::Num(f) => write_f64(out, *f),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_f64(out: &mut String, f: f64) {
    if f.is_nan() || f.is_infinite() {
        // JSON has no NaN/Inf; emit null (matches python json.dumps default
        // behaviour closely enough for our diagnostic files).
        out.push_str("null");
    } else if f == f.trunc() && f.abs() < 1e15 {
        out.push_str(&format!("{:.1}", f));
    } else {
        out.push_str(&format!("{}", f));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ----------------------------------------------------------------------
// From impls for ergonomic construction.
// ----------------------------------------------------------------------

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<i64> for Json {
    fn from(i: i64) -> Self {
        Json::Int(i)
    }
}
impl From<usize> for Json {
    fn from(i: usize) -> Self {
        Json::Int(i as i64)
    }
}
impl From<u32> for Json {
    fn from(i: u32) -> Self {
        Json::Int(i as i64)
    }
}
impl From<f64> for Json {
    fn from(f: f64) -> Self {
        Json::Num(f)
    }
}
impl From<f32> for Json {
    fn from(f: f32) -> Self {
        Json::Num(f as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<JsonObj> for Json {
    fn from(o: JsonObj) -> Self {
        Json::Obj(o)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

// ----------------------------------------------------------------------
// Parser
// ----------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']' in array"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.set(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}' in object"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Convenience macro-free builder: `obj(&[("k", json_value)])`.
pub fn obj(pairs: &[(&str, Json)]) -> Json {
    let mut o = JsonObj::new();
    for (k, v) in pairs {
        o.set(*k, v.clone());
    }
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        let a = v.field_arr("a").unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].field("b").unwrap(), &Json::Null);
        assert_eq!(v.field_str("c").unwrap(), "x");
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo wörld 中文\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld 中文");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"mikv","n":3,"f":2.5,"list":[1,2,3],"flag":true,"none":null}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        let v2 = Json::parse(&compact).unwrap();
        assert_eq!(v, v2);
        let pretty = v.to_string_pretty();
        let v3 = Json::parse(&pretty).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn object_order_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse(r#"{"a": "str"}"#).unwrap();
        assert!(matches!(v.field_i64("a"), Err(JsonError::WrongType { .. })));
        assert!(matches!(v.field("b"), Err(JsonError::Missing(_))));
    }

    #[test]
    fn builder_api() {
        let mut o = JsonObj::new();
        o.set("x", 1i64).set("y", "two").set("z", vec![1i64, 2]);
        let j = Json::Obj(o);
        let rt = Json::parse(&j.to_string()).unwrap();
        assert_eq!(rt.field_i64("x").unwrap(), 1);
        assert_eq!(rt.field_str("y").unwrap(), "two");
        assert_eq!(rt.field_arr("z").unwrap().len(), 2);
    }

    #[test]
    fn float_formatting_roundtrips() {
        for f in [0.1, 1.0, -2.5, 1e-9, 3.141592653589793, 1e15] {
            let s = Json::Num(f).to_string();
            let v = Json::parse(&s).unwrap();
            assert!((v.as_f64().unwrap() - f).abs() <= f.abs() * 1e-12);
        }
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn large_int_precision() {
        let v = Json::parse("9007199254740993").unwrap(); // 2^53 + 1
        assert_eq!(v.as_i64().unwrap(), 9007199254740993);
    }
}
