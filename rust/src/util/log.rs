//! Leveled stderr logger with wall-clock timestamps relative to process
//! start. Controlled by `MIKV_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`].

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log verbosity levels, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialised
static START: OnceLock<Instant> = OnceLock::new();

fn current_level() -> Level {
    // lint: relaxed-ordering-audit-ok: lone u8 level flag; a stale read only delays a verbosity change
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let lvl = std::env::var("MIKV_LOG")
            .ok()
            .and_then(|s| Level::from_str(&s))
            .unwrap_or(Level::Info);
        // lint: relaxed-ordering-audit-ok: racing initializers store the same env-derived value
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        return lvl;
    }
    // Safety: only valid discriminants are ever stored.
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the log level programmatically.
pub fn set_level(level: Level) {
    // lint: relaxed-ordering-audit-ok: single u8 flag; no other memory is published with it
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Is `level` currently enabled?
pub fn enabled(level: Level) -> bool {
    level <= current_level()
}

/// Core emit function — use the `log_*!` macros instead.
pub fn emit(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {module}] {msg}", level.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($fmt:tt)+) => { $crate::util::log::emit($crate::util::log::Level::Error, module_path!(), format_args!($($fmt)+)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($fmt:tt)+) => { $crate::util::log::emit($crate::util::log::Level::Warn, module_path!(), format_args!($($fmt)+)) };
}
#[macro_export]
macro_rules! log_info {
    ($($fmt:tt)+) => { $crate::util::log::emit($crate::util::log::Level::Info, module_path!(), format_args!($($fmt)+)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($fmt:tt)+) => { $crate::util::log::emit($crate::util::log::Level::Debug, module_path!(), format_args!($($fmt)+)) };
}
#[macro_export]
macro_rules! log_trace {
    ($($fmt:tt)+) => { $crate::util::log::emit($crate::util::log::Level::Trace, module_path!(), format_args!($($fmt)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_and_parsing() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn set_level_gates_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        // leave a sane default for other tests in the same process
        set_level(Level::Info);
    }
}
