//! Tiny CLI argument parser (the offline image has no clap).
//!
//! Supports the forms the `mikv` binary and the bench/example drivers use:
//! `--flag`, `--key value`, `--key=value`, positional arguments, and
//! subcommands (first positional). Typed getters parse on access and report
//! readable errors.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Program name (argv[0]).
    pub program: String,
    /// `--key value` / `--key=value` options, last occurrence wins.
    opts: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    flags: Vec<String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

/// CLI parse/access error.
#[derive(Debug)]
pub enum CliError {
    Missing(String),
    BadValue {
        key: String,
        value: String,
        ty: &'static str,
    },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(name) => write!(f, "missing required option --{name}"),
            CliError::BadValue { key, value, ty } => {
                write!(f, "option --{key}: cannot parse '{value}' as {ty}")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of arguments (excluding or including argv[0] —
    /// pass `std::env::args()` directly).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut it = argv.into_iter();
        let program = it.next().unwrap_or_default();
        let mut args = Args {
            program,
            ..Default::default()
        };
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    args.opts.insert(body.to_string(), rest[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse the current process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args())
    }

    /// First positional argument, conventionally the subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Is `--name` present as a bare flag (or as `--name true`)?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// String option with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.opts
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Required string option.
    pub fn require_str(&self, name: &str) -> Result<String, CliError> {
        self.opts
            .get(name)
            .cloned()
            .ok_or_else(|| CliError::Missing(name.to_string()))
    }

    /// Positive-count option with default (worker/connection/turn counts):
    /// parses as `usize` and rejects 0 with a readable error instead of
    /// letting a `--workers 0` panic deep inside the runtime.
    pub fn get_nonzero(&self, name: &str, default: usize) -> Result<usize, CliError> {
        let v = self.get::<usize>(name, default)?;
        if v == 0 {
            return Err(CliError::BadValue {
                key: name.to_string(),
                value: "0".to_string(),
                ty: "positive integer",
            });
        }
        Ok(v)
    }

    /// Typed option with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| CliError::BadValue {
                key: name.to_string(),
                value: v.clone(),
                ty: std::any::type_name::<T>(),
            }),
        }
    }

    /// Comma-separated list option, e.g. `--ratios 0.2,0.25,0.5`.
    pub fn get_list<T: std::str::FromStr>(
        &self,
        name: &str,
        default: &[T],
    ) -> Result<Vec<T>, CliError>
    where
        T: Clone,
    {
        match self.opts.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse::<T>().map_err(|_| CliError::BadValue {
                        key: name.to_string(),
                        value: s.to_string(),
                        ty: std::any::type_name::<T>(),
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        let mut v = vec!["prog".to_string()];
        v.extend(s.split_whitespace().map(|w| w.to_string()));
        Args::parse(v)
    }

    #[test]
    fn parses_key_value_both_forms() {
        let a = argv("--model cfg-s --steps=100");
        assert_eq!(a.get_str("model", "x"), "cfg-s");
        assert_eq!(a.get::<u32>("steps", 0).unwrap(), 100);
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = argv("serve --verbose --port 9000 extra");
        assert_eq!(a.subcommand(), Some("serve"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get::<u16>("port", 0).unwrap(), 9000);
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn last_occurrence_wins() {
        let a = argv("--n 1 --n 2");
        assert_eq!(a.get::<i64>("n", 0).unwrap(), 2);
    }

    #[test]
    fn typed_errors() {
        let a = argv("--n abc");
        assert!(matches!(
            a.get::<i64>("n", 0),
            Err(CliError::BadValue { .. })
        ));
        assert!(matches!(a.require_str("missing"), Err(CliError::Missing(_))));
    }

    #[test]
    fn nonzero_option() {
        assert_eq!(argv("--workers 4").get_nonzero("workers", 1).unwrap(), 4);
        assert_eq!(argv("").get_nonzero("workers", 2).unwrap(), 2);
        assert!(matches!(
            argv("--workers 0").get_nonzero("workers", 1),
            Err(CliError::BadValue { .. })
        ));
        assert!(matches!(
            argv("--workers -3").get_nonzero("workers", 1),
            Err(CliError::BadValue { .. })
        ));
    }

    #[test]
    fn list_option() {
        let a = argv("--ratios 0.2,0.25,0.5");
        let v = a.get_list::<f64>("ratios", &[]).unwrap();
        assert_eq!(v, vec![0.2, 0.25, 0.5]);
        let d = argv("").get_list::<f64>("ratios", &[1.0]).unwrap();
        assert_eq!(d, vec![1.0]);
    }

    #[test]
    fn defaults_apply() {
        let a = argv("");
        assert_eq!(a.get_str("model", "cfg-s"), "cfg-s");
        assert_eq!(a.get::<f32>("temp", 1.5).unwrap(), 1.5);
    }
}
