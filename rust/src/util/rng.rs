//! Deterministic, dependency-free pseudo-random number generators.
//!
//! The offline image ships no `rand` crate, so the crate carries its own
//! small RNG family: [`SplitMix64`] for seeding/stream-splitting and
//! [`Pcg32`] (PCG-XSH-RR 64/32) as the general-purpose generator used by the
//! evaluation workload generators, the property-test harness, and the
//! benchmark drivers. Everything here is reproducible from a `u64` seed —
//! every experiment in EXPERIMENTS.md records its seed.

/// SplitMix64: tiny, high-quality 64-bit generator. Primarily used to expand
/// a user seed into the state/stream parameters of [`Pcg32`] and to derive
/// independent child seeds (`split`).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Derive an independent child seed (used to give each parallel worker /
    /// each property-test case its own stream).
    pub fn split(&mut self) -> u64 {
        self.next_u64()
    }
}

/// PCG-XSH-RR 64/32: the workhorse generator.
///
/// Small state (128 bits), excellent statistical quality for our purposes
/// (workload synthesis, property-test case generation, sampling), and
/// trivially reproducible.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Seed the generator. `seed` selects the starting point, the stream is
    /// derived from it via SplitMix64 so two nearby seeds do not share a
    /// sequence.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let init_state = sm.next_u64();
        let init_seq = sm.next_u64();
        let mut rng = Self {
            state: 0,
            inc: (init_seq << 1) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(init_state);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` via Lemire's unbiased method.
    pub fn gen_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "gen_below(0)");
        // Rejection sampling on the multiply-shift trick.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn gen_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "gen_range: lo > hi");
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64() as i64;
        }
        if span <= u32::MAX as u64 {
            lo + self.gen_below(span as u32) as i64
        } else {
            // 64-bit Lemire
            let threshold = span.wrapping_neg() % span;
            loop {
                let r = self.next_u64();
                let m = (r as u128) * (span as u128);
                if (m as u64) >= threshold {
                    return lo + (m >> 64) as i64;
                }
            }
        }
    }

    /// Uniform `f32` in `[0, 1)`.
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[lo, hi)`.
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.gen_f32() * (hi - lo)
    }

    /// Standard normal via Box–Muller (one draw discarded; fine for our use).
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli draw with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Uniformly choose one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.gen_below(xs.len() as u32) as usize]
    }

    /// Sample `k` distinct indices from `0..n` (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Expose the raw `(state, inc)` pair so a generator mid-stream can be
    /// serialized (session snapshots) and resumed bit-identically.
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from raw `(state, inc)` parts captured by
    /// [`Pcg32::state_parts`]. The resumed stream continues exactly where the
    /// captured one left off.
    pub fn from_parts(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (computed from the canonical
        // SplitMix64 algorithm).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // determinism
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn pcg_determinism_and_stream_independence() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        let mut c = Pcg32::new(43);
        let va: Vec<u32> = (0..16).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..16).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..16).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn gen_below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gen_range_inclusive_bounds() {
        let mut rng = Pcg32::new(9);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..2000 {
            let v = rng.gen_range(-3, 3);
            assert!((-3..=3).contains(&v));
            hit_lo |= v == -3;
            hit_hi |= v == 3;
        }
        assert!(hit_lo && hit_hi);
    }

    #[test]
    fn gen_f32_unit_interval_mean() {
        let mut rng = Pcg32::new(11);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = rng.gen_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut rng = Pcg32::new(13);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = rng.gen_normal() as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn state_parts_round_trip_resumes_stream() {
        let mut a = Pcg32::new(23);
        for _ in 0..100 {
            a.next_u32();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg32::from_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::new(19);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }
}
