//! Benchmark harness (the offline image has no criterion).
//!
//! Provides what the `benches/` binaries need: warmup + timed repetitions
//! with robust statistics, and table builders that render the paper's
//! tables/figures as aligned markdown plus machine-readable JSON under
//! `bench_out/`.

use crate::util::json::{Json, JsonObj};
use std::time::{Duration, Instant};

/// Statistics over a set of timed iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    pub std_dev: Duration,
}

/// Percentile of an ascending-sorted sample set with linear interpolation
/// between the two nearest ranks (numpy's default `linear` method).
///
/// The previous implementation rounded `(n-1)·p` to the nearest index,
/// which made p99 of small sample sets (n ≤ ~50) silently equal the max
/// and biased p50 on even n toward the upper of the two middle samples.
/// Interpolating keeps small-n percentiles distinct from min/max and
/// unbiased: p50 of an even-sized set is the midpoint of the middle pair.
pub fn percentile(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let p = p.clamp(0.0, 1.0);
    let idx = (sorted.len() - 1) as f64 * p;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        return sorted[lo];
    }
    let frac = idx - lo as f64;
    let (a, b) = (sorted[lo].as_secs_f64(), sorted[hi].as_secs_f64());
    Duration::from_secs_f64(a + (b - a) * frac)
}

impl Stats {
    /// Build stats from raw per-iteration timings (need not be sorted).
    /// Public so serving drivers (load generator, throughput bench) reuse
    /// the same percentile definition as the micro-bench harness.
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let sum: f64 = samples.iter().map(|d| d.as_secs_f64()).sum();
        let mean = sum / n as f64;
        let var: f64 = samples
            .iter()
            .map(|d| (d.as_secs_f64() - mean).powi(2))
            .sum::<f64>()
            / n as f64;
        Stats {
            iters: n,
            mean: Duration::from_secs_f64(mean),
            p50: percentile(&samples, 0.50),
            p99: percentile(&samples, 0.99),
            min: samples[0],
            max: samples[n - 1],
            std_dev: Duration::from_secs_f64(var.sqrt()),
        }
    }

    /// Throughput given `units` of work per iteration.
    pub fn per_second(&self, units: f64) -> f64 {
        units / self.mean.as_secs_f64()
    }
}

/// Benchmark runner: `Bencher::new("name").warmup(3).iters(20).run(|| ...)`.
pub struct Bencher {
    name: String,
    warmup: usize,
    iters: usize,
    /// Optional wall-clock budget: stop early (after >= 3 iters) once spent.
    max_total: Option<Duration>,
}

impl Bencher {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            warmup: 2,
            iters: 10,
            max_total: None,
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n;
        self
    }

    pub fn max_total(mut self, d: Duration) -> Self {
        self.max_total = Some(d);
        self
    }

    /// Run the closure and collect timing stats. The closure's return value
    /// is passed through `std::hint::black_box` to keep the work alive.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        let start = Instant::now();
        for i in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
            if let Some(budget) = self.max_total {
                if i >= 2 && start.elapsed() > budget {
                    break;
                }
            }
        }
        let stats = Stats::from_samples(samples);
        crate::log_debug!(
            "bench {}: mean={:?} p50={:?} p99={:?} (n={})",
            self.name,
            stats.mean,
            stats.p50,
            stats.p99,
            stats.iters
        );
        stats
    }
}

/// A cell value in a result table.
#[derive(Debug, Clone)]
pub enum Cell {
    Str(String),
    Int(i64),
    /// Float with display precision.
    F(f64, usize),
    /// Percentage with display precision (stored as fraction OR percent —
    /// caller passes the already-scaled percent value).
    Pct(f64, usize),
}

impl Cell {
    fn render(&self) -> String {
        match self {
            Cell::Str(s) => s.clone(),
            Cell::Int(i) => i.to_string(),
            Cell::F(v, p) => format!("{v:.p$}", p = p),
            Cell::Pct(v, p) => format!("{v:.p$}%", p = p),
        }
    }

    fn to_json(&self) -> Json {
        match self {
            Cell::Str(s) => Json::Str(s.clone()),
            Cell::Int(i) => Json::Int(*i),
            Cell::F(v, _) | Cell::Pct(v, _) => Json::Num(*v),
        }
    }
}

impl From<&str> for Cell {
    fn from(s: &str) -> Self {
        Cell::Str(s.to_string())
    }
}
impl From<String> for Cell {
    fn from(s: String) -> Self {
        Cell::Str(s)
    }
}
impl From<i64> for Cell {
    fn from(i: i64) -> Self {
        Cell::Int(i)
    }
}
impl From<usize> for Cell {
    fn from(i: usize) -> Self {
        Cell::Int(i as i64)
    }
}

/// Result table mirroring one paper exhibit (table or figure series).
#[derive(Debug, Clone)]
pub struct Table {
    /// e.g. "table1" — used as the output file stem.
    pub id: String,
    /// Human title, e.g. the paper caption.
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<Cell>>,
    /// Free-form notes (seeds, config) recorded with the results.
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(id: impl Into<String>, title: impl Into<String>, columns: &[&str]) -> Self {
        Self {
            id: id.into(),
            title: title.into(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<Cell>) -> &mut Self {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, n: impl Into<String>) -> &mut Self {
        self.notes.push(n.into());
        self
    }

    /// Render as aligned markdown.
    pub fn to_markdown(&self) -> String {
        let rendered: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| r.iter().map(Cell::render).collect())
            .collect();
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &rendered {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("## {} — {}\n\n", self.id, self.title);
        let hdr: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{c:<w$}", w = widths[i]))
            .collect();
        out.push_str(&format!("| {} |\n", hdr.join(" | ")));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for r in rendered {
            let cells: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{c:<w$}", w = widths[i]))
                .collect();
            out.push_str(&format!("| {} |\n", cells.join(" | ")));
        }
        for n in &self.notes {
            out.push_str(&format!("\n> {n}\n"));
        }
        out
    }

    fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.set("id", self.id.as_str());
        o.set("title", self.title.as_str());
        o.set(
            "columns",
            Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect()),
        );
        o.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(Cell::to_json).collect()))
                    .collect(),
            ),
        );
        o.set(
            "notes",
            Json::Arr(self.notes.iter().map(|n| Json::Str(n.clone())).collect()),
        );
        Json::Obj(o)
    }

    /// Print to stdout and persist under `bench_out/<id>.{md,json}`.
    pub fn emit(&self) -> std::io::Result<()> {
        let md = self.to_markdown();
        println!("{md}");
        std::fs::create_dir_all("bench_out")?;
        std::fs::write(format!("bench_out/{}.md", self.id), &md)?;
        std::fs::write(
            format!("bench_out/{}.json", self.id),
            self.to_json().to_string_pretty(),
        )?;
        Ok(())
    }
}

/// Format a byte count with KiB/MiB/GiB autoscale (serving benches report
/// host bytes-per-session with this).
pub fn fmt_bytes(bytes: usize) -> String {
    let b = bytes as f64;
    if b < 1024.0 {
        format!("{bytes}B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1}KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.1}MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2}GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

/// Format a Duration as a human-readable string with µs/ms/s autoscale.
pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1000.0)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let samples = vec![
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ];
        let s = Stats::from_samples(samples);
        assert_eq!(s.iters, 3);
        assert_eq!(s.p50, Duration::from_millis(20));
        assert_eq!(s.min, Duration::from_millis(10));
        assert_eq!(s.max, Duration::from_millis(30));
        assert!((s.mean.as_secs_f64() - 0.020).abs() < 1e-9);
    }

    /// Regression for the nearest-index percentile bias: pins p50/p99 on
    /// known sample sets under linear interpolation.
    #[test]
    fn percentiles_interpolate_on_known_sets() {
        let ms = Duration::from_millis;
        // n = 10, samples 1..=10 ms.
        let s = Stats::from_samples((1..=10).map(ms).collect());
        // p50: idx 4.5 → midpoint of 5 ms and 6 ms (round-to-nearest gave
        // the biased 6 ms on even n).
        assert!((s.p50.as_secs_f64() - 0.0055).abs() < 1e-12, "{:?}", s.p50);
        // p99: idx 8.91 → 9.91 ms, strictly below max (round-to-nearest
        // silently returned max = 10 ms for every n ≤ 50).
        assert!((s.p99.as_secs_f64() - 0.00991).abs() < 1e-12, "{:?}", s.p99);
        assert!(s.p99 < s.max);

        // n = 4 even set: p50 is the midpoint of the middle pair.
        let s = Stats::from_samples(vec![ms(1), ms(2), ms(3), ms(4)]);
        assert!((s.p50.as_secs_f64() - 0.0025).abs() < 1e-12, "{:?}", s.p50);

        // n = 1: every percentile is the single sample.
        let s = Stats::from_samples(vec![ms(7)]);
        assert_eq!(s.p50, ms(7));
        assert_eq!(s.p99, ms(7));

        // exact-index percentiles are untouched by interpolation
        let sorted: Vec<Duration> = (1..=5).map(ms).collect();
        assert_eq!(percentile(&sorted, 0.5), ms(3));
        assert_eq!(percentile(&sorted, 0.0), ms(1));
        assert_eq!(percentile(&sorted, 1.0), ms(5));
        assert_eq!(percentile(&[], 0.5), Duration::ZERO);
    }

    #[test]
    fn bencher_runs_and_counts() {
        let mut calls = 0usize;
        let stats = Bencher::new("t").warmup(1).iters(5).run(|| {
            calls += 1;
            calls
        });
        assert_eq!(stats.iters, 5);
        assert_eq!(calls, 6); // 1 warmup + 5 timed
    }

    #[test]
    fn throughput_math() {
        let s = Stats::from_samples(vec![Duration::from_millis(100)]);
        let tput = s.per_second(50.0);
        assert!((tput - 500.0).abs() < 1.0);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("tX", "demo", &["a", "bb"]);
        t.row(vec!["x".into(), Cell::Pct(92.6, 1)]);
        t.row(vec!["longer".into(), Cell::F(0.5, 2)]);
        t.note("seed=1");
        let md = t.to_markdown();
        assert!(md.contains("| a      | bb    |"), "got:\n{md}");
        assert!(md.contains("92.6%"));
        assert!(md.contains("> seed=1"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("t", "x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_duration_scales() {
        assert_eq!(fmt_duration(Duration::from_micros(500)), "500.0µs");
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.00ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000s");
    }

    #[test]
    fn fmt_bytes_scales() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0MiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.00GiB");
    }
}
