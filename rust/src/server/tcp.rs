//! Threaded TCP front-end over the serving runtime.
//!
//! One listener thread accepts connections; each connection gets a reader
//! thread (decode one [`proto::WireOp`] per line → forward to the
//! scheduler's op channel) and a writer thread that is the connection's
//! **event sink**: every in-flight request on the connection owns a
//! `LineSink` that encodes its [`ServeEvent`]s (token/done/error/stats/
//! cancelled) into JSON lines and pushes them onto the writer channel. In
//! the sharded runtime a connection's requests may be decoding on
//! different workers concurrently; their results all fan back in over this
//! one writer channel, so streamed events from concurrent requests
//! interleave but each line stays atomic and per-request ordering is
//! preserved (a request lives on exactly one worker). The engines
//! themselves stay on their worker threads (PJRT handles are not `Send`).
//!
//! Request ids are namespaced per connection before they reach the
//! scheduler (`conn_id << 32 | id`) and rewritten back to the client's
//! ids on the way out, so concurrent clients can't observe or cancel each
//! other's requests. Session ids are runtime-global by design: a kept
//! session may be continued from a different connection (it routes to the
//! owning worker either way).

use crate::coordinator::{CompressionSpec, EventSink, Op, Request, Response, ServeEvent};
use crate::server::proto::{self, RequestBuilder, WireOp};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

static CONN_IDS: AtomicU64 = AtomicU64::new(1);

/// Cooperative stop signal for a listener's accept loop. Cheap to clone;
/// hand one copy to [`serve_until`] and keep another to call
/// [`StopHandle::stop`] — the blocked `accept` is woken with a throwaway
/// loopback connection, the loop exits, and dropping the listener releases
/// the socket and its thread (previously every bench/test boot parked a
/// listener thread until process exit).
#[derive(Clone)]
pub struct StopHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl StopHandle {
    /// Build a handle for `listener` (captures its local address so
    /// [`Self::stop`] can dial it to unblock `accept`).
    pub fn for_listener(listener: &TcpListener) -> crate::Result<StopHandle> {
        Ok(StopHandle {
            addr: listener.local_addr()?,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Signal the accept loop to exit. Idempotent; safe from any thread.
    /// The wake-up dial is attempted on EVERY call (not just the first),
    /// so a transiently failed connect can be recovered by calling
    /// `stop()` again instead of leaving the accept loop blocked with the
    /// flag already set; once the listener is gone the dial fails
    /// harmlessly.
    pub fn stop(&self) {
        // lint: note(relaxed-ordering-audit): Release publishes the stop flag; the Acquire
        // load in is_stopped() synchronizes-with it, so the accept loop that observes `true`
        // also observes everything the stopping thread did first. SeqCst bought nothing here:
        // there is no second atomic whose ordering relative to this flag matters.
        self.stop.store(true, Ordering::Release);
        // Wake the blocked accept; the loop sees the flag and breaks
        // before handling this throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }

    pub fn is_stopped(&self) -> bool {
        // lint: note(relaxed-ordering-audit): Acquire pairs with the Release store in stop().
        self.stop.load(Ordering::Acquire)
    }
}

/// Accept-and-serve loop. Blocks the calling thread; spawn it alongside the
/// coordinator thread. Returns only on listener error (no stop signal —
/// the long-running `mikv serve` shape). Use [`serve_until`] when the
/// listener must be releasable (benches, tests, embedded stacks).
pub fn serve(listener: TcpListener, tx: Sender<Op>) -> crate::Result<()> {
    let stop = StopHandle::for_listener(&listener)?;
    serve_until(listener, tx, stop)
}

/// Accept-and-serve until `stop` fires (graceful listener shutdown):
/// in-flight connections keep their threads, but the accept loop exits and
/// the listener socket is released when this returns.
pub fn serve_until(listener: TcpListener, tx: Sender<Op>, stop: StopHandle) -> crate::Result<()> {
    let addr = listener.local_addr()?;
    crate::log_info!("serving on {addr}");
    for stream in listener.incoming() {
        if stop.is_stopped() {
            break;
        }
        let stream = stream?;
        let tx = tx.clone();
        std::thread::spawn(move || {
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_default();
            if let Err(e) = handle_conn(stream, tx) {
                crate::log_debug!("connection {peer} closed: {e}");
            }
        });
    }
    crate::log_info!("listener on {addr} stopped");
    Ok(())
}

/// Per-request event sink: encodes events (v1 or legacy) into lines on the
/// connection's writer channel, rewriting coordinator-namespaced ids back
/// to the ids the client sent.
struct LineSink {
    tx: Sender<String>,
    wire_id: u64,
    legacy: bool,
}

impl EventSink for LineSink {
    fn emit(&self, ev: ServeEvent) -> bool {
        let ev = match ev {
            ServeEvent::Token { index, token, .. } => ServeEvent::Token {
                id: self.wire_id,
                index,
                token,
            },
            ServeEvent::Done(mut r) => {
                r.id = self.wire_id;
                ServeEvent::Done(r)
            }
            ServeEvent::Stats { snapshot, .. } => ServeEvent::Stats {
                id: self.wire_id,
                snapshot,
            },
            ServeEvent::CancelResult { target, found, .. } => ServeEvent::CancelResult {
                id: self.wire_id,
                target: target & 0xFFFF_FFFF,
                found,
            },
        };
        let line = if self.legacy {
            match proto::encode_legacy_event(&ev) {
                Some(line) => line,
                // token/stats events have no legacy representation
                None => return true,
            }
        } else {
            proto::encode_event(&ev)
        };
        self.tx.send(line).is_ok()
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<Op>) -> crate::Result<()> {
    // lint: relaxed-ordering-audit-ok: unique-id counter — only atomicity matters; no cross-thread data is published under this fetch_add
    let conn_id = CONN_IDS.fetch_add(1, Ordering::Relaxed);
    let reader = BufReader::new(stream.try_clone()?);
    let (line_tx, line_rx) = std::sync::mpsc::channel::<String>();

    // Writer thread: deliver event lines in emission order.
    let mut write_half = stream;
    let writer = std::thread::spawn(move || {
        for line in line_rx {
            if write_half
                .write_all(line.as_bytes())
                .and_then(|_| write_half.write_all(b"\n"))
                .is_err()
            {
                break;
            }
        }
    });

    // Namespace ids per connection so concurrent clients don't collide.
    let ns = |id: u64| conn_id << 32 | (id & 0xFFFF_FFFF);
    // Per-request event sink bound to this connection's writer.
    let sink = |wire_id: u64, legacy: bool| -> crate::coordinator::Reply {
        Box::new(LineSink {
            tx: line_tx.clone(),
            wire_id,
            legacy,
        })
    };
    let send = |op: Op| -> crate::Result<()> {
        anyhow::ensure!(tx.send(op).is_ok(), "coordinator gone");
        Ok(())
    };
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match proto::decode_line(&line) {
            Ok(WireOp::Submit(w)) => send(Op::Submit(Request {
                id: ns(w.id),
                prompt: w.prompt,
                max_new: w.max_new,
                stop: w.stop,
                spec: w.spec,
                session: w.session,
                keep: w.keep,
                // The connection is the tenant: QoS fair-queues and
                // rate-limits per connection, so one chatty client can't
                // starve its neighbours.
                tenant: conn_id,
                priority: w.priority,
                submitted_at: Instant::now(),
                reply: sink(w.id, w.legacy),
            }))?,
            Ok(WireOp::Cancel { id, target }) => send(Op::Cancel {
                id: ns(id),
                target: ns(target),
                reply: sink(id, false),
            })?,
            Ok(WireOp::Stats { id }) => send(Op::Stats {
                id: ns(id),
                reply: sink(id, false),
            })?,
            Err(de) => {
                // Malformed line: answer directly in the right encoding.
                let resp = Response::error(de.id, de.err);
                let out = if de.legacy {
                    proto::encode_legacy_response(&resp)
                } else {
                    proto::encode_event(&ServeEvent::Done(resp))
                };
                let _ = line_tx.send(out);
            }
        }
    }
    drop(line_tx);
    let _ = writer.join();
    Ok(())
}

/// Blocking JSON-lines client (used by examples, tests and the CI smoke).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> crate::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    /// Allocate the next request id.
    pub fn next_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Send a raw request line (callers should prefer [`Client::submit`]).
    pub fn send_line(&mut self, line: &str) -> crate::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Send a built request.
    pub fn submit(&mut self, req: &RequestBuilder) -> crate::Result<()> {
        self.send_line(&req.build())
    }

    /// Fire a **legacy** one-shot generation request (single response
    /// line); returns the request id used.
    pub fn request(
        &mut self,
        prompt: &[i64],
        max_new: usize,
        spec: &CompressionSpec,
    ) -> crate::Result<u64> {
        let id = self.next_id();
        let line = RequestBuilder::generate(id)
            .prompt(prompt)
            .max_new(max_new)
            .compression(spec.clone())
            .legacy()
            .build();
        self.send_line(&line)?;
        Ok(id)
    }

    /// Block for the next response/event line.
    pub fn recv(&mut self) -> crate::Result<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed connection");
        Ok(Json::parse(line.trim())?)
    }

    /// Read one v1 turn to completion: collects this request's streamed
    /// `token` events and returns them with the terminal `done`/`error`
    /// event. Lines belonging to other in-flight ids are skipped, so keep
    /// one outstanding streaming turn per client when using this helper.
    pub fn read_turn(&mut self, id: u64) -> crate::Result<(Vec<i64>, Json)> {
        let mut tokens = Vec::new();
        loop {
            let v = self.recv()?;
            if v.field("id").ok().and_then(Json::as_i64) != Some(id as i64) {
                continue;
            }
            let ev = v.field_str("event").unwrap_or("").to_string();
            match ev.as_str() {
                "token" => tokens.push(v.field_i64("t")?),
                "done" | "error" | "stats" | "cancelled" => return Ok((tokens, v)),
                _ => anyhow::bail!("unexpected line for id {id}: {v}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    /// Graceful listener shutdown: `stop()` wakes the blocked accept, the
    /// serve thread joins, and the socket is released (new connections are
    /// refused) instead of parking the listener until process exit.
    #[test]
    fn stop_handle_releases_listener_thread_and_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = StopHandle::for_listener(&listener).unwrap();
        assert!(!stop.is_stopped());

        let (tx, _rx) = mpsc::channel::<Op>();
        let stop_l = stop.clone();
        let server = std::thread::spawn(move || serve_until(listener, tx, stop_l));

        // the loop is alive: a client can connect while un-stopped
        assert!(TcpStream::connect(addr).is_ok());

        stop.stop();
        stop.stop(); // idempotent
        server.join().expect("serve thread").expect("clean exit");
        assert!(stop.is_stopped());

        // the listener is gone with the thread: loopback refuses new dials
        assert!(
            TcpStream::connect(addr).is_err(),
            "socket must be released after stop"
        );
    }
}
