//! Threaded TCP front-end over the coordinator.
//!
//! One listener thread accepts connections; each connection gets a reader
//! thread (parse JSON line → forward to the coordinator with a reply
//! channel) and a writer thread (serialize responses back). The engine
//! itself stays on the coordinator thread (PJRT handles are not `Send`).

use crate::coordinator::{Request, Response};
use crate::runtime::ModelDims;
use crate::server::proto;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

static CONN_IDS: AtomicU64 = AtomicU64::new(1);

/// Accept-and-serve loop. Blocks the calling thread; spawn it alongside the
/// coordinator thread. Returns only on listener error.
pub fn serve(
    listener: TcpListener,
    dims: ModelDims,
    tx: Sender<Request>,
) -> crate::Result<()> {
    crate::log_info!("serving on {}", listener.local_addr()?);
    let dims = Arc::new(dims);
    for stream in listener.incoming() {
        let stream = stream?;
        let tx = tx.clone();
        let dims = dims.clone();
        std::thread::spawn(move || {
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_default();
            if let Err(e) = handle_conn(stream, &dims, tx) {
                crate::log_debug!("connection {peer} closed: {e}");
            }
        });
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    dims: &ModelDims,
    tx: Sender<Request>,
) -> crate::Result<()> {
    let conn_id = CONN_IDS.fetch_add(1, Ordering::Relaxed);
    let reader = BufReader::new(stream.try_clone()?);
    let (reply_tx, reply_rx) = std::sync::mpsc::channel::<Response>();

    // Writer thread: deliver responses in completion order.
    let mut write_half = stream;
    let writer = std::thread::spawn(move || {
        for resp in reply_rx {
            let line = proto::encode_response(&resp);
            if write_half
                .write_all(line.as_bytes())
                .and_then(|_| write_half.write_all(b"\n"))
                .is_err()
            {
                break;
            }
        }
    });

    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match proto::decode_request(&line, dims) {
            Ok(w) => {
                let req = Request {
                    // namespace ids per connection so concurrent clients
                    // don't collide in logs
                    id: conn_id << 32 | (w.id & 0xFFFF_FFFF),
                    prompt: w.prompt,
                    max_new: w.max_new,
                    stop: w.stop,
                    mode: w.mode,
                    submitted_at: Instant::now(),
                    reply: reply_tx.clone(),
                };
                if tx.send(req).is_err() {
                    anyhow::bail!("coordinator gone");
                }
            }
            Err(e) => {
                let _ = reply_tx.send(Response::error(0, format!("bad request: {e}")));
            }
        }
    }
    drop(reply_tx);
    let _ = writer.join();
    Ok(())
}

/// Blocking JSON-lines client (used by examples and the serve bench).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> crate::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    /// Send a raw request line (the `id` field is managed by the caller).
    pub fn send_line(&mut self, line: &str) -> crate::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Fire a generation request; returns the request id used.
    pub fn request(
        &mut self,
        prompt: &[i64],
        max_new: usize,
        mode_json: &str,
    ) -> crate::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let prompt_s: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
        self.send_line(&format!(
            r#"{{"id":{id},"prompt":[{}],"max_new":{max_new},{mode_json}}}"#,
            prompt_s.join(",")
        ))?;
        Ok(id)
    }

    /// Block for the next response line.
    pub fn recv(&mut self) -> crate::Result<crate::util::json::Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed connection");
        Ok(crate::util::json::Json::parse(line.trim())?)
    }
}
