//! Threaded TCP front-end over the serving runtime.
//!
//! One listener thread accepts connections; each connection gets a reader
//! thread (decode one [`proto::WireOp`] per line → forward to the
//! scheduler's op channel) and a writer thread that is the connection's
//! **event sink**: every in-flight request on the connection owns a
//! `LineSink` that encodes its [`ServeEvent`]s (token/done/error/stats/
//! cancelled) into JSON lines and pushes them onto the writer channel. In
//! the sharded runtime a connection's requests may be decoding on
//! different workers concurrently; their results all fan back in over this
//! one writer channel, so streamed events from concurrent requests
//! interleave but each line stays atomic and per-request ordering is
//! preserved (a request lives on exactly one worker). The engines
//! themselves stay on their worker threads (PJRT handles are not `Send`).
//!
//! Request ids are namespaced per connection before they reach the
//! scheduler (`conn_id << 32 | id`) and rewritten back to the client's
//! ids on the way out, so concurrent clients can't observe or cancel each
//! other's requests. Session ids are runtime-global by design: a kept
//! session may be continued from a different connection (it routes to the
//! owning worker either way).
//!
//! **Slow-client backpressure.** The writer channel is *bounded*
//! ([`BackpressureConfig::queue_depth`]) and the writer enforces a
//! per-write timeout plus a hard stall deadline. When a client stops
//! draining, degradation is laddered: non-terminal `token` events are
//! shed first (counted in the `events_dropped` stat); terminal
//! `done`/`error`/`stats`/`cancelled` lines are never shed; a client that
//! stays wedged past [`BackpressureConfig::stall_deadline`] is
//! disconnected, which unblocks both the writer and any worker waiting to
//! enqueue a terminal event. The defaults are generous enough that a
//! client reading at any reasonable rate sees the identical event stream
//! as an unbounded writer would produce.
//!
//! **Fault injection.** [`ServeConfig::faults`] threads a deterministic
//! [`FaultPlan`] through the listener (`accept_error`) and the per-
//! connection writer (`conn_stall`, `conn_disconnect`). Disabled by
//! default; enabled only by tests, the chaos soak, and
//! `mikv serve --fault-plan`.

use crate::coordinator::{CompressionSpec, EventSink, Op, Request, Response, ServeEvent};
use crate::server::proto::{self, RequestBuilder, WireOp};
use crate::util::faults::{FaultPlan, FaultSite};
use crate::util::json::Json;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

static CONN_IDS: AtomicU64 = AtomicU64::new(1);

/// Cooperative stop signal for a listener's accept loop. Cheap to clone;
/// hand one copy to [`serve_until`] and keep another to call
/// [`StopHandle::stop`] — the blocked `accept` is woken with a throwaway
/// loopback connection, the loop exits, and dropping the listener releases
/// the socket and its thread (previously every bench/test boot parked a
/// listener thread until process exit).
#[derive(Clone)]
pub struct StopHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl StopHandle {
    /// Build a handle for `listener` (captures its local address so
    /// [`Self::stop`] can dial it to unblock `accept`).
    pub fn for_listener(listener: &TcpListener) -> crate::Result<StopHandle> {
        Ok(StopHandle {
            addr: listener.local_addr()?,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Signal the accept loop to exit. Idempotent; safe from any thread.
    /// The wake-up dial is attempted on EVERY call (not just the first),
    /// so a transiently failed connect can be recovered by calling
    /// `stop()` again instead of leaving the accept loop blocked with the
    /// flag already set; once the listener is gone the dial fails
    /// harmlessly.
    pub fn stop(&self) {
        // lint: note(relaxed-ordering-audit): Release publishes the stop flag; the Acquire
        // load in is_stopped() synchronizes-with it, so the accept loop that observes `true`
        // also observes everything the stopping thread did first. SeqCst bought nothing here:
        // there is no second atomic whose ordering relative to this flag matters.
        self.stop.store(true, Ordering::Release);
        // Wake the blocked accept; the loop sees the flag and breaks
        // before handling this throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }

    pub fn is_stopped(&self) -> bool {
        // lint: note(relaxed-ordering-audit): Acquire pairs with the Release store in stop().
        self.stop.load(Ordering::Acquire)
    }
}

/// Slow-client limits for a connection's writer half. The defaults are
/// deliberately generous: a client reading at any reasonable rate never
/// hits them, so default behavior matches the previous unbounded writer.
#[derive(Debug, Clone, Copy)]
pub struct BackpressureConfig {
    /// Bounded writer-queue depth (lines). `token` events that arrive
    /// while the queue is full are shed (counted in `events_dropped`);
    /// terminal events block until a slot frees or the writer gives up.
    pub queue_depth: usize,
    /// Socket write timeout for one `write` call; on expiry the writer
    /// re-checks the stall deadline instead of blocking forever.
    pub write_timeout: Duration,
    /// Hard deadline: if a connection makes **no write progress** for
    /// this long it is disconnected (shutdown both halves).
    pub stall_deadline: Duration,
}

impl Default for BackpressureConfig {
    fn default() -> Self {
        BackpressureConfig {
            queue_depth: 1024,
            write_timeout: Duration::from_secs(5),
            stall_deadline: Duration::from_secs(30),
        }
    }
}

/// Front-end configuration for [`serve_until_with`]. `Default` preserves
/// the historical wire behavior: no fault injection, backpressure limits
/// far above what a draining client ever touches.
#[derive(Debug, Clone, Default)]
pub struct ServeConfig {
    pub backpressure: BackpressureConfig,
    pub faults: FaultPlan,
}

/// Per-listener state shared by every connection it accepts.
struct ConnShared {
    bp: BackpressureConfig,
    faults: FaultPlan,
    /// Server-global count of `token` events shed by slow-client
    /// backpressure; folded into every `stats` snapshot on the way out.
    events_dropped: AtomicU64,
}

/// Accept-and-serve loop. Blocks the calling thread; spawn it alongside the
/// coordinator thread. Returns only on listener error (no stop signal —
/// the long-running `mikv serve` shape). Use [`serve_until`] when the
/// listener must be releasable (benches, tests, embedded stacks).
pub fn serve(listener: TcpListener, tx: Sender<Op>) -> crate::Result<()> {
    let stop = StopHandle::for_listener(&listener)?;
    serve_until(listener, tx, stop)
}

/// Accept-and-serve until `stop` fires (graceful listener shutdown):
/// in-flight connections keep their threads, but the accept loop exits and
/// the listener socket is released when this returns.
pub fn serve_until(listener: TcpListener, tx: Sender<Op>, stop: StopHandle) -> crate::Result<()> {
    serve_until_with(listener, tx, stop, ServeConfig::default())
}

/// Give up on the listener only after this many accept errors in a row
/// (a single `Ok` resets the streak). Transient failures — EMFILE under
/// connection churn, aborted handshakes, injected faults — must not kill
/// the serving runtime's front door.
const MAX_CONSECUTIVE_ACCEPT_ERRORS: u32 = 64;

/// [`serve_until`] with explicit backpressure limits and fault plan.
///
/// A transient `accept` error no longer aborts the listener (it used to
/// propagate immediately, silently killing the front door while workers
/// kept running): the error is logged, the loop backs off briefly and
/// keeps accepting, and only [`MAX_CONSECUTIVE_ACCEPT_ERRORS`] failures
/// in a row — a dead listener socket, not a bad handshake — propagate.
pub fn serve_until_with(
    listener: TcpListener,
    tx: Sender<Op>,
    stop: StopHandle,
    cfg: ServeConfig,
) -> crate::Result<()> {
    let addr = listener.local_addr()?;
    crate::log_info!("serving on {addr}");
    let shared = Arc::new(ConnShared {
        bp: cfg.backpressure,
        faults: cfg.faults,
        events_dropped: AtomicU64::new(0),
    });
    let mut consecutive_errs = 0u32;
    loop {
        if stop.is_stopped() {
            break;
        }
        let accepted = if shared.faults.should_fire(FaultSite::AcceptError) {
            Err(io::Error::new(
                io::ErrorKind::ConnectionAborted,
                "fault plan: injected accept error",
            ))
        } else {
            listener.accept().map(|(s, _)| s)
        };
        if stop.is_stopped() {
            break;
        }
        match accepted {
            Ok(stream) => {
                consecutive_errs = 0;
                let tx = tx.clone();
                let shared = shared.clone();
                std::thread::spawn(move || {
                    let peer = stream
                        .peer_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_default();
                    if let Err(e) = handle_conn(stream, tx, shared) {
                        crate::log_debug!("connection {peer} closed: {e}");
                    }
                });
            }
            Err(e) => {
                consecutive_errs += 1;
                if consecutive_errs >= MAX_CONSECUTIVE_ACCEPT_ERRORS {
                    crate::log_error!(
                        "listener on {addr}: {consecutive_errs} consecutive accept errors, giving up: {e}"
                    );
                    return Err(e.into());
                }
                crate::log_warn!("accept error on {addr} (transient, continuing): {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    crate::log_info!("listener on {addr} stopped");
    Ok(())
}

/// Per-request event sink: encodes events (v1 or legacy) into lines on the
/// connection's writer channel, rewriting coordinator-namespaced ids back
/// to the ids the client sent.
///
/// The channel is bounded; this is where the degradation ladder's first
/// rung lives. Non-terminal `token` events are sent with `try_send` and
/// shed when the queue is full (the client is not draining; dropping
/// stream progress is recoverable, the terminal `done` still carries the
/// full token vector). Terminal events use a blocking `send` — they are
/// never shed; if the writer disconnects a wedged client the send fails
/// and the worker sees `false`, exactly as for a vanished connection.
struct LineSink {
    tx: SyncSender<String>,
    wire_id: u64,
    legacy: bool,
    shared: Arc<ConnShared>,
}

impl EventSink for LineSink {
    fn emit(&self, ev: ServeEvent) -> bool {
        let droppable = matches!(ev, ServeEvent::Token { .. });
        let ev = match ev {
            ServeEvent::Token { index, token, .. } => ServeEvent::Token {
                id: self.wire_id,
                index,
                token,
            },
            ServeEvent::Done(mut r) => {
                r.id = self.wire_id;
                ServeEvent::Done(r)
            }
            ServeEvent::Stats { mut snapshot, .. } => {
                // Backpressure sheds happen on this side of the worker
                // boundary, so fold the listener-wide counter into the
                // snapshot at encode time (workers always report 0).
                // lint: relaxed-ordering-audit-ok: monotonic counter folded into a point-in-time snapshot; no ordering dependency
                snapshot.events_dropped += self.shared.events_dropped.load(Ordering::Relaxed);
                ServeEvent::Stats {
                    id: self.wire_id,
                    snapshot,
                }
            }
            ServeEvent::CancelResult { target, found, .. } => ServeEvent::CancelResult {
                id: self.wire_id,
                target: target & 0xFFFF_FFFF,
                found,
            },
        };
        let line = if self.legacy {
            match proto::encode_legacy_event(&ev) {
                Some(line) => line,
                // token/stats events have no legacy representation
                None => return true,
            }
        } else {
            proto::encode_event(&ev)
        };
        if droppable {
            match self.tx.try_send(line) {
                Ok(()) => true,
                Err(TrySendError::Full(_)) => {
                    // Slow client: shed the token event, keep decoding.
                    // lint: relaxed-ordering-audit-ok: monotonic stat counter; readers only need eventual totals
                    self.shared.events_dropped.fetch_add(1, Ordering::Relaxed);
                    true
                }
                Err(TrySendError::Disconnected(_)) => false,
            }
        } else {
            self.tx.send(line).is_ok()
        }
    }
}

/// Writer half of a connection: drain event lines in emission order,
/// enforcing the per-write timeout and the hard stall deadline. The
/// deadline tracks *progress*, not whole lines — a trickling client that
/// accepts a byte every few seconds stays connected; one that accepts
/// nothing for [`BackpressureConfig::stall_deadline`] is cut off. On exit
/// both socket halves are shut down so the reader thread unblocks too.
fn writer_loop(
    mut stream: TcpStream,
    rx: std::sync::mpsc::Receiver<String>,
    bp: BackpressureConfig,
    faults: FaultPlan,
) {
    if stream.set_write_timeout(Some(bp.write_timeout)).is_err() {
        // Pathological socket; fall back to blocking writes rather than
        // dropping the connection on a setsockopt failure.
        crate::log_warn!("set_write_timeout failed; writer runs without stall detection");
    }
    'conn: for line in rx {
        if faults.should_fire(FaultSite::ConnDisconnect) {
            crate::log_warn!("fault plan: injected mid-stream disconnect");
            break 'conn;
        }
        if faults.should_fire(FaultSite::ConnStall) {
            std::thread::sleep(Duration::from_millis(faults.stall_ms(FaultSite::ConnStall)));
        }
        let mut buf = line.into_bytes();
        buf.push(b'\n');
        let mut off = 0usize;
        let mut last_progress = Instant::now();
        while off < buf.len() {
            match stream.write(buf.get(off..).unwrap_or(&[])) {
                Ok(0) => break 'conn,
                Ok(n) => {
                    off += n;
                    last_progress = Instant::now();
                }
                Err(e)
                    if matches!(e.kind(), io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock) =>
                {
                    if last_progress.elapsed() >= bp.stall_deadline {
                        crate::log_warn!(
                            "client made no write progress for {:?}; disconnecting",
                            bp.stall_deadline
                        );
                        break 'conn;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => break 'conn,
            }
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

fn handle_conn(stream: TcpStream, tx: Sender<Op>, shared: Arc<ConnShared>) -> crate::Result<()> {
    // lint: relaxed-ordering-audit-ok: unique-id counter — only atomicity matters; no cross-thread data is published under this fetch_add
    let conn_id = CONN_IDS.fetch_add(1, Ordering::Relaxed);
    let reader = BufReader::new(stream.try_clone()?);
    let (line_tx, line_rx) = std::sync::mpsc::sync_channel::<String>(shared.bp.queue_depth.max(1));

    // Writer thread: deliver event lines in emission order, under the
    // backpressure limits (bounded queue upstream, stall deadline here).
    let write_half = stream;
    let bp = shared.bp;
    let faults = shared.faults.clone();
    let writer = std::thread::spawn(move || writer_loop(write_half, line_rx, bp, faults));

    // Namespace ids per connection so concurrent clients don't collide.
    let ns = |id: u64| conn_id << 32 | (id & 0xFFFF_FFFF);
    // Per-request event sink bound to this connection's writer.
    let sink = |wire_id: u64, legacy: bool| -> crate::coordinator::Reply {
        Box::new(LineSink {
            tx: line_tx.clone(),
            wire_id,
            legacy,
            shared: shared.clone(),
        })
    };
    let send = |op: Op| -> crate::Result<()> {
        anyhow::ensure!(tx.send(op).is_ok(), "coordinator gone");
        Ok(())
    };
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match proto::decode_line(&line) {
            Ok(WireOp::Submit(w)) => send(Op::Submit(Request {
                id: ns(w.id),
                prompt: w.prompt,
                max_new: w.max_new,
                stop: w.stop,
                spec: w.spec,
                session: w.session,
                keep: w.keep,
                // The connection is the tenant: QoS fair-queues and
                // rate-limits per connection, so one chatty client can't
                // starve its neighbours.
                tenant: conn_id,
                priority: w.priority,
                submitted_at: Instant::now(),
                reply: sink(w.id, w.legacy),
            }))?,
            Ok(WireOp::Cancel { id, target }) => send(Op::Cancel {
                id: ns(id),
                target: ns(target),
                reply: sink(id, false),
            })?,
            Ok(WireOp::Stats { id }) => send(Op::Stats {
                id: ns(id),
                reply: sink(id, false),
            })?,
            Err(de) => {
                // Malformed line: answer directly in the right encoding.
                let resp = Response::error(de.id, de.err);
                let out = if de.legacy {
                    proto::encode_legacy_response(&resp)
                } else {
                    proto::encode_event(&ServeEvent::Done(resp))
                };
                let _ = line_tx.send(out);
            }
        }
    }
    drop(line_tx);
    let _ = writer.join();
    Ok(())
}

/// Blocking JSON-lines client (used by examples, tests and the CI smoke).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> crate::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 1,
        })
    }

    /// Allocate the next request id.
    pub fn next_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Send a raw request line (callers should prefer [`Client::submit`]).
    pub fn send_line(&mut self, line: &str) -> crate::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Send a built request.
    pub fn submit(&mut self, req: &RequestBuilder) -> crate::Result<()> {
        self.send_line(&req.build())
    }

    /// Fire a **legacy** one-shot generation request (single response
    /// line); returns the request id used.
    pub fn request(
        &mut self,
        prompt: &[i64],
        max_new: usize,
        spec: &CompressionSpec,
    ) -> crate::Result<u64> {
        let id = self.next_id();
        let line = RequestBuilder::generate(id)
            .prompt(prompt)
            .max_new(max_new)
            .compression(spec.clone())
            .legacy()
            .build();
        self.send_line(&line)?;
        Ok(id)
    }

    /// Block for the next response/event line.
    pub fn recv(&mut self) -> crate::Result<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed connection");
        Ok(Json::parse(line.trim())?)
    }

    /// Read one v1 turn to completion: collects this request's streamed
    /// `token` events and returns them with the terminal `done`/`error`
    /// event. Lines belonging to other in-flight ids are skipped, so keep
    /// one outstanding streaming turn per client when using this helper.
    pub fn read_turn(&mut self, id: u64) -> crate::Result<(Vec<i64>, Json)> {
        let mut tokens = Vec::new();
        loop {
            let v = self.recv()?;
            if v.field("id").ok().and_then(Json::as_i64) != Some(id as i64) {
                continue;
            }
            let ev = v.field_str("event").unwrap_or("").to_string();
            match ev.as_str() {
                "token" => tokens.push(v.field_i64("t")?),
                "done" | "error" | "stats" | "cancelled" => return Ok((tokens, v)),
                _ => anyhow::bail!("unexpected line for id {id}: {v}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{StatsSnapshot, WireError};
    use crate::util::faults::FaultRule;
    use std::sync::mpsc;

    /// Graceful listener shutdown: `stop()` wakes the blocked accept, the
    /// serve thread joins, and the socket is released (new connections are
    /// refused) instead of parking the listener until process exit.
    #[test]
    fn stop_handle_releases_listener_thread_and_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = StopHandle::for_listener(&listener).unwrap();
        assert!(!stop.is_stopped());

        let (tx, _rx) = mpsc::channel::<Op>();
        let stop_l = stop.clone();
        let server = std::thread::spawn(move || serve_until(listener, tx, stop_l));

        // the loop is alive: a client can connect while un-stopped
        assert!(TcpStream::connect(addr).is_ok());

        stop.stop();
        stop.stop(); // idempotent
        server.join().expect("serve thread").expect("clean exit");
        assert!(stop.is_stopped());

        // the listener is gone with the thread: loopback refuses new dials
        assert!(
            TcpStream::connect(addr).is_err(),
            "socket must be released after stop"
        );
    }

    /// Regression for the accept-loop fault domain: a transient accept
    /// error used to propagate out of `serve_until` and silently kill the
    /// listener while workers kept running. Now it logs, backs off, and
    /// keeps accepting — a client connecting after a burst of injected
    /// accept errors is still served.
    #[test]
    fn transient_accept_errors_do_not_kill_the_listener() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = StopHandle::for_listener(&listener).unwrap();
        let plan = FaultPlan::builder()
            .site(
                FaultSite::AcceptError,
                FaultRule {
                    every: 1,
                    after: 0,
                    limit: 3,
                    ms: 0,
                },
            )
            .build();
        let cfg = ServeConfig {
            faults: plan.clone(),
            ..ServeConfig::default()
        };
        let (tx, _rx) = mpsc::channel::<Op>();
        let stop_l = stop.clone();
        let server = std::thread::spawn(move || serve_until_with(listener, tx, stop_l, cfg));

        // The first 3 accept attempts fail by injection; the connection
        // sits in the kernel backlog until the loop recovers and accepts
        // it. A malformed line is answered directly by the connection
        // handler (no coordinator needed), proving end-to-end service.
        let mut client = Client::connect(&addr.to_string()).unwrap();
        client.send_line("{\"v\":1,\"op\":\"nonsense\"}").unwrap();
        let reply = client.recv().unwrap();
        assert_eq!(reply.field_str("event").unwrap_or(""), "error");
        assert_eq!(plan.fired(FaultSite::AcceptError), 3);

        stop.stop();
        server.join().expect("serve thread").expect("clean exit");
    }

    /// A persistently failing accept (every attempt, no limit) must give
    /// up with a structured error instead of spinning forever.
    #[test]
    fn persistent_accept_errors_eventually_propagate() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let stop = StopHandle::for_listener(&listener).unwrap();
        let plan = FaultPlan::builder().every(FaultSite::AcceptError, 1).build();
        let cfg = ServeConfig {
            faults: plan,
            ..ServeConfig::default()
        };
        let (tx, _rx) = mpsc::channel::<Op>();
        let err = serve_until_with(listener, tx, stop, cfg);
        assert!(err.is_err(), "dead listener must propagate, got {err:?}");
    }

    /// The degradation ladder's first rung: with the writer queue full,
    /// `token` events are shed (counted, emit still returns `true` so the
    /// worker keeps decoding) while terminal events are never shed, and
    /// the shed count is folded into outgoing stats snapshots.
    #[test]
    fn slow_client_sheds_tokens_but_never_terminals() {
        let (tx, rx) = mpsc::sync_channel::<String>(1);
        let shared = Arc::new(ConnShared {
            bp: BackpressureConfig::default(),
            faults: FaultPlan::disabled(),
            events_dropped: AtomicU64::new(0),
        });
        let sink = LineSink {
            tx,
            wire_id: 7,
            legacy: false,
            shared: shared.clone(),
        };
        // first token fills the queue's single slot
        assert!(sink.emit(ServeEvent::Token {
            id: 7,
            index: 0,
            token: 11,
        }));
        // queue full: further tokens are shed, not blocked on
        for index in 1..3 {
            assert!(sink.emit(ServeEvent::Token {
                id: 7,
                index,
                token: 11 + index as i64,
            }));
        }
        assert_eq!(shared.events_dropped.load(Ordering::Relaxed), 2);

        // drain the slot; a terminal error then goes through intact
        assert!(rx.recv().unwrap().contains("\"token\""));
        assert!(sink.emit(ServeEvent::Done(Response::error(
            7,
            WireError::internal("boom".to_string()),
        ))));
        assert!(rx.recv().unwrap().contains("\"error\""));

        // stats snapshots leaving this connection carry the shed count
        assert!(sink.emit(ServeEvent::Stats {
            id: 7,
            snapshot: StatsSnapshot::default(),
        }));
        let line = rx.recv().unwrap();
        assert!(
            line.contains("\"events_dropped\":2"),
            "stats line must fold in shed count: {line}"
        );
    }

    /// An injected mid-stream disconnect tears down both socket halves:
    /// the client observes EOF instead of a hung stream.
    #[test]
    fn injected_disconnect_closes_the_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = StopHandle::for_listener(&listener).unwrap();
        let plan = FaultPlan::builder()
            .every(FaultSite::ConnDisconnect, 1)
            .build();
        let cfg = ServeConfig {
            faults: plan,
            ..ServeConfig::default()
        };
        let (tx, _rx) = mpsc::channel::<Op>();
        let stop_l = stop.clone();
        let server = std::thread::spawn(move || serve_until_with(listener, tx, stop_l, cfg));

        let mut client = Client::connect(&addr.to_string()).unwrap();
        // malformed line → the handler queues a direct error reply; the
        // writer's disconnect fault fires before it is written out
        client.send_line("not json").unwrap();
        assert!(
            client.recv().is_err(),
            "client must see EOF after injected disconnect"
        );

        stop.stop();
        server.join().expect("serve thread").expect("clean exit");
    }
}
