//! TCP JSON-lines serving front-end.
//!
//! * [`proto`] — wire format: one JSON object per line in both directions.
//! * [`tcp`] — threaded listener: one reader thread per connection
//!   forwarding requests to the coordinator channel, one writer thread
//!   delivering responses back; plus a blocking [`tcp::Client`].

pub mod proto;
pub mod tcp;

pub use proto::{decode_request, encode_response, WireRequest};
pub use tcp::{serve, Client};
