//! TCP JSON-lines serving front-end.
//!
//! * [`proto`] — the versioned wire format ("Serving API v1"): one JSON
//!   envelope per line in (`{"v":1,"op":"generate"|"append"|"cancel"|
//!   "stats",...}`), one event per line out (`token` stream + terminal
//!   `done`/`error`, `stats`, `cancelled`), plus the legacy v-less
//!   one-shot shape and a [`RequestBuilder`] so clients never hand-roll
//!   protocol JSON. See the [`proto`] module docs for the full grammar.
//! * [`tcp`] — threaded listener: one reader thread per connection
//!   forwarding decoded ops to the scheduler channel, one writer thread
//!   acting as the connection's event sink (worker results fan back in
//!   over it); plus a blocking [`tcp::Client`] with streaming helpers.
//!   The writer is bounded and stall-aware ([`tcp::BackpressureConfig`]):
//!   slow clients shed `token` events first and are disconnected only
//!   past a hard stall deadline — terminal events are never shed.
//! * [`loadgen`] — multi-connection load generator (M connections × K
//!   turns) shared by `examples/client.rs --load` and the
//!   `serve_throughput` bench.

pub mod loadgen;
pub mod proto;
pub mod tcp;

pub use loadgen::{run_load, LoadConfig, LoadReport, Scenario};
pub use proto::{
    decode_line, encode_event, encode_legacy_response, DecodeError, RequestBuilder, WireOp,
    WireRequest,
};
pub use tcp::{
    serve, serve_until, serve_until_with, BackpressureConfig, Client, ServeConfig, StopHandle,
};
