//! Multi-connection load generator for the serving TCP stack.
//!
//! Drives `conns` concurrent client connections, each running a
//! `turns`-turn conversation (streamed `generate` with `keep`, then
//! `append`s into the same session; the final turn releases the session so
//! a finished run leaves no parked state behind). Per-turn TTFT and
//! latency are measured client-side; a trailing `stats` op collects the
//! per-worker breakdown so worker utilization is part of the report.
//!
//! Shared by `examples/client.rs --load` and
//! `benches/serve_throughput.rs` so the CLI load mode and the benchmark
//! measure exactly the same workload.

use crate::bench::percentile;
use crate::coordinator::{CompressionSpec, CoordinatorConfig, Op, Scheduler};
use crate::model::StubEngine;
use crate::server::{Client, RequestBuilder};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use std::time::{Duration, Instant};

/// Boot a sharded StubEngine serving stack — scheduler + `workers` engine
/// workers (each a [`StubEngine::fork`] of `base`) + a TCP listener on an
/// ephemeral local port — run `f` against its socket address on a driver
/// thread, and drain the runtime once `f` returns. The one boot contract
/// shared by `examples/client.rs --load`, `benches/serve_throughput.rs`
/// and the concurrency suite.
///
/// Teardown is complete: once the driver finishes and the scheduler
/// drains, the listener is stopped via [`crate::server::StopHandle`] and
/// its thread joined, so the ephemeral port and thread are released
/// instead of parking until process exit (benches boot many stacks per
/// run).
pub fn with_stub_stack<T, F>(
    workers: usize,
    cfg: CoordinatorConfig,
    base: StubEngine,
    f: F,
) -> crate::Result<T>
where
    T: Send + 'static,
    F: FnOnce(String) -> T + Send + 'static,
{
    let scheduler = Scheduler::start(workers, cfg, move |w| Ok(base.fork(w)))?;
    let (tx, rx) = std::sync::mpsc::channel::<Op>();
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stop = crate::server::StopHandle::for_listener(&listener)?;
    let stop_l = stop.clone();
    let accept_thread = std::thread::spawn(move || {
        let _ = crate::server::serve_until(listener, tx, stop_l);
    });
    let driver = std::thread::spawn(move || f(addr));
    scheduler.run_until(rx, || driver.is_finished());
    stop.stop();
    let _ = accept_thread.join();
    match driver.join() {
        Ok(v) => Ok(v),
        // Preserve assertion panics from test closures.
        Err(panic) => std::panic::resume_unwind(panic),
    }
}

/// Workload shape.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Concurrent client connections.
    pub conns: usize,
    /// Turns per connection (turn 1 is `generate`, the rest `append`).
    pub turns: usize,
    /// Token budget per turn.
    pub max_new: usize,
    /// Prompt tokens per turn.
    pub prompt_len: usize,
    /// Compression requested for each conversation.
    pub spec: CompressionSpec,
    /// Master seed; each connection derives an independent prompt stream.
    pub seed: u64,
    /// Exclusive upper bound for synthesized prompt token ids.
    pub vocab: i64,
}

impl Default for LoadConfig {
    fn default() -> Self {
        Self {
            conns: 8,
            turns: 2,
            max_new: 16,
            prompt_len: 6,
            spec: CompressionSpec::mikv(0.25, "int4"),
            seed: 0x10AD,
            vocab: 32,
        }
    }
}

/// One worker's share of the generated load.
#[derive(Debug, Clone)]
pub struct WorkerUtil {
    pub worker: usize,
    pub completed: usize,
    pub generated_tokens: usize,
    /// Fraction of all generated tokens this worker produced.
    pub share: f64,
}

/// Aggregated result of one load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Turns that ended with `done`.
    pub turns_ok: usize,
    /// Turns that ended with a wire `error`.
    pub turns_err: usize,
    /// Tokens streamed across all turns.
    pub tokens: usize,
    /// Wall-clock time from first submit to last terminal event.
    pub wall: Duration,
    /// `tokens / wall`.
    pub tokens_per_sec: f64,
    pub ttft_p50: Duration,
    pub ttft_p99: Duration,
    pub latency_p50: Duration,
    pub latency_p99: Duration,
    /// Per-worker utilization from the trailing `stats` op (empty if the
    /// server predates per-worker rows).
    pub per_worker: Vec<WorkerUtil>,
    /// Server-reported p50 of per-decode-step host input-assembly time
    /// (µs), from the trailing `stats` op (0 when unreported).
    pub assembly_us_p50: f64,
    /// Server-reported p99 of per-decode-step assembly time (µs).
    pub assembly_us_p99: f64,
    /// lo→hi promotions THIS run caused (delta of the trailing `stats`
    /// against the pre-run baseline; 0 unless the workload opted into
    /// `compression.promotion`).
    pub promotions: u64,
    /// Hysteresis-suppressed promotions this run caused (same delta).
    pub thrash_suppressed: u64,
    /// Cold-tier restores THIS run caused (delta of `restore_samples`
    /// against the pre-run baseline; 0 unless the server has a cold tier
    /// and sessions aged out mid-conversation).
    pub restores: u64,
    /// Server-reported p50 of cold-restore latency (µs) from the trailing
    /// `stats` op (0 when unreported or no restore ever happened).
    pub restore_us_p50: f64,
    /// Server-reported p99 of cold-restore latency (µs).
    pub restore_us_p99: f64,
    /// Sessions still spilled on disk after the run (a clean run releases
    /// every session, so nonzero means the workload left cold state).
    pub parked_cold_sessions: usize,
    /// Their on-disk footprint in bytes.
    pub cold_bytes: u64,
}

/// Per-connection raw samples.
struct ConnResult {
    ttfts: Vec<Duration>,
    latencies: Vec<Duration>,
    tokens: usize,
    ok: usize,
    err: usize,
}

/// Run the workload against a serving endpoint and aggregate the report.
pub fn run_load(addr: &str, cfg: &LoadConfig) -> crate::Result<LoadReport> {
    anyhow::ensure!(cfg.conns >= 1 && cfg.turns >= 1, "empty load config");
    // Per-worker counters are server-lifetime cumulative; snapshot before
    // the run so the report attributes only THIS run's tokens (matters
    // when targeting a long-running `--addr` server).
    let baseline = stats_probe(addr);
    let started = Instant::now();
    let mut handles = Vec::with_capacity(cfg.conns);
    for conn in 0..cfg.conns {
        let addr = addr.to_string();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || drive_conn(&addr, &cfg, conn)));
    }
    let mut ttfts = Vec::new();
    let mut latencies = Vec::new();
    let (mut tokens, mut ok, mut err) = (0usize, 0usize, 0usize);
    for handle in handles {
        let r = handle
            .join()
            .map_err(|_| anyhow::anyhow!("load connection panicked"))??;
        ttfts.extend(r.ttfts);
        latencies.extend(r.latencies);
        tokens += r.tokens;
        ok += r.ok;
        err += r.err;
    }
    let wall = started.elapsed();
    ttfts.sort_unstable();
    latencies.sort_unstable();

    // Trailing stats op: per-worker utilization (as the delta against the
    // pre-run baseline) plus the server's assembly_us percentiles.
    // Decoration only — any failure (server gone, old server without the
    // fields) degrades to empty/zero instead of discarding the measured
    // run.
    let after = stats_probe(addr);
    let per_worker = worker_utilization(&baseline.counters, &after.counters);

    Ok(LoadReport {
        turns_ok: ok,
        turns_err: err,
        tokens,
        wall,
        tokens_per_sec: tokens as f64 / wall.as_secs_f64().max(1e-9),
        ttft_p50: percentile(&ttfts, 0.5),
        ttft_p99: percentile(&ttfts, 0.99),
        latency_p50: percentile(&latencies, 0.5),
        latency_p99: percentile(&latencies, 0.99),
        per_worker,
        assembly_us_p50: after.assembly_us_p50,
        assembly_us_p99: after.assembly_us_p99,
        promotions: after.promotions.saturating_sub(baseline.promotions),
        thrash_suppressed: after
            .thrash_suppressed
            .saturating_sub(baseline.thrash_suppressed),
        restores: after.restore_samples.saturating_sub(baseline.restore_samples),
        restore_us_p50: after.restore_us_p50,
        restore_us_p99: after.restore_us_p99,
        parked_cold_sessions: after.parked_cold_sessions,
        cold_bytes: after.cold_bytes,
    })
}

/// One best-effort `stats` round trip: cumulative per-worker counters
/// (`worker → (completed, generated_tokens)`) plus the merged assembly
/// percentiles. Empty/zero on any failure.
#[derive(Default)]
struct StatsProbe {
    counters: std::collections::HashMap<usize, (usize, usize)>,
    assembly_us_p50: f64,
    assembly_us_p99: f64,
    promotions: u64,
    thrash_suppressed: u64,
    restore_samples: u64,
    restore_us_p50: f64,
    restore_us_p99: f64,
    parked_cold_sessions: usize,
    cold_bytes: u64,
}

fn stats_probe(addr: &str) -> StatsProbe {
    let mut out = StatsProbe::default();
    let mut client = match Client::connect(addr) {
        Ok(c) => c,
        Err(_) => return out,
    };
    let id = client.next_id();
    if client.submit(&RequestBuilder::stats(id)).is_err() {
        return out;
    }
    let stats = match client.read_turn(id) {
        Ok((_, v)) => v,
        Err(_) => return out,
    };
    out.assembly_us_p50 = stats.field_f64("assembly_us_p50").unwrap_or(0.0);
    out.assembly_us_p99 = stats.field_f64("assembly_us_p99").unwrap_or(0.0);
    out.promotions = stats.field_i64("promotions").unwrap_or(0).max(0) as u64;
    out.thrash_suppressed = stats
        .field_i64("thrash_suppressed")
        .unwrap_or(0)
        .max(0) as u64;
    out.restore_samples = stats.field_i64("restore_samples").unwrap_or(0).max(0) as u64;
    out.restore_us_p50 = stats.field_f64("restore_us_p50").unwrap_or(0.0);
    out.restore_us_p99 = stats.field_f64("restore_us_p99").unwrap_or(0.0);
    out.parked_cold_sessions = stats
        .field_i64("parked_cold_sessions")
        .unwrap_or(0)
        .max(0) as usize;
    out.cold_bytes = stats.field_i64("cold_bytes").unwrap_or(0).max(0) as u64;
    if let Ok(rows) = stats.field_arr("workers") {
        for row in rows {
            out.counters.insert(
                row.field_i64("worker").unwrap_or(0).max(0) as usize,
                (
                    row.field_i64("completed").unwrap_or(0).max(0) as usize,
                    row.field_i64("generated_tokens").unwrap_or(0).max(0) as usize,
                ),
            );
        }
    }
    out
}

/// Per-worker utilization as the delta of `after` against the pre-run
/// `baseline` counters.
fn worker_utilization(
    baseline: &std::collections::HashMap<usize, (usize, usize)>,
    after: &std::collections::HashMap<usize, (usize, usize)>,
) -> Vec<WorkerUtil> {
    let mut rows: Vec<(usize, usize, usize)> = after
        .iter()
        .map(|(&worker, &(completed, generated))| {
            let (c0, g0) = baseline.get(&worker).copied().unwrap_or((0, 0));
            (
                worker,
                completed.saturating_sub(c0),
                generated.saturating_sub(g0),
            )
        })
        .collect();
    rows.sort_unstable_by_key(|(worker, ..)| *worker);
    let total: usize = rows.iter().map(|(.., generated)| *generated).sum();
    rows.into_iter()
        .map(|(worker, completed, generated)| WorkerUtil {
            worker,
            completed,
            generated_tokens: generated,
            share: if total > 0 {
                generated as f64 / total as f64
            } else {
                0.0
            },
        })
        .collect()
}

/// One connection's conversation loop.
fn drive_conn(addr: &str, cfg: &LoadConfig, conn: usize) -> crate::Result<ConnResult> {
    let mut client = Client::connect(addr)?;
    let mut rng = Pcg32::new(cfg.seed ^ ((conn as u64 + 1) << 20));
    let mut session: Option<u64> = None;
    let mut out = ConnResult {
        ttfts: Vec::new(),
        latencies: Vec::new(),
        tokens: 0,
        ok: 0,
        err: 0,
    };
    let vocab = cfg.vocab.max(2);
    for turn in 0..cfg.turns {
        let id = client.next_id();
        // The final turn drops `keep`, so a completed conversation leaves
        // nothing parked (no session leak from a finished load run).
        let keep = turn + 1 < cfg.turns;
        let prompt: Vec<i64> = (0..cfg.prompt_len.max(1))
            .map(|_| rng.gen_range(1, vocab - 1))
            .collect();
        let builder = match session {
            Some(sid) => RequestBuilder::append(id, sid)
                .prompt(&prompt)
                .max_new(cfg.max_new)
                .keep(keep),
            None => RequestBuilder::generate(id)
                .prompt(&prompt)
                .max_new(cfg.max_new)
                .keep(keep)
                .compression(cfg.spec.clone()),
        };
        let t0 = Instant::now();
        client.submit(&builder)?;
        let mut first: Option<Duration> = None;
        loop {
            let v = client.recv()?;
            if v.field("id").ok().and_then(Json::as_i64) != Some(id as i64) {
                continue; // stale line from an earlier turn
            }
            match v.field_str("event").unwrap_or("") {
                "token" => {
                    if first.is_none() {
                        first = Some(t0.elapsed());
                    }
                    out.tokens += 1;
                }
                "done" => {
                    out.ok += 1;
                    session = v
                        .field("session")
                        .ok()
                        .and_then(Json::as_i64)
                        .map(|s| s as u64);
                    break;
                }
                "error" => {
                    out.err += 1;
                    session = None;
                    break;
                }
                other => anyhow::bail!("unexpected event '{other}' for turn {id}: {v}"),
            }
        }
        out.latencies.push(t0.elapsed());
        out.ttfts.push(first.unwrap_or_else(|| t0.elapsed()));
    }
    Ok(out)
}
