//! Multi-connection load generator for the serving TCP stack.
//!
//! Drives `conns` concurrent client connections, each running a
//! `turns`-turn conversation (streamed `generate` with `keep`, then
//! `append`s into the same session; the final turn releases the session so
//! a finished run leaves no parked state behind — including after a
//! mid-conversation error, where the orphaned session is released with an
//! explicit no-keep turn). Per-turn TTFT and latency are measured
//! client-side; error turns (shed/rate-limit rejections) are tracked
//! separately so they can't skew the ok-turn percentiles. A trailing
//! `stats` op collects the per-worker breakdown and QoS shed counters so
//! worker utilization and fairness are part of the report.
//!
//! [`Scenario`] varies the arrival process: steady (default), bursty
//! arrivals, heavy-tailed prompt lengths, a flash crowd (every connection
//! submits its first turn simultaneously), and an adversarial chatty
//! connection that submits 4× the turns of its well-behaved neighbours —
//! the workload the QoS deficit-round-robin layer exists to contain.
//!
//! Shared by `examples/client.rs --load` and
//! `benches/serve_throughput.rs` so the CLI load mode and the benchmark
//! measure exactly the same workload.

use crate::bench::percentile;
use crate::coordinator::{
    CompressionSpec, CoordinatorConfig, Op, Priority, QosConfig, Scheduler,
};
use crate::model::StubEngine;
use crate::server::{Client, RequestBuilder, ServeConfig};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// Boot a sharded StubEngine serving stack — scheduler + `workers` engine
/// workers (each a [`StubEngine::fork`] of `base`) + a TCP listener on an
/// ephemeral local port — run `f` against its socket address on a driver
/// thread, and drain the runtime once `f` returns. The one boot contract
/// shared by `examples/client.rs --load`, `benches/serve_throughput.rs`
/// and the concurrency suite.
///
/// Teardown is complete: once the driver finishes and the scheduler
/// drains, the listener is stopped via [`crate::server::StopHandle`] and
/// its thread joined, so the ephemeral port and thread are released
/// instead of parking until process exit (benches boot many stacks per
/// run).
pub fn with_stub_stack<T, F>(
    workers: usize,
    cfg: CoordinatorConfig,
    base: StubEngine,
    f: F,
) -> crate::Result<T>
where
    T: Send + 'static,
    F: FnOnce(String) -> T + Send + 'static,
{
    with_stub_stack_qos(workers, cfg, None, base, f)
}

/// [`with_stub_stack`] with an optional QoS admission layer: `Some(qos)`
/// boots the scheduler with per-connection fair queuing, priority lanes
/// and shedding; `None` is the stock FCFS stack (the two are behaviorally
/// identical until a `QosConfig` is supplied).
pub fn with_stub_stack_qos<T, F>(
    workers: usize,
    cfg: CoordinatorConfig,
    qos: Option<QosConfig>,
    base: StubEngine,
    f: F,
) -> crate::Result<T>
where
    T: Send + 'static,
    F: FnOnce(String) -> T + Send + 'static,
{
    with_stub_stack_full(workers, cfg, qos, base, ServeConfig::default(), f)
}

/// The fully-general boot: [`with_stub_stack_qos`] plus an explicit
/// [`ServeConfig`] so chaos harnesses can thread a fault plan and
/// tightened backpressure limits through the TCP front-end. The engine-
/// and cold-tier fault sites ride in on `cfg.faults` / `base.faults`.
pub fn with_stub_stack_full<T, F>(
    workers: usize,
    cfg: CoordinatorConfig,
    qos: Option<QosConfig>,
    base: StubEngine,
    serve: ServeConfig,
    f: F,
) -> crate::Result<T>
where
    T: Send + 'static,
    F: FnOnce(String) -> T + Send + 'static,
{
    let scheduler = Scheduler::start_with_qos(workers, cfg, qos, move |w| Ok(base.fork(w)))?;
    let (tx, rx) = std::sync::mpsc::channel::<Op>();
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stop = crate::server::StopHandle::for_listener(&listener)?;
    let stop_l = stop.clone();
    let accept_thread = std::thread::spawn(move || {
        let _ = crate::server::serve_until_with(listener, tx, stop_l, serve);
    });
    let driver = std::thread::spawn(move || f(addr));
    scheduler.run_until(rx, || driver.is_finished());
    stop.stop();
    let _ = accept_thread.join();
    match driver.join() {
        Ok(v) => Ok(v),
        // Preserve assertion panics from test closures.
        Err(panic) => std::panic::resume_unwind(panic),
    }
}

/// Arrival-process shape of a load run. Everything stays seeded and
/// deterministic — a scenario changes *which* prompts/pauses the per-conn
/// RNG produces, not whether the run is reproducible.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Scenario {
    /// Back-to-back turns on every connection (the original workload).
    #[default]
    Steady,
    /// Bursty arrivals: each connection pauses a few milliseconds between
    /// bursts of turns, so queue depth oscillates instead of saturating.
    Bursty,
    /// Heavy-tailed prompt lengths: most turns use `prompt_len`, ~1 in 8
    /// uses 8× that, so per-turn cost varies by an order of magnitude.
    HeavyTail,
    /// Flash crowd: every connection submits its first turn at the same
    /// instant (barrier-aligned) instead of as threads happen to start.
    FlashCrowd,
    /// One adversarial chatty connection (conn 0) submits 4× the turns of
    /// its well-behaved neighbours, back to back — the workload QoS fair
    /// queuing exists to contain.
    Chatty,
}

impl Scenario {
    pub fn as_str(&self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Bursty => "bursty",
            Scenario::HeavyTail => "heavy-tail",
            Scenario::FlashCrowd => "flash-crowd",
            Scenario::Chatty => "chatty",
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        Some(match s {
            "steady" => Scenario::Steady,
            "bursty" => Scenario::Bursty,
            "heavy-tail" | "heavytail" => Scenario::HeavyTail,
            "flash-crowd" | "flashcrowd" => Scenario::FlashCrowd,
            "chatty" => Scenario::Chatty,
            _ => return None,
        })
    }
}

/// Workload shape.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Concurrent client connections.
    pub conns: usize,
    /// Turns per connection (turn 1 is `generate`, the rest `append`).
    pub turns: usize,
    /// Token budget per turn.
    pub max_new: usize,
    /// Prompt tokens per turn.
    pub prompt_len: usize,
    /// Compression requested for each conversation.
    pub spec: CompressionSpec,
    /// Master seed; each connection derives an independent prompt stream.
    pub seed: u64,
    /// Exclusive upper bound for synthesized prompt token ids.
    pub vocab: i64,
    /// Arrival-process shape (see [`Scenario`]).
    pub scenario: Scenario,
    /// QoS lane every turn is submitted on. `Interactive` (the default)
    /// emits no `priority` field, so default runs produce the exact
    /// pre-QoS wire lines.
    pub priority: Priority,
    /// Shed-aware backoff: max re-submissions per turn after an
    /// `overloaded` rejection that carries a `retry_after_ms` hint.
    /// 0 (the default) is the historical fail-fast behavior; rejections
    /// without a hint (plain FCFS backpressure) are never retried.
    pub max_retries: usize,
    /// Cap on the server-suggested backoff honored per retry, so an
    /// adversarial hint can't park the generator.
    pub retry_backoff_cap: Duration,
}

impl Default for LoadConfig {
    fn default() -> Self {
        Self {
            conns: 8,
            turns: 2,
            max_new: 16,
            prompt_len: 6,
            spec: CompressionSpec::mikv(0.25, "int4"),
            seed: 0x10AD,
            vocab: 32,
            scenario: Scenario::Steady,
            priority: Priority::Interactive,
            max_retries: 0,
            retry_backoff_cap: Duration::from_millis(50),
        }
    }
}

/// One worker's share of the generated load.
#[derive(Debug, Clone)]
pub struct WorkerUtil {
    pub worker: usize,
    pub completed: usize,
    pub generated_tokens: usize,
    /// Fraction of all generated tokens this worker produced.
    pub share: f64,
}

/// Aggregated result of one load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Turns that ended with `done`.
    pub turns_ok: usize,
    /// Turns that ended with a wire `error`.
    pub turns_err: usize,
    /// Tokens streamed across all turns.
    pub tokens: usize,
    /// Wall-clock time from first submit to last terminal event.
    pub wall: Duration,
    /// `tokens / wall`.
    pub tokens_per_sec: f64,
    /// Percentiles over **ok turns only** — a turn that ended in a wire
    /// `error` never contributes here (rejections are near-instant and
    /// used to drag the percentiles down).
    pub ttft_p50: Duration,
    pub ttft_p99: Duration,
    pub latency_p50: Duration,
    pub latency_p99: Duration,
    /// Round-trip percentiles of **error turns** (shed / rate-limit /
    /// other rejections), zero when no turn errored.
    pub rejected_latency_p50: Duration,
    pub rejected_latency_p99: Duration,
    /// Error turns whose wire error carried a `retry_after_ms` hint (QoS
    /// shed and rate-limit rejections always do). Counts **final**
    /// failures only — rejections consumed by the retry ladder land in
    /// `retries` instead.
    pub rejects_with_hint: usize,
    /// Shed-aware re-submissions performed ([`LoadConfig::max_retries`]).
    pub retries: usize,
    /// Turns that failed at least once and then completed `done` within
    /// the retry budget.
    pub retry_success: usize,
    /// p99 ok-turn latency per connection, indexed by connection id
    /// (zero Duration for a connection with no ok turns).
    pub per_conn_latency_p99: Vec<Duration>,
    /// Fairness figure: max/min ratio of per-connection p99 over the
    /// connections that completed at least one ok turn (1.0 when uniform
    /// or fewer than two connections qualify).
    pub conn_p99_spread: f64,
    /// QoS shed/rate-limit rejections THIS run caused (delta of the
    /// trailing `stats` op against the pre-run baseline; all 0 on a
    /// QoS-less stack).
    pub shed_batch: u64,
    pub shed_interactive: u64,
    pub rate_limited: u64,
    /// Per-worker utilization from the trailing `stats` op (empty if the
    /// server predates per-worker rows).
    pub per_worker: Vec<WorkerUtil>,
    /// Server-reported p50 of per-decode-step host input-assembly time
    /// (µs), from the trailing `stats` op (0 when unreported).
    pub assembly_us_p50: f64,
    /// Server-reported p99 of per-decode-step assembly time (µs).
    pub assembly_us_p99: f64,
    /// lo→hi promotions THIS run caused (delta of the trailing `stats`
    /// against the pre-run baseline; 0 unless the workload opted into
    /// `compression.promotion`).
    pub promotions: u64,
    /// Hysteresis-suppressed promotions this run caused (same delta).
    pub thrash_suppressed: u64,
    /// Cold-tier restores THIS run caused (delta of `restore_samples`
    /// against the pre-run baseline; 0 unless the server has a cold tier
    /// and sessions aged out mid-conversation).
    pub restores: u64,
    /// Server-reported p50 of cold-restore latency (µs) from the trailing
    /// `stats` op (0 when unreported or no restore ever happened).
    pub restore_us_p50: f64,
    /// Server-reported p99 of cold-restore latency (µs).
    pub restore_us_p99: f64,
    /// Sessions still spilled on disk after the run (a clean run releases
    /// every session, so nonzero means the workload left cold state).
    pub parked_cold_sessions: usize,
    /// Their on-disk footprint in bytes.
    pub cold_bytes: u64,
    /// Worker panics survived by scheduler supervision THIS run (delta of
    /// the trailing `stats` against the pre-run baseline; 0 on a healthy
    /// run).
    pub worker_restarts: u64,
    /// Cold-spilled sessions adopted by respawned workers this run.
    pub sessions_recovered: u64,
    /// Hot-parked sessions lost to worker crashes this run.
    pub sessions_lost: u64,
    /// `token` events shed by slow-client backpressure this run.
    pub events_dropped: u64,
}

/// Per-connection raw samples. `ttfts`/`latencies` hold ok turns only;
/// error turns land in `rejected` so they can't skew the ok percentiles.
struct ConnResult {
    ttfts: Vec<Duration>,
    latencies: Vec<Duration>,
    rejected: Vec<Duration>,
    tokens: usize,
    ok: usize,
    err: usize,
    rejects_with_hint: usize,
    retries: usize,
    retry_success: usize,
}

/// Client-side aggregation of per-connection samples, separated from the
/// socket work so the ok/error split is unit-testable with pinned values.
struct Folded {
    ttfts: Vec<Duration>,
    latencies: Vec<Duration>,
    rejected: Vec<Duration>,
    per_conn_latency_p99: Vec<Duration>,
    conn_p99_spread: f64,
    tokens: usize,
    ok: usize,
    err: usize,
    rejects_with_hint: usize,
    retries: usize,
    retry_success: usize,
}

fn fold_results(results: Vec<ConnResult>) -> Folded {
    let mut out = Folded {
        ttfts: Vec::new(),
        latencies: Vec::new(),
        rejected: Vec::new(),
        per_conn_latency_p99: Vec::with_capacity(results.len()),
        conn_p99_spread: 1.0,
        tokens: 0,
        ok: 0,
        err: 0,
        rejects_with_hint: 0,
        retries: 0,
        retry_success: 0,
    };
    for mut r in results {
        r.latencies.sort_unstable();
        out.per_conn_latency_p99.push(if r.latencies.is_empty() {
            Duration::ZERO
        } else {
            percentile(&r.latencies, 0.99)
        });
        out.ttfts.extend(r.ttfts);
        out.latencies.extend(r.latencies);
        out.rejected.extend(r.rejected);
        out.tokens += r.tokens;
        out.ok += r.ok;
        out.err += r.err;
        out.rejects_with_hint += r.rejects_with_hint;
        out.retries += r.retries;
        out.retry_success += r.retry_success;
    }
    out.ttfts.sort_unstable();
    out.latencies.sort_unstable();
    out.rejected.sort_unstable();
    // Spread over connections that completed at least one ok turn: the
    // figure the fairness suite bounds (one chatty connection must not
    // inflate its neighbours' p99 past its deficit share).
    let qualifying: Vec<f64> = out
        .per_conn_latency_p99
        .iter()
        .filter(|d| !d.is_zero())
        .map(Duration::as_secs_f64)
        .collect();
    if qualifying.len() >= 2 {
        let max = qualifying.iter().cloned().fold(f64::MIN, f64::max);
        let min = qualifying.iter().cloned().fold(f64::MAX, f64::min);
        out.conn_p99_spread = if min > 0.0 { max / min } else { f64::INFINITY };
    }
    out
}

/// Run the workload against a serving endpoint and aggregate the report.
pub fn run_load(addr: &str, cfg: &LoadConfig) -> crate::Result<LoadReport> {
    anyhow::ensure!(cfg.conns >= 1 && cfg.turns >= 1, "empty load config");
    // Per-worker counters are server-lifetime cumulative; snapshot before
    // the run so the report attributes only THIS run's tokens (matters
    // when targeting a long-running `--addr` server).
    let baseline = stats_probe(addr);
    let started = Instant::now();
    // Flash crowd: align every connection's first submit on a barrier so
    // the admission path sees `conns` simultaneous arrivals.
    let barrier = (cfg.scenario == Scenario::FlashCrowd)
        .then(|| Arc::new(Barrier::new(cfg.conns)));
    let mut handles = Vec::with_capacity(cfg.conns);
    for conn in 0..cfg.conns {
        let addr = addr.to_string();
        let cfg = cfg.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            drive_conn(&addr, &cfg, conn, barrier)
        }));
    }
    let mut results = Vec::with_capacity(cfg.conns);
    for handle in handles {
        results.push(
            handle
                .join()
                .map_err(|_| anyhow::anyhow!("load connection panicked"))??,
        );
    }
    let wall = started.elapsed();
    let folded = fold_results(results);

    // Trailing stats op: per-worker utilization (as the delta against the
    // pre-run baseline) plus the server's assembly_us percentiles and QoS
    // shed counters. Decoration only — any failure (server gone, old
    // server without the fields) degrades to empty/zero instead of
    // discarding the measured run.
    let after = stats_probe(addr);
    let per_worker = worker_utilization(&baseline.counters, &after.counters);

    Ok(LoadReport {
        turns_ok: folded.ok,
        turns_err: folded.err,
        tokens: folded.tokens,
        wall,
        tokens_per_sec: folded.tokens as f64 / wall.as_secs_f64().max(1e-9),
        ttft_p50: percentile(&folded.ttfts, 0.5),
        ttft_p99: percentile(&folded.ttfts, 0.99),
        latency_p50: percentile(&folded.latencies, 0.5),
        latency_p99: percentile(&folded.latencies, 0.99),
        rejected_latency_p50: percentile(&folded.rejected, 0.5),
        rejected_latency_p99: percentile(&folded.rejected, 0.99),
        rejects_with_hint: folded.rejects_with_hint,
        retries: folded.retries,
        retry_success: folded.retry_success,
        per_conn_latency_p99: folded.per_conn_latency_p99,
        conn_p99_spread: folded.conn_p99_spread,
        shed_batch: after.shed_batch.saturating_sub(baseline.shed_batch),
        shed_interactive: after
            .shed_interactive
            .saturating_sub(baseline.shed_interactive),
        rate_limited: after.rate_limited.saturating_sub(baseline.rate_limited),
        per_worker,
        assembly_us_p50: after.assembly_us_p50,
        assembly_us_p99: after.assembly_us_p99,
        promotions: after.promotions.saturating_sub(baseline.promotions),
        thrash_suppressed: after
            .thrash_suppressed
            .saturating_sub(baseline.thrash_suppressed),
        restores: after.restore_samples.saturating_sub(baseline.restore_samples),
        restore_us_p50: after.restore_us_p50,
        restore_us_p99: after.restore_us_p99,
        parked_cold_sessions: after.parked_cold_sessions,
        cold_bytes: after.cold_bytes,
        worker_restarts: after
            .worker_restarts
            .saturating_sub(baseline.worker_restarts),
        sessions_recovered: after
            .sessions_recovered
            .saturating_sub(baseline.sessions_recovered),
        sessions_lost: after.sessions_lost.saturating_sub(baseline.sessions_lost),
        events_dropped: after
            .events_dropped
            .saturating_sub(baseline.events_dropped),
    })
}

/// One best-effort `stats` round trip: cumulative per-worker counters
/// (`worker → (completed, generated_tokens)`) plus the merged assembly
/// percentiles. Empty/zero on any failure.
#[derive(Default)]
struct StatsProbe {
    counters: std::collections::HashMap<usize, (usize, usize)>,
    assembly_us_p50: f64,
    assembly_us_p99: f64,
    promotions: u64,
    thrash_suppressed: u64,
    restore_samples: u64,
    restore_us_p50: f64,
    restore_us_p99: f64,
    parked_cold_sessions: usize,
    cold_bytes: u64,
    shed_batch: u64,
    shed_interactive: u64,
    rate_limited: u64,
    worker_restarts: u64,
    sessions_recovered: u64,
    sessions_lost: u64,
    events_dropped: u64,
}

fn stats_probe(addr: &str) -> StatsProbe {
    let mut out = StatsProbe::default();
    let mut client = match Client::connect(addr) {
        Ok(c) => c,
        Err(_) => return out,
    };
    let id = client.next_id();
    if client.submit(&RequestBuilder::stats(id)).is_err() {
        return out;
    }
    let stats = match client.read_turn(id) {
        Ok((_, v)) => v,
        Err(_) => return out,
    };
    out.assembly_us_p50 = stats.field_f64("assembly_us_p50").unwrap_or(0.0);
    out.assembly_us_p99 = stats.field_f64("assembly_us_p99").unwrap_or(0.0);
    out.promotions = stats.field_i64("promotions").unwrap_or(0).max(0) as u64;
    out.thrash_suppressed = stats
        .field_i64("thrash_suppressed")
        .unwrap_or(0)
        .max(0) as u64;
    out.restore_samples = stats.field_i64("restore_samples").unwrap_or(0).max(0) as u64;
    out.restore_us_p50 = stats.field_f64("restore_us_p50").unwrap_or(0.0);
    out.restore_us_p99 = stats.field_f64("restore_us_p99").unwrap_or(0.0);
    out.parked_cold_sessions = stats
        .field_i64("parked_cold_sessions")
        .unwrap_or(0)
        .max(0) as usize;
    out.cold_bytes = stats.field_i64("cold_bytes").unwrap_or(0).max(0) as u64;
    out.shed_batch = stats.field_i64("shed_batch").unwrap_or(0).max(0) as u64;
    out.shed_interactive = stats
        .field_i64("shed_interactive")
        .unwrap_or(0)
        .max(0) as u64;
    out.rate_limited = stats.field_i64("rate_limited").unwrap_or(0).max(0) as u64;
    out.worker_restarts = stats.field_i64("worker_restarts").unwrap_or(0).max(0) as u64;
    out.sessions_recovered = stats
        .field_i64("sessions_recovered")
        .unwrap_or(0)
        .max(0) as u64;
    out.sessions_lost = stats.field_i64("sessions_lost").unwrap_or(0).max(0) as u64;
    out.events_dropped = stats.field_i64("events_dropped").unwrap_or(0).max(0) as u64;
    if let Ok(rows) = stats.field_arr("workers") {
        for row in rows {
            out.counters.insert(
                row.field_i64("worker").unwrap_or(0).max(0) as usize,
                (
                    row.field_i64("completed").unwrap_or(0).max(0) as usize,
                    row.field_i64("generated_tokens").unwrap_or(0).max(0) as usize,
                ),
            );
        }
    }
    out
}

/// Per-worker utilization as the delta of `after` against the pre-run
/// `baseline` counters.
fn worker_utilization(
    baseline: &std::collections::HashMap<usize, (usize, usize)>,
    after: &std::collections::HashMap<usize, (usize, usize)>,
) -> Vec<WorkerUtil> {
    let mut rows: Vec<(usize, usize, usize)> = after
        .iter()
        .map(|(&worker, &(completed, generated))| {
            let (c0, g0) = baseline.get(&worker).copied().unwrap_or((0, 0));
            (
                worker,
                completed.saturating_sub(c0),
                generated.saturating_sub(g0),
            )
        })
        .collect();
    rows.sort_unstable_by_key(|(worker, ..)| *worker);
    let total: usize = rows.iter().map(|(.., generated)| *generated).sum();
    rows.into_iter()
        .map(|(worker, completed, generated)| WorkerUtil {
            worker,
            completed,
            generated_tokens: generated,
            share: if total > 0 {
                generated as f64 / total as f64
            } else {
                0.0
            },
        })
        .collect()
}

/// Release a session a failed turn left parked: one no-keep 1-token turn
/// consumes the cache. Any error on the release turn (typically
/// `session_not_found` — the server already dropped it) means the session
/// is gone either way, so only transport failures propagate.
fn release_session(client: &mut Client, sid: u64) -> crate::Result<()> {
    let id = client.next_id();
    let line = RequestBuilder::append(id, sid)
        .prompt(&[1])
        .max_new(1)
        .keep(false)
        .build();
    client.send_line(&line)?;
    let _ = client.read_turn(id)?;
    Ok(())
}

/// One connection's conversation loop.
fn drive_conn(
    addr: &str,
    cfg: &LoadConfig,
    conn: usize,
    barrier: Option<Arc<Barrier>>,
) -> crate::Result<ConnResult> {
    let mut client = Client::connect(addr)?;
    let mut rng = Pcg32::new(cfg.seed ^ ((conn as u64 + 1) << 20));
    let mut session: Option<u64> = None;
    let mut out = ConnResult {
        ttfts: Vec::new(),
        latencies: Vec::new(),
        rejected: Vec::new(),
        tokens: 0,
        ok: 0,
        err: 0,
        rejects_with_hint: 0,
        retries: 0,
        retry_success: 0,
    };
    let vocab = cfg.vocab.max(2);
    let turns = if cfg.scenario == Scenario::Chatty && conn == 0 {
        cfg.turns * 4
    } else {
        cfg.turns
    };
    if let Some(b) = &barrier {
        b.wait();
    }
    for turn in 0..turns {
        if cfg.scenario == Scenario::Bursty && turn > 0 && turn % 2 == 0 {
            std::thread::sleep(Duration::from_millis(1 + rng.gen_below(4) as u64));
        }
        // The final turn drops `keep`, so a completed conversation leaves
        // nothing parked (no session leak from a finished load run).
        let keep = turn + 1 < turns;
        let prompt_len = if cfg.scenario == Scenario::HeavyTail && rng.gen_bool(0.125) {
            cfg.prompt_len.max(1) * 8
        } else {
            cfg.prompt_len.max(1)
        };
        let prompt: Vec<i64> = (0..prompt_len)
            .map(|_| rng.gen_range(1, vocab - 1))
            .collect();
        // Turn timing spans the whole retry ladder: a turn that was shed
        // twice and then completed reports the latency the caller saw,
        // backoff included.
        let t0 = Instant::now();
        let mut attempts_left = cfg.max_retries;
        let mut turn_retried = false;
        let mut first: Option<Duration> = None;
        let mut turn_ok = false;
        'attempt: loop {
            let id = client.next_id();
            let mut builder = match session {
                Some(sid) => RequestBuilder::append(id, sid)
                    .prompt(&prompt)
                    .max_new(cfg.max_new)
                    .keep(keep),
                None => RequestBuilder::generate(id)
                    .prompt(&prompt)
                    .max_new(cfg.max_new)
                    .keep(keep)
                    .compression(cfg.spec.clone()),
            };
            if cfg.priority != Priority::Interactive {
                builder = builder.priority(cfg.priority);
            }
            client.submit(&builder)?;
            loop {
                let v = client.recv()?;
                if v.field("id").ok().and_then(Json::as_i64) != Some(id as i64) {
                    continue; // stale line from an earlier turn
                }
                match v.field_str("event").unwrap_or("") {
                    "token" => {
                        if first.is_none() {
                            first = Some(t0.elapsed());
                        }
                        out.tokens += 1;
                    }
                    "done" => {
                        out.ok += 1;
                        turn_ok = true;
                        if turn_retried {
                            out.retry_success += 1;
                        }
                        session = v
                            .field("session")
                            .ok()
                            .and_then(Json::as_i64)
                            .map(|s| s as u64);
                        break 'attempt;
                    }
                    "error" => {
                        let hint = v.field("retry_after_ms").ok().and_then(Json::as_i64);
                        // Shed-aware backoff: an `overloaded` rejection
                        // carrying a retry hint is a promise that capacity
                        // frees up — honor it (capped) and re-submit the
                        // same turn. Admission sheds happen before any
                        // session state is touched, so the retry reuses
                        // the session id as-is. Hint-less rejections
                        // (plain FCFS backpressure) stay fail-fast.
                        if attempts_left > 0
                            && v.field_str("code").unwrap_or("") == "overloaded"
                        {
                            if let Some(ms) = hint {
                                attempts_left -= 1;
                                turn_retried = true;
                                out.retries += 1;
                                std::thread::sleep(
                                    Duration::from_millis(ms.max(0) as u64)
                                        .min(cfg.retry_backoff_cap),
                                );
                                continue 'attempt;
                            }
                        }
                        out.err += 1;
                        if hint.is_some() {
                            out.rejects_with_hint += 1;
                        }
                        break 'attempt;
                    }
                    other => anyhow::bail!("unexpected event '{other}' for turn {id}: {v}"),
                }
            }
        }
        let elapsed = t0.elapsed();
        if turn_ok {
            out.latencies.push(elapsed);
            out.ttfts.push(first.unwrap_or(elapsed));
        } else {
            // Error turns are sampled separately: rejections are
            // near-instant and would otherwise drag the ok percentiles
            // down (and a tokenless error used to be counted as a TTFT).
            out.rejected.push(elapsed);
            // A failed turn leaves the previous turn's session parked
            // (this append never consumed it) — release it instead of
            // orphaning it until TTL eviction.
            if let Some(sid) = session.take() {
                release_session(&mut client, sid)?;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    fn conn(
        ttfts: &[u64],
        latencies: &[u64],
        rejected: &[u64],
        hints: usize,
    ) -> ConnResult {
        ConnResult {
            ttfts: ttfts.iter().copied().map(ms).collect(),
            latencies: latencies.iter().copied().map(ms).collect(),
            rejected: rejected.iter().copied().map(ms).collect(),
            tokens: latencies.len() * 2,
            ok: latencies.len(),
            err: rejected.len(),
            rejects_with_hint: hints,
            retries: 0,
            retry_success: 0,
        }
    }

    /// Retry counters fold across connections; a retried-then-ok turn
    /// counts toward `ok`/`retry_success` and not toward `err`.
    #[test]
    fn retry_counters_fold_across_conns() {
        let mut a = conn(&[2], &[20], &[], 0);
        a.retries = 2;
        a.retry_success = 1;
        let mut b = conn(&[3], &[12], &[500], 1);
        b.retries = 1;
        let folded = fold_results(vec![a, b]);
        assert_eq!(folded.retries, 3);
        assert_eq!(folded.retry_success, 1);
        assert_eq!(folded.ok, 2);
        assert_eq!(folded.err, 1);
    }

    /// Pinned values for the metric-skew fix: error turns contribute to
    /// `rejected` percentiles only, never to the ok-turn ttft/latency
    /// samples (pre-fix, a 500ms timeout-then-error turn dragged both).
    #[test]
    fn error_turns_do_not_skew_ok_percentiles() {
        let folded = fold_results(vec![
            conn(&[2], &[20], &[], 0),
            conn(&[3], &[12], &[500], 1),
        ]);
        assert_eq!(folded.ok, 2);
        assert_eq!(folded.err, 1);
        assert_eq!(folded.rejects_with_hint, 1);
        assert_eq!(folded.tokens, 4);
        // ok samples are blind to the 500ms rejection...
        assert_eq!(folded.latencies, vec![ms(12), ms(20)]);
        assert_eq!(folded.ttfts, vec![ms(2), ms(3)]);
        // ...which lands in the rejected track instead
        assert_eq!(folded.rejected, vec![ms(500)]);
        assert_eq!(percentile(&folded.rejected, 0.5), ms(500));
        // per-conn p99 over ok turns only: 20ms vs 12ms
        assert_eq!(folded.per_conn_latency_p99, vec![ms(20), ms(12)]);
        assert!((folded.conn_p99_spread - 20.0 / 12.0).abs() < 1e-9);
    }

    /// A connection with zero ok turns reports a zero p99 and is excluded
    /// from the spread instead of forcing it to infinity.
    #[test]
    fn all_rejected_conn_is_excluded_from_spread() {
        let folded = fold_results(vec![
            conn(&[1], &[10], &[], 0),
            conn(&[], &[], &[5, 6], 2),
        ]);
        assert_eq!(folded.per_conn_latency_p99, vec![ms(10), Duration::ZERO]);
        assert_eq!(folded.conn_p99_spread, 1.0);
        assert_eq!(folded.rejects_with_hint, 2);
    }

    #[test]
    fn scenario_names_roundtrip() {
        for s in [
            Scenario::Steady,
            Scenario::Bursty,
            Scenario::HeavyTail,
            Scenario::FlashCrowd,
            Scenario::Chatty,
        ] {
            assert_eq!(Scenario::parse(s.as_str()), Some(s));
        }
        assert_eq!(Scenario::parse("warp"), None);
    }
}
