//! Wire protocol: versioned JSON-lines envelope with streaming events.
//!
//! # Serving API v1
//!
//! Every request is one JSON object per line carrying `"v": 1` and an
//! `"op"`. Parsing is **policy-free**: compression arrives as a plain-data
//! [`CompressionSpec`] and is validated/resolved against the model only at
//! coordinator admission.
//!
//! ```json
//! {"v":1,"op":"generate","id":1,"prompt":[1,17,230],"max_new":8,
//!  "stop":6,"keep":true,"priority":"interactive",
//!  "compression":{"mode":"mikv","ratio":0.25,"lo":"int2","group":16,
//!                 "policy":"h2o","promotion":true}}
//! {"v":1,"op":"append","id":2,"session":7,"prompt":[4,5],"max_new":8}
//! {"v":1,"op":"cancel","id":3,"target":1}
//! {"v":1,"op":"stats","id":4}
//! ```
//!
//! * `generate` — start a turn. `compression.mode` ∈ `full` | `oracle`
//!   (+`k`) | `mikv` (+`ratio`, `lo`, `group`, `policy`, and the opt-in
//!   boolean `promotion` enabling the lo→hi promotion pass) | `h2o`
//!   (+`ratio`) | `rtn` (+`lo`). With `"keep":true` the session's cache
//!   stays checked out after `done` under the returned `session` id. The
//!   optional boolean `spill` (any mode, default true) controls whether a
//!   kept session may later spill to the on-disk cold tier when it is
//!   evicted from the parked registry; `false` drops it instead so its KV
//!   state never touches disk. The optional string `priority` ∈
//!   `interactive` (default) | `batch` picks the QoS lane on a sharded
//!   deployment with QoS enabled: the batch lane is served only when the
//!   interactive lane is empty and is shed first under pressure. Any other
//!   value (or a non-string) is a `bad_request`; without QoS the field
//!   parses but has no scheduling effect.
//! * `append` — continue a kept session: the new prompt tokens re-ingest
//!   into the same hi/lo tiers (`keep` defaults to true here). Session ids
//!   are coordinator-global and carry no capability token: any connection
//!   to the server may continue (or consume) a kept session, so the
//!   listener must sit behind a trusted boundary (it binds 127.0.0.1).
//! * `cancel` — cancel an in-flight request by its `id` (same connection).
//! * `stats` — pool/footprint/throughput counters.
//!
//! Responses are **events**, one JSON object per line, ordered per
//! connection. A submit op streams `token` events and ends with exactly
//! one terminal `done` or `error`:
//!
//! ```json
//! {"event":"token","id":1,"i":0,"t":230}
//! {"event":"done","id":1,"tokens":[230,231],"session":7,
//!  "cancelled":false,"ttft_ms":12.3,"latency_ms":40.1,
//!  "prompt_tokens":3,"generated_tokens":2,"cache_pct":33.2,
//!  "host_bytes":43008,"hi_slots":12,"lo_slots":36,
//!  "promotions":0,"thrash_suppressed":0}
//! {"event":"error","id":1,"code":"bad_request","message":"..."}
//! {"event":"stats","id":4,"active":1,"waiting":0,...}
//! {"event":"cancelled","id":3,"target":1,"found":true}
//! ```
//!
//! Error `code`s are the stable [`crate::coordinator::ErrorCode`] set:
//! `bad_request`, `overloaded`, `session_not_found`, `session_busy`,
//! `cache_full`, `internal`. `overloaded` rejections from the QoS
//! admission layer (shedding, rate limiting) additionally carry an integer
//! `retry_after_ms` backoff hint; every other error omits the field, so
//! pre-QoS error lines are byte-identical.
//!
//! # Legacy one-shot shape
//!
//! A line **without** `"v"` is the pre-v1 flat request
//! (`{"id":1,"prompt":[...],"max_new":4,"mode":"mikv","ratio":0.25,
//! "lo":"int2"}`) and is answered with the pre-v1 single response line —
//! no events:
//!
//! ```json
//! {"id":1,"tokens":[230,231],"ttft_ms":12.3,"latency_ms":40.1,
//!  "prompt_tokens":3,"generated_tokens":2,"cache_pct":33.2,
//!  "host_bytes":43008,"error":null}
//! ```
//!
//! Prompt tokens must be integers in both shapes; a non-integer element is
//! rejected with `bad_request` (it is never silently coerced).

use crate::coordinator::{CompressionSpec, Priority, Response, ServeEvent, WireError};
use crate::util::json::{Json, JsonObj};

// ----------------------------------------------------------------------
// Decoded requests
// ----------------------------------------------------------------------

/// A parsed submit-style request (`generate` or `append`), pre-resolution.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub id: u64,
    pub prompt: Vec<i64>,
    pub max_new: usize,
    pub stop: Option<i64>,
    pub spec: CompressionSpec,
    /// `Some(sid)` for `append` (continue a kept session).
    pub session: Option<u64>,
    pub keep: bool,
    /// QoS lane (`"priority"` in the v1 envelope; legacy lines are always
    /// interactive). Plain data here — only a QoS-enabled scheduler acts
    /// on it.
    pub priority: Priority,
    /// Parsed from the legacy v-less one-shot shape: the reply is a single
    /// response line, no events.
    pub legacy: bool,
}

/// One decoded wire operation.
#[derive(Debug, Clone, PartialEq)]
pub enum WireOp {
    Submit(WireRequest),
    Cancel { id: u64, target: u64 },
    Stats { id: u64 },
}

/// A request line that failed to decode: the structured error to send
/// back, plus how to encode it.
#[derive(Debug, Clone)]
pub struct DecodeError {
    /// Request id when recoverable from the line (0 otherwise).
    pub id: u64,
    /// The line was (or had to be assumed) legacy-shaped, so the error
    /// reply must use the legacy single-line encoding.
    pub legacy: bool,
    pub err: WireError,
}

/// Decode one request line into a [`WireOp`].
pub fn decode_line(line: &str) -> Result<WireOp, DecodeError> {
    let v = Json::parse(line).map_err(|e| DecodeError {
        id: 0,
        legacy: true,
        err: WireError::bad_request(format!("bad json: {e}")),
    })?;
    let id_field = v.field("id").ok().and_then(Json::as_i64);
    let id = id_field.unwrap_or(0).max(0) as u64;
    let versioned = v.field("v").is_ok();
    let legacy = !versioned;
    let fail = move |err: WireError| DecodeError { id, legacy, err };
    match id_field {
        Some(n) if n >= 0 => {}
        _ => {
            return Err(fail(WireError::bad_request(
                "'id' must be a non-negative integer",
            )))
        }
    }

    if !versioned {
        // Legacy flat one-shot generate.
        let prompt = parse_prompt(&v).map_err(&fail)?;
        let max_new = v.field_i64("max_new").unwrap_or(8).max(0) as usize;
        let stop = v.field("stop").ok().and_then(Json::as_i64);
        return Ok(WireOp::Submit(WireRequest {
            id,
            prompt,
            max_new,
            stop,
            spec: legacy_spec(&v),
            session: None,
            keep: false,
            priority: Priority::Interactive,
            legacy: true,
        }));
    }

    let ver = v
        .field("v")
        .ok()
        .and_then(Json::as_i64)
        .ok_or_else(|| fail(WireError::bad_request("'v' must be an integer")))?;
    if ver != 1 {
        return Err(fail(WireError::bad_request(format!(
            "unsupported protocol version {ver}"
        ))));
    }
    let op = v
        .field_str("op")
        .map_err(|_| fail(WireError::bad_request("missing string 'op'")))?;
    match op {
        "generate" | "append" => {
            let session = if op == "append" {
                let sid = v
                    .field("session")
                    .ok()
                    .and_then(Json::as_i64)
                    .filter(|s| *s >= 0)
                    .ok_or_else(|| {
                        fail(WireError::bad_request(
                            "append requires a non-negative integer 'session'",
                        ))
                    })?;
                Some(sid as u64)
            } else {
                None
            };
            let prompt = parse_prompt(&v).map_err(&fail)?;
            // v1 is strictly typed end to end: a present field of the wrong
            // type is a bad_request, never a silent default (the legacy
            // shape below stays lenient for compatibility).
            let max_new = match v.field("max_new") {
                Ok(j) => j.as_i64().filter(|n| *n >= 0).ok_or_else(|| {
                    fail(WireError::bad_request(
                        "'max_new' must be a non-negative integer",
                    ))
                })? as usize,
                Err(_) => 8,
            };
            let stop = match v.field("stop") {
                Ok(j) => Some(j.as_i64().ok_or_else(|| {
                    fail(WireError::bad_request("'stop' must be an integer"))
                })?),
                Err(_) => None,
            };
            let keep = match v.field("keep") {
                Ok(j) => j.as_bool().ok_or_else(|| {
                    fail(WireError::bad_request("'keep' must be a boolean"))
                })?,
                Err(_) => op == "append",
            };
            let priority = match v.field("priority") {
                Ok(j) => {
                    let s = j.as_str().ok_or_else(|| {
                        fail(WireError::bad_request("'priority' must be a string"))
                    })?;
                    Priority::parse(s).ok_or_else(|| {
                        fail(WireError::bad_request(format!(
                            "unknown priority '{s}' (expected 'interactive' or 'batch')"
                        )))
                    })?
                }
                Err(_) => Priority::Interactive,
            };
            let spec = match v.field("compression") {
                Ok(c) => spec_from_json(c).map_err(&fail)?,
                Err(_) => CompressionSpec::full(),
            };
            Ok(WireOp::Submit(WireRequest {
                id,
                prompt,
                max_new,
                stop,
                spec,
                session,
                keep,
                priority,
                legacy: false,
            }))
        }
        "cancel" => {
            let target = v
                .field("target")
                .ok()
                .and_then(Json::as_i64)
                .filter(|t| *t >= 0)
                .ok_or_else(|| {
                    fail(WireError::bad_request(
                        "cancel requires a non-negative integer 'target'",
                    ))
                })?;
            Ok(WireOp::Cancel {
                id,
                target: target as u64,
            })
        }
        "stats" => Ok(WireOp::Stats { id }),
        other => Err(fail(WireError::bad_request(format!("unknown op '{other}'")))),
    }
}

/// Strict prompt parsing: every element must be an integer token id — a
/// non-integer is a `bad_request`, never silently coerced to 0.
fn parse_prompt(v: &Json) -> Result<Vec<i64>, WireError> {
    let arr = v
        .field_arr("prompt")
        .map_err(|_| WireError::bad_request("missing 'prompt' array"))?;
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        match t.as_i64() {
            Some(tok) => prompt.push(tok),
            None => {
                return Err(WireError::bad_request(format!(
                    "prompt[{i}] is not an integer token id"
                )))
            }
        }
    }
    if prompt.is_empty() {
        return Err(WireError::bad_request("empty prompt"));
    }
    Ok(prompt)
}

/// Compression fields of the legacy flat shape (`mode`/`ratio`/`lo`/...
/// inline at the top level). Unknown values fail later, at resolution.
fn legacy_spec(v: &Json) -> CompressionSpec {
    CompressionSpec {
        mode: v.field_str("mode").unwrap_or("full").to_string(),
        ratio: v.field("ratio").ok().and_then(Json::as_f64),
        lo: v
            .field_str("lo")
            .or_else(|_| v.field_str("prec"))
            .ok()
            .map(str::to_string),
        group: v
            .field("group")
            .ok()
            .and_then(Json::as_i64)
            .map(|g| g.max(0) as usize),
        policy: v.field_str("policy").ok().map(str::to_string),
        k: v
            .field("k")
            .ok()
            .and_then(Json::as_i64)
            .map(|k| k.max(0) as usize),
        promotion: v.field("promotion").ok().and_then(Json::as_bool),
        spill: v.field("spill").ok().and_then(Json::as_bool),
    }
}

/// Parse a v1 `"compression"` object into a [`CompressionSpec`].
fn spec_from_json(c: &Json) -> Result<CompressionSpec, WireError> {
    if c.as_obj().is_none() {
        return Err(WireError::bad_request("'compression' must be an object"));
    }
    let str_field = |name: &str| -> Result<Option<String>, WireError> {
        match c.field(name) {
            Ok(j) => j
                .as_str()
                .map(|s| Some(s.to_string()))
                .ok_or_else(|| {
                    WireError::bad_request(format!("compression.{name} must be a string"))
                }),
            Err(_) => Ok(None),
        }
    };
    let uint_field = |name: &str| -> Result<Option<usize>, WireError> {
        match c.field(name) {
            Ok(j) => j
                .as_i64()
                .filter(|n| *n >= 0)
                .map(|n| Some(n as usize))
                .ok_or_else(|| {
                    WireError::bad_request(format!(
                        "compression.{name} must be a non-negative integer"
                    ))
                }),
            Err(_) => Ok(None),
        }
    };
    let ratio = match c.field("ratio") {
        Ok(j) => Some(j.as_f64().ok_or_else(|| {
            WireError::bad_request("compression.ratio must be a number")
        })?),
        Err(_) => None,
    };
    let promotion = match c.field("promotion") {
        Ok(j) => Some(j.as_bool().ok_or_else(|| {
            WireError::bad_request("compression.promotion must be a boolean")
        })?),
        Err(_) => None,
    };
    let spill = match c.field("spill") {
        Ok(j) => Some(j.as_bool().ok_or_else(|| {
            WireError::bad_request("compression.spill must be a boolean")
        })?),
        Err(_) => None,
    };
    Ok(CompressionSpec {
        mode: str_field("mode")?.unwrap_or_else(|| "full".to_string()),
        ratio,
        lo: match str_field("lo")? {
            Some(lo) => Some(lo),
            None => str_field("prec")?,
        },
        group: uint_field("group")?,
        policy: str_field("policy")?,
        k: uint_field("k")?,
        promotion,
        spill,
    })
}

// ----------------------------------------------------------------------
// Event encoding
// ----------------------------------------------------------------------

/// Emit a spec's set fields into `o` — shared by the nested v1
/// `"compression"` object and the flattened legacy shape, so the two
/// encodings can't drift apart field-by-field.
fn spec_fields_into(o: &mut JsonObj, spec: &CompressionSpec) {
    o.set("mode", spec.mode.as_str());
    if let Some(r) = spec.ratio {
        o.set("ratio", r);
    }
    if let Some(lo) = &spec.lo {
        o.set("lo", lo.as_str());
    }
    if let Some(g) = spec.group {
        o.set("group", g);
    }
    if let Some(p) = &spec.policy {
        o.set("policy", p.as_str());
    }
    if let Some(k) = spec.k {
        o.set("k", k);
    }
    if let Some(p) = spec.promotion {
        o.set("promotion", p);
    }
    if let Some(s) = spec.spill {
        o.set("spill", s);
    }
}

fn spec_to_json(spec: &CompressionSpec) -> Json {
    let mut o = JsonObj::new();
    spec_fields_into(&mut o, spec);
    Json::Obj(o)
}

fn tokens_json(tokens: &[i64]) -> Json {
    Json::Arr(tokens.iter().map(|&t| Json::Int(t)).collect())
}

/// Encode one v1 event as a JSON line (no trailing newline).
pub fn encode_event(ev: &ServeEvent) -> String {
    let mut o = JsonObj::new();
    match ev {
        ServeEvent::Token { id, index, token } => {
            o.set("event", "token");
            o.set("id", *id as i64);
            o.set("i", *index);
            o.set("t", *token);
        }
        ServeEvent::Done(r) => match &r.error {
            Some(e) => {
                o.set("event", "error");
                o.set("id", r.id as i64);
                o.set("code", e.code.as_str());
                o.set("message", e.message.as_str());
                // Only QoS shed / rate-limit rejections carry a backoff
                // hint; omitting it otherwise keeps pre-QoS error lines
                // byte-identical.
                if let Some(ms) = e.retry_after_ms {
                    o.set("retry_after_ms", ms as i64);
                }
            }
            None => {
                o.set("event", "done");
                o.set("id", r.id as i64);
                o.set("tokens", tokens_json(&r.tokens));
                if let Some(sid) = r.session {
                    o.set("session", sid as i64);
                }
                o.set("cancelled", r.cancelled);
                o.set("ttft_ms", r.metrics.ttft.as_secs_f64() * 1e3);
                o.set("latency_ms", r.metrics.latency.as_secs_f64() * 1e3);
                o.set("prompt_tokens", r.metrics.prompt_tokens);
                o.set("generated_tokens", r.metrics.generated_tokens);
                o.set("cache_pct", r.metrics.cache_pct);
                o.set("host_bytes", r.metrics.host_bytes);
                o.set("hi_slots", r.metrics.hi_slots as i64);
                o.set("lo_slots", r.metrics.lo_slots as i64);
                o.set("promotions", r.metrics.promotions as i64);
                o.set("thrash_suppressed", r.metrics.thrash_suppressed as i64);
            }
        },
        ServeEvent::Stats { id, snapshot } => {
            o.set("event", "stats");
            o.set("id", *id as i64);
            o.set("active", snapshot.active);
            o.set("waiting", snapshot.waiting);
            // Admission-side gauges, injected by the scheduler at fanout
            // fold time (all 0 from a bare single-worker Coordinator).
            o.set("admitted_in_flight", snapshot.admitted_in_flight);
            o.set("qos_queued", snapshot.qos_queued);
            o.set("shed_batch", snapshot.shed_batch as i64);
            o.set("shed_interactive", snapshot.shed_interactive as i64);
            o.set("rate_limited", snapshot.rate_limited as i64);
            // Fault-domain counters: worker panics survived (scheduler
            // supervision), parked sessions recovered from / lost to a
            // crash, and token events shed by slow-client backpressure
            // (folded in by the TCP front-end at encode time).
            o.set("worker_restarts", snapshot.worker_restarts as i64);
            o.set("sessions_recovered", snapshot.sessions_recovered as i64);
            o.set("sessions_lost", snapshot.sessions_lost as i64);
            o.set("events_dropped", snapshot.events_dropped as i64);
            o.set("parked_sessions", snapshot.parked_sessions);
            o.set("parked_bytes", snapshot.parked_bytes);
            // Cold tier: sessions spilled to disk, their on-disk footprint,
            // and capacity evictions (each one a lost session context).
            o.set("parked_cold_sessions", snapshot.parked_cold_sessions);
            o.set("cold_bytes", snapshot.cold_bytes as i64);
            o.set("cold_evictions", snapshot.cold_evictions as i64);
            o.set("completed", snapshot.completed);
            o.set("generated_tokens", snapshot.generated_tokens);
            o.set("throughput_tps", snapshot.throughput_tps);
            o.set("mean_host_bytes", snapshot.mean_host_bytes);
            o.set("peak_host_bytes", snapshot.peak_host_bytes);
            // Decode-step host assembly percentiles (µs) — the time the
            // delta-aware arena spends building batch inputs per step.
            o.set("assembly_us_p50", snapshot.assembly_us_p50);
            o.set("assembly_us_p99", snapshot.assembly_us_p99);
            o.set("assembly_samples", snapshot.assembly_samples as i64);
            // Cold-restore latency percentiles (µs) — time to decode a
            // spilled session's snapshot back into a pooled cache on
            // `append`.
            o.set("restore_us_p50", snapshot.restore_us_p50);
            o.set("restore_us_p99", snapshot.restore_us_p99);
            o.set("restore_samples", snapshot.restore_samples as i64);
            // Tier-lifecycle counters (the lo→hi promotion pass; 0 unless
            // sessions opted into `compression.promotion`).
            o.set("promotions", snapshot.promotions as i64);
            o.set("thrash_suppressed", snapshot.thrash_suppressed as i64);
            o.set("pool_free_blocks", snapshot.pool.free_blocks);
            o.set("pool_free_bytes", snapshot.pool.free_bytes);
            o.set("pool_outstanding_blocks", snapshot.pool.outstanding_blocks);
            o.set("pool_outstanding_bytes", snapshot.pool.outstanding_bytes);
            o.set("pool_hits", snapshot.pool.hits as i64);
            o.set("pool_misses", snapshot.pool.misses as i64);
            // Per-worker rows of the sharded runtime (one row with
            // worker = 0 on a single-worker deployment).
            let workers: Vec<Json> = snapshot
                .workers
                .iter()
                .map(|w| {
                    let mut wo = JsonObj::new();
                    wo.set("worker", w.worker);
                    wo.set("active", w.active);
                    wo.set("waiting", w.waiting);
                    wo.set("admitted_in_flight", w.admitted_in_flight);
                    wo.set("parked_sessions", w.parked_sessions);
                    wo.set("parked_cold_sessions", w.parked_cold_sessions);
                    wo.set("cold_bytes", w.cold_bytes as i64);
                    wo.set("completed", w.completed);
                    wo.set("generated_tokens", w.generated_tokens);
                    wo.set("throughput_tps", w.throughput_tps);
                    wo.set("assembly_us_p50", w.assembly_us_p50);
                    wo.set("assembly_us_p99", w.assembly_us_p99);
                    wo.set("assembly_samples", w.assembly_samples as i64);
                    wo.set("restore_us_p50", w.restore_us_p50);
                    wo.set("restore_us_p99", w.restore_us_p99);
                    wo.set("restore_samples", w.restore_samples as i64);
                    wo.set("promotions", w.promotions as i64);
                    wo.set("thrash_suppressed", w.thrash_suppressed as i64);
                    Json::Obj(wo)
                })
                .collect();
            o.set("workers", Json::Arr(workers));
        }
        ServeEvent::CancelResult { id, target, found } => {
            o.set("event", "cancelled");
            o.set("id", *id as i64);
            o.set("target", *target as i64);
            o.set("found", *found);
        }
    }
    Json::Obj(o).to_string()
}

/// Encode a terminal response in the legacy single-line shape (the exact
/// pre-v1 field set, locked by regression test).
pub fn encode_legacy_response(r: &Response) -> String {
    let mut o = JsonObj::new();
    o.set("id", r.id as i64);
    o.set("tokens", tokens_json(&r.tokens));
    o.set("ttft_ms", r.metrics.ttft.as_secs_f64() * 1e3);
    o.set("latency_ms", r.metrics.latency.as_secs_f64() * 1e3);
    o.set("prompt_tokens", r.metrics.prompt_tokens);
    o.set("generated_tokens", r.metrics.generated_tokens);
    o.set("cache_pct", r.metrics.cache_pct);
    o.set("host_bytes", r.metrics.host_bytes);
    o.set(
        "error",
        match &r.error {
            Some(e) => Json::Str(e.message.clone()),
            None => Json::Null,
        },
    );
    Json::Obj(o).to_string()
}

/// Encode an event for a legacy client: only the terminal response is
/// visible (token/stats/cancel events have no legacy representation).
pub fn encode_legacy_event(ev: &ServeEvent) -> Option<String> {
    match ev {
        ServeEvent::Done(r) => Some(encode_legacy_response(r)),
        _ => None,
    }
}

// ----------------------------------------------------------------------
// RequestBuilder
// ----------------------------------------------------------------------

#[derive(Debug, Clone)]
enum BuilderOp {
    Generate,
    Append { session: u64 },
    Cancel { target: u64 },
    Stats,
}

/// Builds request lines programmatically so clients (examples, benches,
/// tests) never hand-roll protocol JSON. `build()` emits exactly what
/// [`decode_line`] parses (property-tested).
#[derive(Debug, Clone)]
pub struct RequestBuilder {
    op: BuilderOp,
    id: u64,
    prompt: Vec<i64>,
    max_new: usize,
    stop: Option<i64>,
    keep: Option<bool>,
    priority: Option<Priority>,
    spec: Option<CompressionSpec>,
    legacy: bool,
}

impl RequestBuilder {
    fn base(op: BuilderOp, id: u64) -> RequestBuilder {
        RequestBuilder {
            op,
            id,
            prompt: Vec::new(),
            max_new: 8,
            stop: None,
            keep: None,
            priority: None,
            spec: None,
            legacy: false,
        }
    }

    /// Start a fresh generation turn.
    pub fn generate(id: u64) -> RequestBuilder {
        Self::base(BuilderOp::Generate, id)
    }

    /// Continue a kept session.
    pub fn append(id: u64, session: u64) -> RequestBuilder {
        Self::base(BuilderOp::Append { session }, id)
    }

    /// Cancel an in-flight request.
    pub fn cancel(id: u64, target: u64) -> RequestBuilder {
        Self::base(BuilderOp::Cancel { target }, id)
    }

    /// Request a stats snapshot.
    pub fn stats(id: u64) -> RequestBuilder {
        Self::base(BuilderOp::Stats, id)
    }

    pub fn prompt(mut self, tokens: &[i64]) -> RequestBuilder {
        self.prompt = tokens.to_vec();
        self
    }

    pub fn max_new(mut self, n: usize) -> RequestBuilder {
        self.max_new = n;
        self
    }

    pub fn stop(mut self, token: i64) -> RequestBuilder {
        self.stop = Some(token);
        self
    }

    pub fn keep(mut self, keep: bool) -> RequestBuilder {
        self.keep = Some(keep);
        self
    }

    /// Pick the QoS lane (`interactive` is the wire default; the field is
    /// emitted only when set here, so default-lane lines stay unchanged).
    pub fn priority(mut self, priority: Priority) -> RequestBuilder {
        self.priority = Some(priority);
        self
    }

    pub fn compression(mut self, spec: CompressionSpec) -> RequestBuilder {
        self.spec = Some(spec);
        self
    }

    /// Emit the v-less legacy one-shot shape (generate only).
    pub fn legacy(mut self) -> RequestBuilder {
        self.legacy = true;
        self
    }

    /// Render the request as one JSON line (no trailing newline).
    pub fn build(&self) -> String {
        let mut o = JsonObj::new();
        if self.legacy {
            debug_assert!(
                matches!(self.op, BuilderOp::Generate),
                "legacy shape only exists for generate"
            );
            o.set("id", self.id as i64);
            o.set("prompt", tokens_json(&self.prompt));
            o.set("max_new", self.max_new);
            if let Some(s) = self.stop {
                o.set("stop", s);
            }
            spec_fields_into(&mut o, &self.spec.clone().unwrap_or_default());
            return Json::Obj(o).to_string();
        }
        o.set("v", 1i64);
        let op_name = match &self.op {
            BuilderOp::Generate => "generate",
            BuilderOp::Append { .. } => "append",
            BuilderOp::Cancel { .. } => "cancel",
            BuilderOp::Stats => "stats",
        };
        o.set("op", op_name);
        o.set("id", self.id as i64);
        match &self.op {
            BuilderOp::Generate | BuilderOp::Append { .. } => {
                if let BuilderOp::Append { session } = &self.op {
                    o.set("session", *session as i64);
                }
                o.set("prompt", tokens_json(&self.prompt));
                o.set("max_new", self.max_new);
                if let Some(s) = self.stop {
                    o.set("stop", s);
                }
                let default_keep = matches!(self.op, BuilderOp::Append { .. });
                o.set("keep", self.keep.unwrap_or(default_keep));
                if let Some(p) = self.priority {
                    o.set("priority", p.as_str());
                }
                if let Some(spec) = &self.spec {
                    o.set("compression", spec_to_json(spec));
                }
            }
            BuilderOp::Cancel { target } => {
                o.set("target", *target as i64);
            }
            BuilderOp::Stats => {}
        }
        Json::Obj(o).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ErrorCode, RequestMetrics, StatsSnapshot};
    use crate::util::prop::{forall, Config};
    use crate::util::rng::Pcg32;
    use std::time::Duration;

    // ------------------------------------------------------------------
    // Decoding
    // ------------------------------------------------------------------

    fn submit(line: &str) -> WireRequest {
        match decode_line(line).unwrap() {
            WireOp::Submit(w) => w,
            other => panic!("expected submit, got {other:?}"),
        }
    }

    #[test]
    fn decodes_v1_generate() {
        let w = submit(
            r#"{"v":1,"op":"generate","id":3,"prompt":[1,2],"max_new":4,"stop":6,
                "keep":true,"priority":"batch",
                "compression":{"mode":"mikv","ratio":0.25,"lo":"int2",
                "group":2,"policy":"local","promotion":true,"spill":false}}"#,
        );
        assert_eq!(w.id, 3);
        assert_eq!(w.prompt, vec![1, 2]);
        assert_eq!(w.max_new, 4);
        assert_eq!(w.stop, Some(6));
        assert!(w.keep);
        assert!(!w.legacy);
        assert_eq!(w.session, None);
        assert_eq!(w.priority, Priority::Batch);
        assert_eq!(w.spec.mode, "mikv");
        assert_eq!(w.spec.ratio, Some(0.25));
        assert_eq!(w.spec.lo.as_deref(), Some("int2"));
        assert_eq!(w.spec.group, Some(2));
        assert_eq!(w.spec.policy.as_deref(), Some("local"));
        assert_eq!(w.spec.promotion, Some(true));
        assert_eq!(w.spec.spill, Some(false));

        // absent promotion/spill decode as None (off / server default)
        let w = submit(
            r#"{"v":1,"op":"generate","id":4,"prompt":[1],"compression":{"mode":"mikv"}}"#,
        );
        assert_eq!(w.spec.promotion, None);
        assert_eq!(w.spec.spill, None);
        // absent priority decodes as the interactive (default) lane
        assert_eq!(w.priority, Priority::Interactive);
    }

    #[test]
    fn decodes_v1_append_cancel_stats() {
        let w = submit(r#"{"v":1,"op":"append","id":2,"session":7,"prompt":[4,5]}"#);
        assert_eq!(w.session, Some(7));
        assert!(w.keep, "append keeps by default");
        assert_eq!(w.spec, CompressionSpec::full());

        assert_eq!(
            decode_line(r#"{"v":1,"op":"cancel","id":3,"target":1}"#).unwrap(),
            WireOp::Cancel { id: 3, target: 1 }
        );
        assert_eq!(
            decode_line(r#"{"v":1,"op":"stats","id":4}"#).unwrap(),
            WireOp::Stats { id: 4 }
        );
    }

    #[test]
    fn legacy_lines_parse_as_one_shot_generate() {
        let w = submit(
            r#"{"id":1,"prompt":[1,2],"max_new":3,"mode":"mikv","ratio":0.3,"lo":"int4"}"#,
        );
        assert!(w.legacy);
        assert!(!w.keep);
        assert_eq!(w.session, None);
        assert_eq!(w.priority, Priority::Interactive);
        assert_eq!(w.spec.mode, "mikv");
        assert_eq!(w.spec.ratio, Some(0.3));
        assert_eq!(w.spec.lo.as_deref(), Some("int4"));

        // `prec` is the legacy rtn spelling
        let w = submit(r#"{"id":2,"prompt":[1],"mode":"rtn","prec":"int8"}"#);
        assert_eq!(w.spec.lo.as_deref(), Some("int8"));
        // defaults
        let w = submit(r#"{"id":3,"prompt":[9]}"#);
        assert_eq!(w.max_new, 8);
        assert_eq!(w.spec, CompressionSpec::full());
    }

    #[test]
    fn rejects_bad_requests_with_codes() {
        let cases = [
            ("not json", 0),
            (r#"{"prompt":[1]}"#, 0),                                // no id
            (r#"{"id":1,"prompt":[]}"#, 1),                          // empty prompt
            (r#"{"id":2,"prompt":[1,"x"]}"#, 2),                     // non-integer token
            (r#"{"id":3,"prompt":[1,1.5]}"#, 3),                     // fractional token
            (r#"{"v":2,"op":"generate","id":4,"prompt":[1]}"#, 4),   // bad version
            (r#"{"v":1,"op":"warp","id":5}"#, 5),                    // unknown op
            (r#"{"v":1,"op":"append","id":6,"prompt":[1]}"#, 6),     // no session
            (r#"{"v":1,"op":"cancel","id":7}"#, 7),                  // no target
            (r#"{"v":1,"op":"generate","id":8,"prompt":[1],"compression":{"ratio":"x"}}"#, 8),
            (r#"{"id":-3,"prompt":[1]}"#, 0),                        // negative id
            (r#"{"v":1,"op":"append","id":10,"session":-1,"prompt":[1]}"#, 10),
            (r#"{"v":1,"op":"cancel","id":11,"target":-2}"#, 11),
            // v1 is strictly typed: wrong-typed top-level fields never
            // silently fall back to defaults
            (r#"{"v":1,"op":"generate","id":12,"prompt":[1],"keep":1}"#, 12),
            (r#"{"v":1,"op":"generate","id":13,"prompt":[1],"max_new":2.5}"#, 13),
            (r#"{"v":1,"op":"generate","id":14,"prompt":[1],"stop":6.5}"#, 14),
            // promotion/spill must be booleans, never coerced
            (r#"{"v":1,"op":"generate","id":15,"prompt":[1],"compression":{"promotion":1}}"#, 15),
            (r#"{"v":1,"op":"generate","id":16,"prompt":[1],"compression":{"spill":1}}"#, 16),
            // priority must be a known lane name, never coerced
            (r#"{"v":1,"op":"generate","id":17,"prompt":[1],"priority":1}"#, 17),
            (r#"{"v":1,"op":"generate","id":18,"prompt":[1],"priority":"turbo"}"#, 18),
        ];
        for (line, want_id) in cases {
            let e = decode_line(line).expect_err(line);
            assert_eq!(e.err.code, ErrorCode::BadRequest, "{line}");
            assert_eq!(e.id, want_id, "{line}");
        }
        // the old silent `unwrap_or(0)` coercion is gone for good
        let e = decode_line(r#"{"id":9,"prompt":[null]}"#).unwrap_err();
        assert!(e.err.message.contains("not an integer"), "{}", e.err);
        assert!(e.legacy);
        // v1 decode failures are marked non-legacy so errors event-encode
        let e = decode_line(r#"{"v":1,"op":"warp","id":5}"#).unwrap_err();
        assert!(!e.legacy);
    }

    // ------------------------------------------------------------------
    // Round-trip property: encode ∘ decode == identity for all ops
    // ------------------------------------------------------------------

    fn arbitrary_spec(rng: &mut Pcg32) -> CompressionSpec {
        let modes = ["full", "oracle", "mikv", "h2o", "rtn"];
        let mut spec = CompressionSpec {
            mode: modes[rng.gen_below(modes.len() as u32) as usize].to_string(),
            ratio: None,
            lo: None,
            group: None,
            policy: None,
            k: None,
            promotion: None,
            spill: None,
        };
        if rng.gen_bool(0.5) {
            spec.ratio = Some((rng.gen_f32() as f64 * 100.0).round() / 100.0);
        }
        if rng.gen_bool(0.5) {
            let los = ["int2", "int3", "int4", "int8"];
            spec.lo = Some(los[rng.gen_below(4) as usize].to_string());
        }
        if rng.gen_bool(0.3) {
            spec.group = Some(1 + rng.gen_below(16) as usize);
        }
        if rng.gen_bool(0.3) {
            let pols = ["h2o", "local", "random"];
            spec.policy = Some(pols[rng.gen_below(3) as usize].to_string());
        }
        if rng.gen_bool(0.3) {
            spec.k = Some(rng.gen_below(64) as usize);
        }
        if rng.gen_bool(0.3) {
            spec.promotion = Some(rng.gen_bool(0.5));
        }
        if rng.gen_bool(0.3) {
            spec.spill = Some(rng.gen_bool(0.5));
        }
        spec
    }

    fn arbitrary_prompt(rng: &mut Pcg32) -> Vec<i64> {
        (0..1 + rng.gen_below(12) as usize)
            .map(|_| rng.gen_below(1000) as i64)
            .collect()
    }

    #[test]
    fn prop_encode_decode_identity_for_all_ops() {
        forall(Config::default().cases(300).name("proto-roundtrip"), |rng| {
            let id = rng.gen_below(100_000) as u64;
            let (builder, want) = match rng.gen_below(4) {
                0 | 1 => {
                    // generate / append share the submit shape
                    let is_append = rng.gen_bool(0.5);
                    let prompt = arbitrary_prompt(rng);
                    let max_new = 1 + rng.gen_below(32) as usize;
                    let stop = if rng.gen_bool(0.5) {
                        Some(rng.gen_below(100) as i64)
                    } else {
                        None
                    };
                    let keep = rng.gen_bool(0.5);
                    let priority = if rng.gen_bool(0.5) {
                        Some(if rng.gen_bool(0.5) {
                            Priority::Batch
                        } else {
                            Priority::Interactive
                        })
                    } else {
                        None
                    };
                    let spec = if rng.gen_bool(0.8) {
                        Some(arbitrary_spec(rng))
                    } else {
                        None
                    };
                    let session = rng.gen_below(50) as u64;
                    let mut b = if is_append {
                        RequestBuilder::append(id, session)
                    } else {
                        RequestBuilder::generate(id)
                    };
                    b = b.prompt(&prompt).max_new(max_new).keep(keep);
                    if let Some(s) = stop {
                        b = b.stop(s);
                    }
                    if let Some(p) = priority {
                        b = b.priority(p);
                    }
                    if let Some(sp) = spec.clone() {
                        b = b.compression(sp);
                    }
                    let want = WireOp::Submit(WireRequest {
                        id,
                        prompt,
                        max_new,
                        stop,
                        spec: spec.unwrap_or_default(),
                        session: if is_append { Some(session) } else { None },
                        keep,
                        priority: priority.unwrap_or_default(),
                        legacy: false,
                    });
                    (b, want)
                }
                2 => {
                    let target = rng.gen_below(1000) as u64;
                    (
                        RequestBuilder::cancel(id, target),
                        WireOp::Cancel { id, target },
                    )
                }
                _ => (RequestBuilder::stats(id), WireOp::Stats { id }),
            };
            let line = builder.build();
            let got = decode_line(&line)
                .map_err(|e| format!("decode({line}) failed: {}", e.err))?;
            crate::prop_assert!(got == want, "line {line}: {got:?} != {want:?}");
            Ok(())
        });
    }

    #[test]
    fn prop_legacy_builder_roundtrips() {
        forall(Config::default().cases(200).name("legacy-roundtrip"), |rng| {
            let id = rng.gen_below(10_000) as u64;
            let prompt = arbitrary_prompt(rng);
            let max_new = 1 + rng.gen_below(16) as usize;
            let spec = arbitrary_spec(rng);
            let line = RequestBuilder::generate(id)
                .prompt(&prompt)
                .max_new(max_new)
                .compression(spec.clone())
                .legacy()
                .build();
            let got = decode_line(&line)
                .map_err(|e| format!("decode({line}) failed: {}", e.err))?;
            let want = WireOp::Submit(WireRequest {
                id,
                prompt,
                max_new,
                stop: None,
                spec,
                session: None,
                keep: false,
                priority: Priority::Interactive,
                legacy: true,
            });
            crate::prop_assert!(got == want, "line {line}: {got:?} != {want:?}");
            Ok(())
        });
    }

    // ------------------------------------------------------------------
    // Event encoding
    // ------------------------------------------------------------------

    fn response(id: u64) -> Response {
        Response {
            id,
            tokens: vec![3, 1, 4],
            metrics: RequestMetrics {
                ttft: Duration::from_millis(5),
                latency: Duration::from_millis(20),
                prompt_tokens: 12,
                generated_tokens: 3,
                cache_pct: 33.5,
                host_bytes: 4096,
                hi_slots: 8,
                lo_slots: 40,
                promotions: 5,
                thrash_suppressed: 2,
            },
            session: Some(7),
            cancelled: false,
            error: None,
        }
    }

    #[test]
    fn done_event_shape() {
        let line = encode_event(&ServeEvent::Done(response(9)));
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.field_str("event").unwrap(), "done");
        assert_eq!(v.field_i64("id").unwrap(), 9);
        assert_eq!(v.field_arr("tokens").unwrap().len(), 3);
        assert_eq!(v.field_i64("session").unwrap(), 7);
        assert_eq!(v.field("cancelled").unwrap(), &Json::Bool(false));
        assert!((v.field_f64("cache_pct").unwrap() - 33.5).abs() < 1e-9);
        assert_eq!(v.field_i64("host_bytes").unwrap(), 4096);
        assert_eq!(v.field_i64("hi_slots").unwrap(), 8);
        assert_eq!(v.field_i64("lo_slots").unwrap(), 40);
        assert_eq!(v.field_i64("promotions").unwrap(), 5);
        assert_eq!(v.field_i64("thrash_suppressed").unwrap(), 2);
    }

    #[test]
    fn token_error_stats_cancel_event_shapes() {
        let line = encode_event(&ServeEvent::Token {
            id: 4,
            index: 2,
            token: 17,
        });
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.field_str("event").unwrap(), "token");
        assert_eq!(v.field_i64("i").unwrap(), 2);
        assert_eq!(v.field_i64("t").unwrap(), 17);

        let line = encode_event(&ServeEvent::Done(Response::error(
            5,
            WireError::new(ErrorCode::SessionNotFound, "no live session 9"),
        )));
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.field_str("event").unwrap(), "error");
        assert_eq!(v.field_str("code").unwrap(), "session_not_found");
        assert!(v.field_str("message").unwrap().contains("9"));
        // no hint, no field: the pre-QoS error shape is locked
        assert!(v.field("retry_after_ms").is_err());

        let line = encode_event(&ServeEvent::Done(Response::error(
            5,
            WireError::new(ErrorCode::Overloaded, "worker 0 backlog full")
                .with_retry_after(25),
        )));
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.field_str("code").unwrap(), "overloaded");
        assert_eq!(v.field_i64("retry_after_ms").unwrap(), 25);

        let line = encode_event(&ServeEvent::Stats {
            id: 6,
            snapshot: StatsSnapshot::default(),
        });
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.field_str("event").unwrap(), "stats");
        assert_eq!(v.field_i64("pool_free_blocks").unwrap(), 0);
        assert_eq!(v.field_arr("workers").unwrap().len(), 0);

        // per-worker rows of the sharded runtime encode under "workers"
        let snapshot = StatsSnapshot {
            completed: 3,
            admitted_in_flight: 5,
            qos_queued: 2,
            shed_batch: 7,
            shed_interactive: 1,
            rate_limited: 4,
            worker_restarts: 2,
            sessions_recovered: 3,
            sessions_lost: 1,
            events_dropped: 17,
            assembly_us_p50: 12.5,
            assembly_us_p99: 80.25,
            assembly_samples: 42,
            promotions: 9,
            thrash_suppressed: 4,
            parked_cold_sessions: 2,
            cold_bytes: 8192,
            cold_evictions: 1,
            restore_us_p50: 250.0,
            restore_us_p99: 900.5,
            restore_samples: 6,
            workers: vec![crate::coordinator::WorkerStats {
                worker: 1,
                active: 2,
                waiting: 0,
                parked_sessions: 1,
                parked_cold_sessions: 2,
                cold_bytes: 8192,
                completed: 3,
                generated_tokens: 12,
                throughput_tps: 4.5,
                assembly_us_p50: 12.5,
                assembly_us_p99: 80.25,
                assembly_samples: 42,
                restore_us_p50: 250.0,
                restore_us_p99: 900.5,
                restore_samples: 6,
                promotions: 9,
                thrash_suppressed: 4,
                admitted_in_flight: 3,
            }],
            ..StatsSnapshot::default()
        };
        let line = encode_event(&ServeEvent::Stats { id: 8, snapshot });
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.field_i64("admitted_in_flight").unwrap(), 5);
        assert_eq!(v.field_i64("qos_queued").unwrap(), 2);
        assert_eq!(v.field_i64("shed_batch").unwrap(), 7);
        assert_eq!(v.field_i64("shed_interactive").unwrap(), 1);
        assert_eq!(v.field_i64("rate_limited").unwrap(), 4);
        assert_eq!(v.field_i64("worker_restarts").unwrap(), 2);
        assert_eq!(v.field_i64("sessions_recovered").unwrap(), 3);
        assert_eq!(v.field_i64("sessions_lost").unwrap(), 1);
        assert_eq!(v.field_i64("events_dropped").unwrap(), 17);
        assert!((v.field_f64("assembly_us_p50").unwrap() - 12.5).abs() < 1e-9);
        assert!((v.field_f64("assembly_us_p99").unwrap() - 80.25).abs() < 1e-9);
        assert_eq!(v.field_i64("assembly_samples").unwrap(), 42);
        assert_eq!(v.field_i64("promotions").unwrap(), 9);
        assert_eq!(v.field_i64("thrash_suppressed").unwrap(), 4);
        assert_eq!(v.field_i64("parked_cold_sessions").unwrap(), 2);
        assert_eq!(v.field_i64("cold_bytes").unwrap(), 8192);
        assert_eq!(v.field_i64("cold_evictions").unwrap(), 1);
        assert!((v.field_f64("restore_us_p50").unwrap() - 250.0).abs() < 1e-9);
        assert!((v.field_f64("restore_us_p99").unwrap() - 900.5).abs() < 1e-9);
        assert_eq!(v.field_i64("restore_samples").unwrap(), 6);
        let rows = v.field_arr("workers").unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].field_i64("worker").unwrap(), 1);
        assert_eq!(rows[0].field_i64("admitted_in_flight").unwrap(), 3);
        assert_eq!(rows[0].field_i64("completed").unwrap(), 3);
        assert_eq!(rows[0].field_i64("generated_tokens").unwrap(), 12);
        assert!((rows[0].field_f64("throughput_tps").unwrap() - 4.5).abs() < 1e-9);
        assert!((rows[0].field_f64("assembly_us_p50").unwrap() - 12.5).abs() < 1e-9);
        assert_eq!(rows[0].field_i64("assembly_samples").unwrap(), 42);
        assert_eq!(rows[0].field_i64("promotions").unwrap(), 9);
        assert_eq!(rows[0].field_i64("thrash_suppressed").unwrap(), 4);
        assert_eq!(rows[0].field_i64("parked_cold_sessions").unwrap(), 2);
        assert_eq!(rows[0].field_i64("cold_bytes").unwrap(), 8192);
        assert!((rows[0].field_f64("restore_us_p50").unwrap() - 250.0).abs() < 1e-9);
        assert_eq!(rows[0].field_i64("restore_samples").unwrap(), 6);

        let line = encode_event(&ServeEvent::CancelResult {
            id: 7,
            target: 3,
            found: true,
        });
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.field_str("event").unwrap(), "cancelled");
        assert_eq!(v.field_i64("target").unwrap(), 3);
        assert_eq!(v.field("found").unwrap(), &Json::Bool(true));
    }

    /// The legacy single-line response shape is locked: exact field set,
    /// no "event" key, free-text error string.
    #[test]
    fn legacy_response_shape_locked() {
        let line = encode_legacy_response(&response(9));
        let v = Json::parse(&line).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(
            keys,
            vec![
                "id",
                "tokens",
                "ttft_ms",
                "latency_ms",
                "prompt_tokens",
                "generated_tokens",
                "cache_pct",
                "host_bytes",
                "error"
            ]
        );
        assert!(v.field("error").unwrap() == &Json::Null);
        assert_eq!(v.field_i64("host_bytes").unwrap(), 4096);

        let err_line = encode_legacy_response(&Response::error(
            0,
            WireError::bad_request("prompt[1] is not an integer token id"),
        ));
        let v = Json::parse(&err_line).unwrap();
        assert!(v.field_str("error").unwrap().contains("not an integer"));

        // tokens are invisible to legacy clients
        assert!(encode_legacy_event(&ServeEvent::Token {
            id: 1,
            index: 0,
            token: 2
        })
        .is_none());
    }
}
