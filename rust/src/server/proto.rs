//! Wire protocol: JSON-lines request/response.
//!
//! Request:
//! ```json
//! {"id": 1, "prompt": [1, 17, 230], "max_new": 4,
//!  "mode": "mikv", "ratio": 0.25, "lo": "int2", "stop": 6}
//! ```
//! `mode` ∈ `full` | `oracle` (+`k`) | `mikv` (+`ratio`, `lo`) |
//! `h2o` (+`ratio`) | `rtn` (+`prec`). Response:
//! ```json
//! {"id": 1, "tokens": [230, 231], "ttft_ms": 12.3, "latency_ms": 40.1,
//!  "cache_pct": 33.2, "host_bytes": 43008, "error": null}
//! ```

use crate::coordinator::Response;
use crate::model::CacheMode;
use crate::quant::Precision;
use crate::runtime::ModelDims;
use crate::util::json::{Json, JsonObj};

/// A parsed wire request (pre-CacheMode resolution).
#[derive(Debug, Clone)]
pub struct WireRequest {
    pub id: u64,
    pub prompt: Vec<i64>,
    pub max_new: usize,
    pub stop: Option<i64>,
    pub mode: CacheMode,
}

/// Decode one request line against a model's dimensions.
pub fn decode_request(line: &str, dims: &ModelDims) -> crate::Result<WireRequest> {
    let v = Json::parse(line)?;
    let id = v.field_i64("id")? as u64;
    let prompt: Vec<i64> = v
        .field_arr("prompt")?
        .iter()
        .map(|t| t.as_i64().unwrap_or(0))
        .collect();
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = v.field_i64("max_new").unwrap_or(8) as usize;
    let stop = v.field("stop").ok().and_then(|s| s.as_i64());

    let mode_s = v.field_str("mode").unwrap_or("full");
    let ratio = v.field_f64("ratio").unwrap_or(0.2);
    let mode = match mode_s {
        "full" => CacheMode::Full,
        "oracle" => CacheMode::Oracle {
            k: v.field_i64("k").unwrap_or(dims.max_seq as i64 + 1) as usize,
        },
        "mikv" => {
            let lo = Precision::parse(v.field_str("lo").unwrap_or("int2"))
                .ok_or_else(|| anyhow::anyhow!("bad lo precision"))?;
            CacheMode::mikv(dims, ratio, lo)
        }
        "h2o" => CacheMode::h2o(dims, ratio),
        "rtn" => {
            let p = Precision::parse(v.field_str("prec").unwrap_or("int8"))
                .ok_or_else(|| anyhow::anyhow!("bad rtn precision"))?;
            CacheMode::rtn(dims, p)
        }
        other => anyhow::bail!("unknown mode '{other}'"),
    };
    Ok(WireRequest {
        id,
        prompt,
        max_new,
        stop,
        mode,
    })
}

/// Encode a coordinator response as one JSON line (no trailing newline).
pub fn encode_response(r: &Response) -> String {
    let mut o = JsonObj::new();
    o.set("id", r.id as i64);
    o.set(
        "tokens",
        Json::Arr(r.tokens.iter().map(|&t| Json::Int(t)).collect()),
    );
    o.set("ttft_ms", r.metrics.ttft.as_secs_f64() * 1e3);
    o.set("latency_ms", r.metrics.latency.as_secs_f64() * 1e3);
    o.set("prompt_tokens", r.metrics.prompt_tokens);
    o.set("generated_tokens", r.metrics.generated_tokens);
    o.set("cache_pct", r.metrics.cache_pct);
    o.set("host_bytes", r.metrics.host_bytes);
    o.set(
        "error",
        match &r.error {
            Some(e) => Json::Str(e.clone()),
            None => Json::Null,
        },
    );
    Json::Obj(o).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RequestMetrics;
    use std::time::Duration;

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 512,
            d_model: 256,
            n_layers: 4,
            n_q_heads: 8,
            n_kv_heads: 8,
            d_head: 32,
            d_ff: 1024,
            max_seq: 320,
            quant_group: 16,
            params: 0,
        }
    }

    #[test]
    fn decodes_all_modes() {
        let d = dims();
        let r = decode_request(r#"{"id":1,"prompt":[1,2],"mode":"full"}"#, &d).unwrap();
        assert!(matches!(r.mode, CacheMode::Full));
        let r = decode_request(r#"{"id":2,"prompt":[1],"mode":"oracle","k":16}"#, &d).unwrap();
        assert!(matches!(r.mode, CacheMode::Oracle { k: 16 }));
        let r = decode_request(
            r#"{"id":3,"prompt":[1],"mode":"mikv","ratio":0.25,"lo":"int2","max_new":4,"stop":6}"#,
            &d,
        )
        .unwrap();
        assert_eq!(r.max_new, 4);
        assert_eq!(r.stop, Some(6));
        match r.mode {
            CacheMode::Mikv { cfg, .. } => {
                assert!((cfg.importance_ratio - 0.25).abs() < 1e-9);
                assert_eq!(cfg.lo.precision, Precision::Int2);
            }
            _ => panic!("not mikv"),
        }
        let r = decode_request(r#"{"id":4,"prompt":[1],"mode":"h2o","ratio":0.5}"#, &d).unwrap();
        match r.mode {
            CacheMode::Mikv { cfg, .. } => {
                assert_eq!(cfg.retention, crate::kvcache::RetentionMode::Evict)
            }
            _ => panic!(),
        }
        let r = decode_request(r#"{"id":5,"prompt":[1],"mode":"rtn","prec":"int4"}"#, &d).unwrap();
        match r.mode {
            CacheMode::Mikv { cfg, .. } => assert_eq!(cfg.lo.precision, Precision::Int4),
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_bad_requests() {
        let d = dims();
        assert!(decode_request("not json", &d).is_err());
        assert!(decode_request(r#"{"id":1,"prompt":[]}"#, &d).is_err());
        assert!(decode_request(r#"{"id":1,"prompt":[1],"mode":"warp"}"#, &d).is_err());
        assert!(decode_request(r#"{"prompt":[1]}"#, &d).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let r = Response {
            id: 9,
            tokens: vec![3, 1, 4],
            metrics: RequestMetrics {
                ttft: Duration::from_millis(5),
                latency: Duration::from_millis(20),
                prompt_tokens: 12,
                generated_tokens: 3,
                cache_pct: 33.5,
                host_bytes: 4096,
            },
            error: None,
        };
        let line = encode_response(&r);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.field_i64("id").unwrap(), 9);
        assert_eq!(v.field_arr("tokens").unwrap().len(), 3);
        assert!(v.field("error").unwrap() == &Json::Null);
        assert!((v.field_f64("cache_pct").unwrap() - 33.5).abs() < 1e-9);
        assert_eq!(v.field_i64("host_bytes").unwrap(), 4096);
    }
}
