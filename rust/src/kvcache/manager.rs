//! The MiKV cache manager: per-session mixed-precision tier state.
//!
//! One manager instance owns the cache of a single generation session across
//! all `layers × kv_heads` planes. It maintains **two representations** of
//! the retained tier:
//!
//! 1. the *physical* packed representation inside [`LoTier`] (bit-packed
//!    codes + FP16 metadata) — this is what the logical memory accounting
//!    charges, and what a real deployment would hold in device memory;
//! 2. a *shadow* dense representation (codes as f32-held integers, scales,
//!    zeros, masks) laid out exactly like the decode graph's inputs — kept
//!    incrementally up to date on every admit/demote so a decode step's
//!    input assembly is a handful of plane-contiguous `memcpy`s instead of
//!    per-slot unpacking (see EXPERIMENTS.md §Perf).
//!
//! Lifecycle per session: [`CacheManager::ingest_prefill`] once, then
//! [`CacheManager::append_token`] per generated token. The engine reads the
//! dense blocks via [`CacheManager::decode_views`].

use super::accounting::{self, Occupancy};
use super::tier::{HiTier, LoTier};
use super::{CacheConfig, Placement, RetentionMode};
use crate::policies::ImportancePolicy;
use crate::quant::Balancer;

/// Dense per-session views over the decode-graph input blocks, all plane-
/// major: `[planes, max_seq, ...]` where `planes = layers × kv_heads`.
pub struct DecodeViews<'a> {
    pub k_hi: &'a [f32],
    pub v_hi: &'a [f32],
    pub hi_mask: &'a [f32],
    pub k_lo_codes: &'a [f32],
    pub k_lo_scale: &'a [f32],
    pub k_lo_zero: &'a [f32],
    pub v_lo_codes: &'a [f32],
    pub v_lo_scale: &'a [f32],
    pub v_lo_zero: &'a [f32],
    pub lo_mask: &'a [f32],
    /// `[planes, head_dim]` — 1/b per channel (identity when outlier
    /// awareness is off).
    pub inv_balancer: &'a [f32],
}

/// Outputs of one decode step the manager needs to ingest.
pub struct StepOutputs<'a> {
    /// New token K, `[planes, head_dim]`.
    pub k_new: &'a [f32],
    /// New token V, `[planes, head_dim]`.
    pub v_new: &'a [f32],
    /// Attention the new query paid to previous slots, `[planes, max_seq]`
    /// (only `0..seq_len` is meaningful).
    pub attn_prev: &'a [f32],
    /// Self-attention mass of the new token, `[planes]`.
    pub attn_self: &'a [f32],
}

/// The mixed-precision cache manager (see module docs).
pub struct CacheManager {
    cfg: CacheConfig,
    policy: Box<dyn ImportancePolicy>,
    planes: usize,
    d: usize,
    s_max: usize,
    groups: usize,

    hi: Vec<HiTier>,
    lo: Vec<LoTier>,
    balancers: Vec<Balancer>,

    // Shadow dense blocks (decode-graph input layout, plane-major).
    k_hi_buf: Vec<f32>,
    v_hi_buf: Vec<f32>,
    hi_mask: Vec<f32>,
    k_lo_codes: Vec<f32>,
    k_lo_scale: Vec<f32>,
    k_lo_zero: Vec<f32>,
    v_lo_codes: Vec<f32>,
    v_lo_scale: Vec<f32>,
    v_lo_zero: Vec<f32>,
    lo_mask: Vec<f32>,
    inv_balancer: Vec<f32>,

    placement: Vec<Placement>,
    hi_count: Vec<usize>,
    seq_len: usize,
    scratch_u8: Vec<u8>,
    scratch_f32: Vec<f32>,
}

impl CacheManager {
    pub fn new(cfg: CacheConfig, policy: Box<dyn ImportancePolicy>) -> Self {
        let planes = cfg.layers * cfg.kv_heads;
        let d = cfg.head_dim;
        let s = cfg.max_seq;
        let lo_group = cfg.lo.group.min(d);
        let groups = d / lo_group;
        let hi = (0..planes).map(|_| HiTier::new(cfg.hi, d, s)).collect();
        let lo = (0..planes).map(|_| LoTier::new(cfg.lo, d, s)).collect();
        Self {
            planes,
            d,
            s_max: s,
            groups,
            hi,
            lo,
            balancers: vec![Balancer::identity(d); planes],
            k_hi_buf: vec![0.0; planes * s * d],
            v_hi_buf: vec![0.0; planes * s * d],
            hi_mask: vec![0.0; planes * s],
            k_lo_codes: vec![0.0; planes * s * d],
            k_lo_scale: vec![0.0; planes * s * groups],
            k_lo_zero: vec![0.0; planes * s * groups],
            v_lo_codes: vec![0.0; planes * s * d],
            v_lo_scale: vec![0.0; planes * s * groups],
            v_lo_zero: vec![0.0; planes * s * groups],
            lo_mask: vec![0.0; planes * s],
            inv_balancer: vec![1.0; planes * d],
            placement: vec![Placement::Empty; planes * s],
            hi_count: vec![0; planes],
            seq_len: 0,
            scratch_u8: vec![0; d],
            scratch_f32: vec![0.0; d],
            cfg,
            policy,
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    fn slot_idx(&self, plane: usize, s: usize) -> usize {
        plane * self.s_max + s
    }

    pub fn placement(&self, plane: usize, s: usize) -> Placement {
        self.placement[self.slot_idx(plane, s)]
    }

    // ------------------------------------------------------------------
    // Prefill ingestion
    // ------------------------------------------------------------------

    /// Ingest the prefill outputs for a prompt of length `seq_len`.
    ///
    /// Layouts (plane-major, padded to `max_seq` where noted):
    /// `k`/`v`: `[planes, seq_len, d]` (unpadded), `attn_acc`:
    /// `[planes, seq_len]`, `qmax`/`kmax`: `[planes, d]`.
    pub fn ingest_prefill(
        &mut self,
        seq_len: usize,
        k: &[f32],
        v: &[f32],
        attn_acc: &[f32],
        qmax: &[f32],
        kmax: &[f32],
    ) {
        assert!(seq_len <= self.s_max, "prompt longer than max_seq");
        assert_eq!(k.len(), self.planes * seq_len * self.d);
        assert_eq!(attn_acc.len(), self.planes * seq_len);
        assert_eq!(qmax.len(), self.planes * self.d);
        self.seq_len = seq_len;

        // 1. Channel balancers from prefill q/k maxima (paper eq. 2).
        for p in 0..self.planes {
            let bal = if self.cfg.outlier_aware {
                Balancer::from_maxima(&qmax[p * self.d..(p + 1) * self.d], &kmax[p * self.d..(p + 1) * self.d])
            } else {
                Balancer::identity(self.d)
            };
            self.inv_balancer[p * self.d..(p + 1) * self.d].copy_from_slice(&bal.inverse());
            self.balancers[p] = bal;
        }

        // 2. Importance seeding + tier placement per plane.
        let budget = self.cfg.hi_budget(seq_len);
        for p in 0..self.planes {
            let acc = &attn_acc[p * seq_len..(p + 1) * seq_len];
            self.policy.init_prefill(p, acc);

            // Rank slots: recency-protected slots are always hi; the rest of
            // the budget goes to the highest-scoring slots.
            let protect_from = seq_len.saturating_sub(self.cfg.recent_window);
            let mut scored: Vec<(f32, usize)> = (0..protect_from)
                .map(|s| (self.policy.score(p, s), s))
                .collect();
            scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
            let n_protected = seq_len - protect_from;
            let n_scored_hi = budget.saturating_sub(n_protected).min(scored.len());

            let mut is_hi = vec![false; seq_len];
            for s in protect_from..seq_len {
                is_hi[s] = true;
            }
            for &(_, s) in scored.iter().take(n_scored_hi) {
                is_hi[s] = true;
            }

            for s in 0..seq_len {
                let kv_off = (p * seq_len + s) * self.d;
                let kt = &k[kv_off..kv_off + self.d];
                let vt = &v[kv_off..kv_off + self.d];
                if is_hi[s] {
                    self.admit_hi(p, s, kt, vt);
                } else {
                    self.place_lo_or_evict(p, s, kt, vt);
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Decode-step ingestion
    // ------------------------------------------------------------------

    /// Ingest one decode step's outputs: update importance, admit the new
    /// token to the hi tier, and demote/evict down to budget.
    pub fn append_token(&mut self, out: StepOutputs<'_>) {
        let t = self.seq_len;
        assert!(t < self.s_max, "cache full");
        assert_eq!(out.k_new.len(), self.planes * self.d);
        assert_eq!(out.attn_prev.len(), self.planes * self.s_max);

        let new_len = t + 1;
        let budget = self.cfg.hi_budget(new_len);
        for p in 0..self.planes {
            // Importance update from this step's attention row (+ self mass).
            let row = &out.attn_prev[p * self.s_max..p * self.s_max + t];
            self.policy.observe(p, row);
            self.policy.admit(p, t);
            // Self-attention mass accrues to the new slot.
            let self_row: Vec<f32> = (0..new_len)
                .map(|s| if s == t { out.attn_self[p] } else { 0.0 })
                .collect();
            self.policy.observe(p, &self_row);

            // The new token always enters hi (recent tokens are important).
            let off = p * self.d;
            // Split borrows: copy out the slices to avoid aliasing self.
            let k_new = out.k_new[off..off + self.d].to_vec();
            let v_new = out.v_new[off..off + self.d].to_vec();
            self.admit_hi(p, t, &k_new, &v_new);

            // Enforce the hi budget.
            while self.hi_count[p] > budget {
                let protect_from = new_len.saturating_sub(self.cfg.recent_window.max(1));
                let candidates: Vec<usize> = (0..protect_from)
                    .filter(|&s| self.placement(p, s) == Placement::Hi)
                    .collect();
                if candidates.is_empty() {
                    break; // everything hi is recency-protected
                }
                let victim = self.policy.select_victim(p, &candidates);
                self.demote(p, victim);
            }
        }
        self.seq_len = new_len;
    }

    // ------------------------------------------------------------------
    // Tier transitions
    // ------------------------------------------------------------------

    fn admit_hi(&mut self, p: usize, s: usize, k: &[f32], v: &[f32]) {
        let prev = self.placement(p, s);
        assert!(
            prev == Placement::Empty,
            "admit_hi into occupied slot {s} ({prev:?})"
        );
        self.hi[p].admit(s, k, v);
        // Mirror the storage-rounded values into the dense block.
        let off = (p * self.s_max + s) * self.d;
        let idx = self.slot_idx(p, s);
        self.k_hi_buf[off..off + self.d].copy_from_slice(self.hi[p].k_slot(s));
        self.v_hi_buf[off..off + self.d].copy_from_slice(self.hi[p].v_slot(s));
        self.hi_mask[idx] = 1.0;
        self.hi_count[p] += 1;
        self.placement[idx] = Placement::Hi;
    }

    /// Demote a hi-tier slot to the retained tier (or evict, per config).
    fn demote(&mut self, p: usize, s: usize) {
        debug_assert_eq!(self.placement(p, s), Placement::Hi);
        let k = self.hi[p].k_slot(s).to_vec();
        let v = self.hi[p].v_slot(s).to_vec();
        // Clear hi state.
        self.hi[p].clear(s);
        let off = (p * self.s_max + s) * self.d;
        let idx = self.slot_idx(p, s);
        self.k_hi_buf[off..off + self.d].fill(0.0);
        self.v_hi_buf[off..off + self.d].fill(0.0);
        self.hi_mask[idx] = 0.0;
        self.hi_count[p] -= 1;
        self.placement[idx] = Placement::Empty;
        self.place_lo_or_evict(p, s, &k, &v);
    }

    fn place_lo_or_evict(&mut self, p: usize, s: usize, k: &[f32], v: &[f32]) {
        let idx = self.slot_idx(p, s);
        match self.cfg.retention {
            RetentionMode::Evict => {
                self.placement[idx] = Placement::Evicted;
            }
            RetentionMode::Retain => {
                // Balance the key before quantization (paper eq. 3).
                let k_bal = self.balancers[p].balance_key(k);
                self.lo[p].admit(s, &k_bal, v);
                self.refresh_lo_shadow(p, s);
                self.lo_mask[idx] = 1.0;
                self.placement[idx] = Placement::Lo;
            }
        }
    }

    /// Rebuild the dense shadow of one lo slot from the packed tier.
    fn refresh_lo_shadow(&mut self, p: usize, s: usize) {
        let d = self.d;
        let off = (p * self.s_max + s) * d;
        let goff = (p * self.s_max + s) * self.groups;

        self.lo[p].k_codes_f32_into(s, &mut self.scratch_u8, &mut self.scratch_f32);
        self.k_lo_codes[off..off + d].copy_from_slice(&self.scratch_f32);
        self.lo[p].v_codes_f32_into(s, &mut self.scratch_u8, &mut self.scratch_f32);
        self.v_lo_codes[off..off + d].copy_from_slice(&self.scratch_f32);

        let (ks, kz) = self.lo[p].k_meta_slot(s);
        self.k_lo_scale[goff..goff + self.groups].copy_from_slice(ks);
        self.k_lo_zero[goff..goff + self.groups].copy_from_slice(kz);
        let (vs, vz) = self.lo[p].v_meta_slot(s);
        self.v_lo_scale[goff..goff + self.groups].copy_from_slice(vs);
        self.v_lo_zero[goff..goff + self.groups].copy_from_slice(vz);
    }

    // ------------------------------------------------------------------
    // Views & diagnostics
    // ------------------------------------------------------------------

    /// Dense plane-major views over the decode-graph inputs.
    pub fn decode_views(&self) -> DecodeViews<'_> {
        DecodeViews {
            k_hi: &self.k_hi_buf,
            v_hi: &self.v_hi_buf,
            hi_mask: &self.hi_mask,
            k_lo_codes: &self.k_lo_codes,
            k_lo_scale: &self.k_lo_scale,
            k_lo_zero: &self.k_lo_zero,
            v_lo_codes: &self.v_lo_codes,
            v_lo_scale: &self.v_lo_scale,
            v_lo_zero: &self.v_lo_zero,
            lo_mask: &self.lo_mask,
            inv_balancer: &self.inv_balancer,
        }
    }

    /// Host-side reconstruction of what the attention kernel effectively
    /// sees for `(plane, slot)`: hi values verbatim, lo values dequantized
    /// with the balancer inverse applied to K. `None` if evicted/empty.
    pub fn effective_kv(&self, p: usize, s: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        match self.placement(p, s) {
            Placement::Hi => Some((self.hi[p].k_slot(s).to_vec(), self.hi[p].v_slot(s).to_vec())),
            Placement::Lo => {
                let (mut k, v) = self.lo[p].dequant_slot(s);
                self.balancers[p].unbalance_key_into(&mut k);
                Some((k, v))
            }
            _ => None,
        }
    }

    /// Tier occupancy summed over planes.
    pub fn occupancy(&self) -> Occupancy {
        let mut occ = Occupancy::default();
        for p in 0..self.planes {
            for s in 0..self.seq_len {
                match self.placement(p, s) {
                    Placement::Hi => occ.hi_slots += 1,
                    Placement::Lo => occ.lo_slots += 1,
                    Placement::Evicted => occ.evicted_slots += 1,
                    Placement::Empty => {}
                }
            }
        }
        occ
    }

    /// Current logical cache size as % of the uncompressed FP16 cache.
    pub fn cache_size_pct(&self) -> f64 {
        accounting::cache_size_pct(&self.cfg, &self.occupancy())
    }

    /// Invariant check used by tests and failure-injection: every slot below
    /// `seq_len` is in exactly one state consistent with the masks, and
    /// hi counts match.
    pub fn check_invariants(&self) -> Result<(), String> {
        for p in 0..self.planes {
            let mut hi_n = 0;
            for s in 0..self.s_max {
                let idx = p * self.s_max + s;
                let pl = self.placement[idx];
                let (hm, lm) = (self.hi_mask[idx], self.lo_mask[idx]);
                if s >= self.seq_len && pl != Placement::Empty {
                    return Err(format!("slot ({p},{s}) beyond seq_len is {pl:?}"));
                }
                match pl {
                    Placement::Hi => {
                        hi_n += 1;
                        if hm != 1.0 || lm != 0.0 {
                            return Err(format!("hi slot ({p},{s}) masks ({hm},{lm})"));
                        }
                    }
                    Placement::Lo => {
                        if hm != 0.0 || lm != 1.0 {
                            return Err(format!("lo slot ({p},{s}) masks ({hm},{lm})"));
                        }
                    }
                    Placement::Evicted | Placement::Empty => {
                        if hm != 0.0 || lm != 0.0 {
                            return Err(format!("empty slot ({p},{s}) masks ({hm},{lm})"));
                        }
                    }
                }
            }
            if hi_n != self.hi_count[p] {
                return Err(format!("plane {p}: hi_count {} != actual {hi_n}", self.hi_count[p]));
            }
            if self.seq_len > 0 && self.hi_count[p] == 0 {
                return Err(format!("plane {p}: no hi tokens at seq_len {}", self.seq_len));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::{make_policy, H2oPolicy};
    use crate::quant::Precision;
    use crate::util::rng::Pcg32;

    fn small_cfg(ratio: f64, retention: RetentionMode) -> CacheConfig {
        let mut c = CacheConfig::mikv(2, 2, 8, 32, ratio, Precision::Int4);
        c.retention = retention;
        c.recent_window = 2;
        c
    }

    /// Random prefill tensors for a config.
    fn prefill_data(
        cfg: &CacheConfig,
        t: usize,
        rng: &mut Pcg32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let planes = cfg.layers * cfg.kv_heads;
        let d = cfg.head_dim;
        let k: Vec<f32> = (0..planes * t * d).map(|_| rng.gen_normal()).collect();
        let v: Vec<f32> = (0..planes * t * d).map(|_| rng.gen_normal()).collect();
        let acc: Vec<f32> = (0..planes * t).map(|_| rng.gen_f32()).collect();
        let qmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
        let kmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
        (k, v, acc, qmax, kmax)
    }

    fn manager(ratio: f64, retention: RetentionMode) -> CacheManager {
        let cfg = small_cfg(ratio, retention);
        let planes = cfg.layers * cfg.kv_heads;
        let policy = Box::new(H2oPolicy::new(planes, cfg.max_seq));
        CacheManager::new(cfg, policy)
    }

    #[test]
    fn prefill_respects_budget_and_invariants() {
        let mut m = manager(0.25, RetentionMode::Retain);
        let mut rng = Pcg32::new(1);
        let t = 16;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        m.check_invariants().unwrap();
        let occ = m.occupancy();
        let planes = 4;
        assert_eq!(occ.total_slots(), (planes * t) as u64);
        // budget = ceil(0.25*16)=4 per plane
        assert_eq!(occ.hi_slots, (planes * 4) as u64);
        assert_eq!(occ.lo_slots, (planes * 12) as u64);
        assert_eq!(occ.evicted_slots, 0);
    }

    #[test]
    fn eviction_mode_discards() {
        let mut m = manager(0.25, RetentionMode::Evict);
        let mut rng = Pcg32::new(2);
        let t = 16;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        let occ = m.occupancy();
        assert_eq!(occ.lo_slots, 0);
        assert_eq!(occ.evicted_slots, 4 * 12);
        // evicted KVs are unrecoverable
        for p in 0..4 {
            for s in 0..t {
                if m.placement(p, s) == Placement::Evicted {
                    assert!(m.effective_kv(p, s).is_none());
                }
            }
        }
    }

    #[test]
    fn append_token_demotes_down_to_budget() {
        let mut m = manager(0.25, RetentionMode::Retain);
        let mut rng = Pcg32::new(3);
        let t0 = 8;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t0, &mut rng);
        m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);
        let planes = 4usize;
        let d = 8usize;
        let s_max = 32usize;
        for step in 0..10 {
            let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            let v_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            let attn_prev: Vec<f32> = (0..planes * s_max).map(|_| rng.gen_f32() * 0.1).collect();
            let attn_self: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
            m.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &v_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
            m.check_invariants()
                .unwrap_or_else(|e| panic!("step {step}: {e}"));
            let budget = m.config().hi_budget(m.seq_len());
            let occ = m.occupancy();
            assert!(
                occ.hi_slots <= (planes * budget) as u64 + planes as u64,
                "hi {} > budget {}",
                occ.hi_slots,
                planes * budget
            );
        }
        assert_eq!(m.seq_len(), 18);
        // no token left behind: nothing evicted in Retain mode
        assert_eq!(m.occupancy().evicted_slots, 0);
    }

    #[test]
    fn recent_window_is_protected() {
        let mut m = manager(0.1, RetentionMode::Retain);
        let mut rng = Pcg32::new(4);
        let t = 20;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        // last `recent_window` slots must be hi in every plane
        for p in 0..4 {
            for s in t - 2..t {
                assert_eq!(m.placement(p, s), Placement::Hi, "plane {p} slot {s}");
            }
        }
    }

    #[test]
    fn full_config_keeps_everything_hi() {
        let cfg = CacheConfig::full(2, 2, 8, 32);
        let planes = 4;
        let policy = make_policy("h2o", planes, 32, 0).unwrap();
        let mut m = CacheManager::new(cfg, policy);
        let mut rng = Pcg32::new(5);
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), 12, &mut rng);
        m.ingest_prefill(12, &k, &v, &acc, &qmax, &kmax);
        let occ = m.occupancy();
        assert_eq!(occ.hi_slots, 4 * 12);
        assert_eq!(occ.lo_slots, 0);
        assert!((m.cache_size_pct() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn rtn_config_quantizes_almost_everything() {
        let cfg = CacheConfig::rtn(2, 2, 8, 32, Precision::Int8);
        let planes = 4;
        let policy = make_policy("h2o", planes, 32, 0).unwrap();
        let mut m = CacheManager::new(cfg, policy);
        let mut rng = Pcg32::new(6);
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), 16, &mut rng);
        m.ingest_prefill(16, &k, &v, &acc, &qmax, &kmax);
        let occ = m.occupancy();
        assert_eq!(occ.hi_slots, 4); // one recent per plane
        assert_eq!(occ.lo_slots, 4 * 15);
    }

    #[test]
    fn effective_kv_hi_is_f16_exact() {
        let mut m = manager(1.0, RetentionMode::Retain);
        let mut rng = Pcg32::new(7);
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), 4, &mut rng);
        m.ingest_prefill(4, &k, &v, &acc, &qmax, &kmax);
        let (ke, _) = m.effective_kv(0, 2).unwrap();
        // plane 0, slot 2 of the original k
        let orig = &k[2 * 8..3 * 8];
        for (a, b) in ke.iter().zip(orig) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}"); // f16 rounding only
        }
    }

    #[test]
    fn effective_kv_lo_roundtrips_balancer() {
        // With outlier awareness on, dequantized lo K must approximate the
        // ORIGINAL key (balance → quantize → dequantize → unbalance ≈ id).
        let mut m = manager(0.1, RetentionMode::Retain);
        let mut rng = Pcg32::new(8);
        let t = 16;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        let d = 8;
        let mut found_lo = false;
        for s in 0..t {
            if m.placement(0, s) == Placement::Lo {
                found_lo = true;
                let (ke, _) = m.effective_kv(0, s).unwrap();
                let orig = &k[s * d..(s + 1) * d];
                for (a, b) in ke.iter().zip(orig) {
                    assert!((a - b).abs() < 0.8, "lo slot {s}: {a} vs {b}");
                }
            }
        }
        assert!(found_lo);
    }

    #[test]
    fn views_match_masks() {
        let mut m = manager(0.5, RetentionMode::Retain);
        let mut rng = Pcg32::new(9);
        let t = 10;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        let views = m.decode_views();
        let d = 8;
        for p in 0..4 {
            for s in 0..t {
                let idx = p * 32 + s;
                let hi = views.hi_mask[idx] == 1.0;
                let lo = views.lo_mask[idx] == 1.0;
                assert!(hi ^ lo, "slot must be exactly one tier");
                if lo {
                    // lo codes are integer-valued
                    let c = &views.k_lo_codes[idx * d..(idx + 1) * d];
                    assert!(c.iter().all(|x| *x == x.trunc()));
                }
                if hi {
                    // hi slot has zero lo metadata
                    let sc = &views.k_lo_scale[idx * 2..(idx + 1) * 2];
                    assert!(sc.iter().all(|&x| x == 0.0));
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "cache full")]
    fn append_beyond_capacity_panics() {
        let mut m = manager(1.0, RetentionMode::Retain);
        let mut rng = Pcg32::new(10);
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), 32, &mut rng);
        m.ingest_prefill(32, &k, &v, &acc, &qmax, &kmax);
        let z = vec![0.0f32; 4 * 8];
        let a = vec![0.0f32; 4 * 32];
        m.append_token(StepOutputs {
            k_new: &z,
            v_new: &z,
            attn_prev: &a,
            attn_self: &z[..4],
        });
    }
}
