//! The MiKV cache manager: per-session mixed-precision tier state.
//!
//! One manager instance owns the cache of a single generation session across
//! all `layers × kv_heads` planes. It maintains **two representations** of
//! the retained tier:
//!
//! 1. the *physical* packed representation inside [`LoTier`] (bit-packed
//!    codes + FP16 metadata) — this is what the logical memory accounting
//!    charges, and what a real deployment would hold in device memory;
//! 2. a *shadow* dense representation (codes as f32-held integers, scales,
//!    zeros, masks) laid out like the decode graph's inputs — kept
//!    incrementally up to date on every admit/demote so a decode step's
//!    input assembly is a handful of plane-contiguous `memcpy`s instead of
//!    per-slot unpacking (see EXPERIMENTS.md §Perf).
//!
//! The shadow blocks are **length-aware and pooled**: they are checked out
//! of a [`BufferPool`] at the current capacity (the sequence length rounded
//! up to a power-of-two chunk, never more than `max_seq`) and grow as the
//! session decodes. Host footprint is therefore proportional to occupancy,
//! not to the compiled graph's `max_seq`; padding to `max_seq` happens once
//! per decode step inside the engine's batch assembly, not per session.
//! Dropping the manager returns the blocks to the pool so the serving
//! coordinator recycles allocations across requests.
//!
//! Lifecycle per session: [`CacheManager::ingest_prefill`] once, then
//! [`CacheManager::append_token`] per generated token. The engine reads the
//! dense blocks via [`CacheManager::decode_views`].
//!
//! Tier transitions are **bidirectional** when [`CacheConfig::promotion`]
//! is set: every `append_token` runs a promotion pass after enforcing the
//! hi budget, re-quantizing the lo slots with the strongest post-demotion
//! re-access signal back into the hi tier (swapping the coldest eligible
//! hi slot down so `hi_count ≤ hi_budget` always holds), with
//! min-residency hysteresis on both tiers so a boundary token cannot
//! thrash. Default `promotion: None` never enters that code path.

use super::accounting::{self, HostFootprint, Occupancy};
use super::dirty::{DirtyTake, DirtyTracker};
use super::merge::{fold_v_into, nearest_retained, MergeConfig, MergeLedger};
use super::pool::{BufferPool, PooledBuf};
use super::tier::{HiTier, LoTier};
use super::{CacheConfig, Placement, RetentionMode};
use crate::policies::ImportancePolicy;
use crate::quant::Balancer;

/// Cumulative promotion-pass counters for one session (reported per turn
/// on the wire and folded into the serving stats snapshot).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PromotionStats {
    /// lo→hi promotions performed.
    pub promotions: u64,
    /// Promotions the re-access signal asked for but the min-residency
    /// hysteresis blocked (the candidate's own residency, or no
    /// residency-eligible hi slot to swap down).
    pub thrash_suppressed: u64,
}

/// Smallest per-plane slot capacity the manager requests from the pool
/// (keeps tiny prompts from growing through many size classes).
const MIN_CAP_SLOTS: usize = 16;

/// Dense per-session views over the decode-graph input blocks, all plane-
/// major with **row stride [`DecodeViews::cap`]** (the pooled capacity, not
/// `max_seq`): `[planes, cap, ...]`. Only rows `0..seq_len` of each plane
/// are live; the engine's batch assembly copies that prefix into the
/// graph's `max_seq`-padded batch tensors.
pub struct DecodeViews<'a> {
    /// Live rows per plane.
    pub seq_len: usize,
    /// Allocated rows per plane — the row stride of every block below.
    pub cap: usize,
    /// Scale/zero groups per token (row stride of the metadata blocks).
    pub groups: usize,
    pub k_hi: &'a [f32],
    pub v_hi: &'a [f32],
    pub hi_mask: &'a [f32],
    pub k_lo_codes: &'a [f32],
    pub k_lo_scale: &'a [f32],
    pub k_lo_zero: &'a [f32],
    pub v_lo_codes: &'a [f32],
    pub v_lo_scale: &'a [f32],
    pub v_lo_zero: &'a [f32],
    pub lo_mask: &'a [f32],
    /// `[planes, head_dim]` — 1/b per channel (identity when outlier
    /// awareness is off).
    pub inv_balancer: &'a [f32],
}

/// Outputs of one decode step the manager needs to ingest.
pub struct StepOutputs<'a> {
    /// New token K, `[planes, head_dim]`.
    pub k_new: &'a [f32],
    /// New token V, `[planes, head_dim]`.
    pub v_new: &'a [f32],
    /// Attention the new query paid to previous slots, `[planes, max_seq]`
    /// (only `0..seq_len` is meaningful — this is the graph's padded
    /// output layout, not the manager's pooled layout).
    pub attn_prev: &'a [f32],
    /// Self-attention mass of the new token, `[planes]`.
    pub attn_self: &'a [f32],
}

/// The mixed-precision cache manager (see module docs).
pub struct CacheManager {
    cfg: CacheConfig,
    policy: Box<dyn ImportancePolicy>,
    planes: usize,
    d: usize,
    s_max: usize,
    groups: usize,

    hi: Vec<HiTier>,
    lo: Vec<LoTier>,
    balancers: Vec<Balancer>,

    // Shadow dense blocks (decode-graph input layout, plane-major with row
    // stride `cap`), checked out of `pool` and grown on demand.
    pool: BufferPool,
    cap: usize,
    k_hi_buf: PooledBuf,
    v_hi_buf: PooledBuf,
    hi_mask: PooledBuf,
    k_lo_codes: PooledBuf,
    k_lo_scale: PooledBuf,
    k_lo_zero: PooledBuf,
    v_lo_codes: PooledBuf,
    v_lo_scale: PooledBuf,
    v_lo_zero: PooledBuf,
    lo_mask: PooledBuf,
    inv_balancer: Vec<f32>,

    placement: Vec<Placement>,
    hi_count: Vec<usize>,
    /// Decode step at which each slot last changed tier, `[planes, cap]`
    /// (same stride as `placement`) — the residency clock the promotion
    /// hysteresis reads. Values are bounded by `max_seq`, so u32 suffices.
    tier_since: Vec<u32>,
    /// Decode steps ingested since prefill (the residency clock).
    step: u32,
    promo: PromotionStats,
    /// Accumulated merge mass per slot, `[planes, cap]` (same stride as
    /// `placement`); nonzero only for slots that have participated in a
    /// WeightedKV-style fold (see [`super::merge`]).
    merge_mass: Vec<f32>,
    ledger: MergeLedger,
    seq_len: usize,
    scratch_u8: Vec<u8>,
    scratch_f32: Vec<f32>,
    // Reusable `[d]` K/V staging for append/demote (kills the per-token
    // `to_vec()`s the split-borrow workaround used to make).
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
    // `[d]` staging for the merge fold: the victim's row rides in
    // `scratch_k`/`scratch_v` during `demote`, so the neighbor needs its
    // own pair.
    merge_k: Vec<f32>,
    merge_v: Vec<f32>,
    /// Shadow rows touched since the engine last synchronized this session
    /// (see [`crate::kvcache::dirty`] for the delta-assembly protocol).
    dirty: DirtyTracker,
}

impl CacheManager {
    /// Build a manager with a private buffer pool (single-session use; the
    /// serving coordinator shares one pool via [`Self::with_pool`]).
    pub fn new(cfg: CacheConfig, policy: Box<dyn ImportancePolicy>) -> Self {
        Self::with_pool(cfg, policy, BufferPool::new())
    }

    /// Build a manager whose shadow blocks come from (and return to) the
    /// given pool.
    pub fn with_pool(
        cfg: CacheConfig,
        policy: Box<dyn ImportancePolicy>,
        pool: BufferPool,
    ) -> Self {
        let planes = cfg.layers * cfg.kv_heads;
        let d = cfg.head_dim;
        let s = cfg.max_seq;
        let lo_group = cfg.lo.group.min(d);
        let groups = d / lo_group;
        let hi = (0..planes).map(|_| HiTier::new(cfg.hi, d, 0)).collect();
        let lo = (0..planes).map(|_| LoTier::new(cfg.lo, d, 0)).collect();
        Self {
            planes,
            d,
            s_max: s,
            groups,
            hi,
            lo,
            balancers: vec![Balancer::identity(d); planes],
            cap: 0,
            k_hi_buf: pool.checkout(0),
            v_hi_buf: pool.checkout(0),
            hi_mask: pool.checkout(0),
            k_lo_codes: pool.checkout(0),
            k_lo_scale: pool.checkout(0),
            k_lo_zero: pool.checkout(0),
            v_lo_codes: pool.checkout(0),
            v_lo_scale: pool.checkout(0),
            v_lo_zero: pool.checkout(0),
            lo_mask: pool.checkout(0),
            inv_balancer: vec![1.0; planes * d],
            placement: Vec::new(),
            hi_count: vec![0; planes],
            tier_since: Vec::new(),
            step: 0,
            promo: PromotionStats::default(),
            merge_mass: Vec::new(),
            ledger: MergeLedger::default(),
            seq_len: 0,
            scratch_u8: vec![0; d],
            scratch_f32: vec![0.0; d],
            scratch_k: vec![0.0; d],
            scratch_v: vec![0.0; d],
            merge_k: vec![0.0; d],
            merge_v: vec![0.0; d],
            dirty: DirtyTracker::new(),
            cfg,
            policy,
            pool,
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Current per-plane slot capacity (the pool-rounded chunk the shadow
    /// blocks are allocated at).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Scale/zero groups per token.
    pub fn groups(&self) -> usize {
        self.groups
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Pool handle the shadow blocks are checked out of.
    pub fn pool(&self) -> &BufferPool {
        &self.pool
    }

    fn slot_idx(&self, plane: usize, s: usize) -> usize {
        debug_assert!(s < self.cap, "slot {s} beyond capacity {}", self.cap);
        plane * self.cap + s
    }

    pub fn placement(&self, plane: usize, s: usize) -> Placement {
        self.placement[self.slot_idx(plane, s)]
    }

    /// Decode steps `(plane, s)` has spent in its current tier (the
    /// hysteresis clock; resets on every tier transition).
    pub fn residency(&self, plane: usize, s: usize) -> usize {
        (self.step - self.tier_since[self.slot_idx(plane, s)]) as usize
    }

    /// Cumulative promotion counters for this session.
    pub fn promotion_stats(&self) -> PromotionStats {
        self.promo
    }

    /// Cumulative merge-lifecycle counters for this session (all zero
    /// unless [`CacheConfig::merge`] is set and folds have fired).
    pub fn merge_ledger(&self) -> MergeLedger {
        self.ledger
    }

    /// Accumulated merge mass of `(plane, s)`: 0.0 unless the slot has
    /// absorbed at least one WeightedKV-style fold.
    pub fn merge_mass(&self, plane: usize, s: usize) -> f32 {
        self.merge_mass[self.slot_idx(plane, s)]
    }

    // ------------------------------------------------------------------
    // Capacity management
    // ------------------------------------------------------------------

    /// Round a needed slot count up to the pool's chunk size: the next
    /// power of two, at least [`MIN_CAP_SLOTS`], never more than `max_seq`.
    fn round_cap(&self, need: usize) -> usize {
        need.max(MIN_CAP_SLOTS)
            .next_power_of_two()
            .min(self.s_max)
    }

    /// Grow the shadow blocks, placement map and tiers to hold at least
    /// `need` slots per plane, copying the live `0..seq_len` prefix of each
    /// plane into the new stride. Old blocks return to the pool.
    fn ensure_capacity(&mut self, need: usize) {
        if need <= self.cap {
            return;
        }
        let new_cap = self.round_cap(need);
        debug_assert!(new_cap >= need && new_cap <= self.s_max);
        let (old_cap, live, planes) = (self.cap, self.seq_len, self.planes);

        fn regrow(
            pool: &BufferPool,
            block: &mut PooledBuf,
            width: usize,
            planes: usize,
            old_cap: usize,
            new_cap: usize,
            live: usize,
        ) {
            let mut grown = pool.checkout(planes * new_cap * width);
            for p in 0..planes {
                let src = p * old_cap * width;
                let dst = p * new_cap * width;
                grown[dst..dst + live * width].copy_from_slice(&block[src..src + live * width]);
            }
            *block = grown; // the old block returns to the pool on drop
        }

        regrow(&self.pool, &mut self.k_hi_buf, self.d, planes, old_cap, new_cap, live);
        regrow(&self.pool, &mut self.v_hi_buf, self.d, planes, old_cap, new_cap, live);
        regrow(&self.pool, &mut self.hi_mask, 1, planes, old_cap, new_cap, live);
        regrow(&self.pool, &mut self.k_lo_codes, self.d, planes, old_cap, new_cap, live);
        regrow(&self.pool, &mut self.k_lo_scale, self.groups, planes, old_cap, new_cap, live);
        regrow(&self.pool, &mut self.k_lo_zero, self.groups, planes, old_cap, new_cap, live);
        regrow(&self.pool, &mut self.v_lo_codes, self.d, planes, old_cap, new_cap, live);
        regrow(&self.pool, &mut self.v_lo_scale, self.groups, planes, old_cap, new_cap, live);
        regrow(&self.pool, &mut self.v_lo_zero, self.groups, planes, old_cap, new_cap, live);
        regrow(&self.pool, &mut self.lo_mask, 1, planes, old_cap, new_cap, live);

        let mut placement = vec![Placement::Empty; planes * new_cap];
        let mut tier_since = vec![0u32; planes * new_cap];
        let mut merge_mass = vec![0.0f32; planes * new_cap];
        for p in 0..planes {
            placement[p * new_cap..p * new_cap + live]
                .copy_from_slice(&self.placement[p * old_cap..p * old_cap + live]);
            tier_since[p * new_cap..p * new_cap + live]
                .copy_from_slice(&self.tier_since[p * old_cap..p * old_cap + live]);
            merge_mass[p * new_cap..p * new_cap + live]
                .copy_from_slice(&self.merge_mass[p * old_cap..p * old_cap + live]);
        }
        self.placement = placement;
        self.tier_since = tier_since;
        self.merge_mass = merge_mass;

        for hi in &mut self.hi {
            hi.ensure_capacity(new_cap);
        }
        for lo in &mut self.lo {
            lo.ensure_capacity(new_cap);
        }
        self.cap = new_cap;
    }

    // ------------------------------------------------------------------
    // Prefill ingestion
    // ------------------------------------------------------------------

    /// Ingest the prefill outputs for a prompt of length `seq_len`.
    ///
    /// Layouts (plane-major, padded to `max_seq` where noted):
    /// `k`/`v`: `[planes, seq_len, d]` (unpadded), `attn_acc`:
    /// `[planes, seq_len]`, `qmax`/`kmax`: `[planes, d]`.
    pub fn ingest_prefill(
        &mut self,
        seq_len: usize,
        k: &[f32],
        v: &[f32],
        attn_acc: &[f32],
        qmax: &[f32],
        kmax: &[f32],
    ) {
        assert!(seq_len <= self.s_max, "prompt longer than max_seq");
        assert_eq!(k.len(), self.planes * seq_len * self.d);
        assert_eq!(attn_acc.len(), self.planes * seq_len);
        assert_eq!(qmax.len(), self.planes * self.d);
        self.ensure_capacity(seq_len);
        self.seq_len = seq_len;
        // Prefill (re)starts the residency clock: every slot admitted below
        // records tier entry at step 0.
        self.step = 0;
        // Prefill rewrites every shadow row (and the balancers): any engine
        // lane holding this session must fully rescatter.
        self.dirty.mark_all();

        // 1. Channel balancers from prefill q/k maxima (paper eq. 2).
        for p in 0..self.planes {
            let bal = if self.cfg.outlier_aware {
                Balancer::from_maxima(&qmax[p * self.d..(p + 1) * self.d], &kmax[p * self.d..(p + 1) * self.d])
            } else {
                Balancer::identity(self.d)
            };
            self.inv_balancer[p * self.d..(p + 1) * self.d].copy_from_slice(&bal.inverse());
            self.balancers[p] = bal;
        }

        // 2. Importance seeding + tier placement per plane.
        let budget = self.cfg.hi_budget(seq_len);
        for p in 0..self.planes {
            let acc = &attn_acc[p * seq_len..(p + 1) * seq_len];
            self.policy.init_prefill(p, acc);
            // Attention-free signal channel: stream every prefill KV row to
            // the policy before ranking, so KV-statistics policies (LagKV)
            // score from the same prompt attention policies see via `acc`.
            for s in 0..seq_len {
                let kv_off = (p * seq_len + s) * self.d;
                self.policy.observe_kv(
                    p,
                    s,
                    &k[kv_off..kv_off + self.d],
                    &v[kv_off..kv_off + self.d],
                );
            }

            // Rank slots: recency-protected slots are always hi; the rest of
            // the budget goes to the highest-scoring slots.
            let protect_from = seq_len.saturating_sub(self.cfg.recent_window);
            let mut scored: Vec<(f32, usize)> = (0..protect_from)
                .map(|s| (self.policy.score(p, s), s))
                .collect();
            // total_cmp gives a total order (NaN sorts greatest), so a NaN
            // importance score deterministically ranks most-important and
            // stays hi instead of letting an inconsistent comparator
            // scramble the whole ranking. "NaN = keep" is the reliable
            // failure mode: over-retaining one token costs bytes, silently
            // evicting an important one costs the answer.
            scored.sort_by(|a, b| b.0.total_cmp(&a.0));
            let n_protected = seq_len - protect_from;
            let n_scored_hi = budget.saturating_sub(n_protected).min(scored.len());

            let mut is_hi = vec![false; seq_len];
            for s in protect_from..seq_len {
                is_hi[s] = true;
            }
            for &(_, s) in scored.iter().take(n_scored_hi) {
                is_hi[s] = true;
            }

            // Hi admissions first, the demoted remainder second: a merge
            // fold (Evict retention + `merge`) lands in the nearest
            // *already retained* slot, so the hi set must be in place
            // before any victim is dropped. Per-slot placement is
            // order-independent otherwise, so the merge-off paths stay
            // byte-identical.
            for s in (0..seq_len).filter(|&s| is_hi[s]) {
                let kv_off = (p * seq_len + s) * self.d;
                self.admit_hi(p, s, &k[kv_off..kv_off + self.d], &v[kv_off..kv_off + self.d]);
            }
            for s in (0..seq_len).filter(|&s| !is_hi[s]) {
                let kv_off = (p * seq_len + s) * self.d;
                self.place_lo_or_evict(
                    p,
                    s,
                    &k[kv_off..kv_off + self.d],
                    &v[kv_off..kv_off + self.d],
                );
            }
        }
    }

    // ------------------------------------------------------------------
    // Decode-step ingestion
    // ------------------------------------------------------------------

    /// Fallible [`Self::append_token`] used by the serving path. Decode
    /// ingest and multi-turn prompt **re-ingest** share this entry point:
    /// when an `append` op continues a parked session, its new prompt
    /// tokens are fed through the decode graph one by one and land here,
    /// entering the same hi/lo tiers (and importance bookkeeping) as the
    /// original prefill. A full cache is an error the coordinator maps
    /// onto the `cache_full` wire code instead of a panic.
    pub fn try_append_token(&mut self, out: StepOutputs<'_>) -> crate::Result<()> {
        anyhow::ensure!(
            self.seq_len < self.s_max,
            "cache full: {} of {} slots",
            self.seq_len,
            self.s_max
        );
        self.append_token(out);
        Ok(())
    }

    /// Ingest one decode step's outputs: update importance, admit the new
    /// token to the hi tier, demote/evict down to budget, and (when
    /// [`CacheConfig::promotion`] is set) run the lo→hi promotion pass.
    pub fn append_token(&mut self, out: StepOutputs<'_>) {
        let t = self.seq_len;
        assert!(t < self.s_max, "cache full");
        assert_eq!(out.k_new.len(), self.planes * self.d);
        assert_eq!(out.attn_prev.len(), self.planes * self.s_max);
        self.ensure_capacity(t + 1);
        self.step += 1;

        let new_len = t + 1;
        let budget = self.cfg.hi_budget(new_len);
        for p in 0..self.planes {
            // Importance update from this step's attention row (+ self mass,
            // credited as a point update — no per-token row allocation).
            let row = &out.attn_prev[p * self.s_max..p * self.s_max + t];
            self.policy.observe(p, row);
            self.policy.admit(p, t);
            self.policy.observe_at(p, t, out.attn_self[p]);
            // Attention-free signal channel (no-op for attention policies).
            let off = p * self.d;
            self.policy.observe_kv(
                p,
                t,
                &out.k_new[off..off + self.d],
                &out.v_new[off..off + self.d],
            );

            // The new token always enters hi (recent tokens are important).
            // `out` borrows caller data (not self), so the slices pass
            // straight through — no staging copy, no allocation.
            self.admit_hi(
                p,
                t,
                &out.k_new[off..off + self.d],
                &out.v_new[off..off + self.d],
            );

            // Enforce the hi budget.
            while self.hi_count[p] > budget {
                let protect_from = new_len.saturating_sub(self.cfg.recent_window.max(1));
                let mut candidates: Vec<usize> = (0..protect_from)
                    .filter(|&s| self.placement(p, s) == Placement::Hi)
                    .collect();
                if candidates.is_empty() {
                    break; // everything hi is recency-protected
                }
                // With promotion on, prefer victims that have served their
                // hi-tier min-residency — a freshly promoted slot must not
                // be the next demotion victim (thrash). The budget
                // invariant outranks the hysteresis: when every candidate
                // is young, demote among all of them anyway.
                if let Some(pcfg) = self.cfg.promotion {
                    let eligible = candidates
                        .iter()
                        .filter(|&&s| self.residency(p, s) >= pcfg.min_residency)
                        .count();
                    if eligible > 0 {
                        candidates.retain(|&s| self.residency(p, s) >= pcfg.min_residency);
                    }
                }
                let victim = self.policy.select_victim(p, &candidates);
                self.demote(p, victim);
            }

            // The demote-inverse: promote hot lo slots back to hi.
            self.promote_pass(p, new_len, budget);
        }
        self.seq_len = new_len;
    }

    // ------------------------------------------------------------------
    // Tier transitions
    // ------------------------------------------------------------------

    fn admit_hi(&mut self, p: usize, s: usize, k: &[f32], v: &[f32]) {
        let prev = self.placement(p, s);
        assert!(
            prev == Placement::Empty,
            "admit_hi into occupied slot {s} ({prev:?})"
        );
        self.hi[p].admit(s, k, v);
        // Mirror the storage-rounded values into the dense block.
        let off = (p * self.cap + s) * self.d;
        let idx = self.slot_idx(p, s);
        self.k_hi_buf[off..off + self.d].copy_from_slice(self.hi[p].k_slot(s));
        self.v_hi_buf[off..off + self.d].copy_from_slice(self.hi[p].v_slot(s));
        self.hi_mask[idx] = 1.0;
        self.hi_count[p] += 1;
        self.placement[idx] = Placement::Hi;
        self.tier_since[idx] = self.step;
        self.dirty.mark(s);
    }

    /// Demote a hi-tier slot to the retained tier (or evict, per config).
    fn demote(&mut self, p: usize, s: usize) {
        debug_assert_eq!(self.placement(p, s), Placement::Hi);
        // Stage the evictee's K/V through the reusable scratch buffers
        // (taken/restored — no per-demotion allocation).
        let mut k = std::mem::take(&mut self.scratch_k);
        let mut v = std::mem::take(&mut self.scratch_v);
        k.copy_from_slice(self.hi[p].k_slot(s));
        v.copy_from_slice(self.hi[p].v_slot(s));
        // Clear hi state.
        self.hi[p].clear(s);
        let off = (p * self.cap + s) * self.d;
        let idx = self.slot_idx(p, s);
        self.k_hi_buf[off..off + self.d].fill(0.0);
        self.v_hi_buf[off..off + self.d].fill(0.0);
        self.hi_mask[idx] = 0.0;
        self.hi_count[p] -= 1;
        self.placement[idx] = Placement::Empty;
        self.place_lo_or_evict(p, s, &k, &v);
        self.scratch_k = k;
        self.scratch_v = v;
    }

    fn place_lo_or_evict(&mut self, p: usize, s: usize, k: &[f32], v: &[f32]) {
        let idx = self.slot_idx(p, s);
        match self.cfg.retention {
            RetentionMode::Evict => match self.cfg.merge {
                // Merge-instead-of-drop: fold the victim's value mass into
                // its nearest retained neighbor (see [`super::merge`]).
                Some(mc) => self.merge_into_neighbor(p, s, v, mc),
                None => self.placement[idx] = Placement::Evicted,
            },
            RetentionMode::Retain => {
                // Balance the key before quantization (paper eq. 3).
                let k_bal = self.balancers[p].balance_key(k);
                self.lo[p].admit(s, &k_bal, v);
                self.refresh_lo_shadow(p, s);
                self.lo_mask[idx] = 1.0;
                self.placement[idx] = Placement::Lo;
            }
        }
        self.tier_since[idx] = self.step;
        // Both arms changed row `s` of the shadow (the hi clear in
        // `demote`, and/or the lo write here).
        self.dirty.mark(s);
    }

    /// The third lifecycle outcome (opt-in via [`CacheConfig::merge`]): in
    /// Evict retention, fold a demotion victim's V row into its nearest
    /// retained neighbor with attention-mass weighting instead of dropping
    /// it (WeightedKV-style — see [`super::merge`] for the math and the
    /// mass-conservation contract). In Evict mode the hi tier is the only
    /// retained tier, so the neighbor is always a hi slot: its K row is
    /// untouched (queries keep addressing it where they always did), its V
    /// row becomes the mass-weighted average re-rounded at hi precision,
    /// and the victim is marked [`Placement::Merged`]. Allocation-free —
    /// the neighbor's rows stage through the dedicated `merge_k`/`merge_v`
    /// scratch pair; the victim's row is the caller's `v` slice.
    fn merge_into_neighbor(&mut self, p: usize, s: usize, v: &[f32], mc: MergeConfig) {
        let idx = self.slot_idx(p, s);
        let base = p * self.cap;
        let plane_placement = &self.placement[base..base + self.cap];
        let neighbor = nearest_retained(s, self.cap, mc.neighbor_window, |x| {
            plane_placement[x] == Placement::Hi
        });
        let Some(n) = neighbor else {
            // Unreachable in practice: prefill places the hi set before any
            // victim, and the hi tier is never empty while tokens exist.
            // But a fold with nowhere to land must degrade to the plain
            // evict, not corrupt a mass accumulator.
            self.placement[idx] = Placement::Evicted;
            return;
        };
        let nidx = base + n;

        // Fold weights: a slot that already absorbed folds carries its own
        // mass inside the accumulator; otherwise seed its live importance
        // score now (floored at `min_mass`, and guarded finite, so weights
        // stay strictly positive whatever the policy emits).
        let mut m_v = self.merge_mass[idx];
        if m_v <= 0.0 {
            let own = self.policy.score(p, s).max(mc.min_mass);
            let own = if own.is_finite() { own } else { mc.min_mass };
            self.ledger.seeded_mass += own as f64;
            m_v = own;
        }
        let mut m_n = self.merge_mass[nidx];
        if m_n <= 0.0 {
            let own = self.policy.score(p, n).max(mc.min_mass);
            let own = if own.is_finite() { own } else { mc.min_mass };
            self.ledger.seeded_mass += own as f64;
            m_n = own;
        }

        // Stage the neighbor's rows, fold, and re-admit at hi precision
        // (storage-rounding the folded V exactly like a fresh admit).
        self.merge_k.copy_from_slice(self.hi[p].k_slot(n));
        self.merge_v.copy_from_slice(self.hi[p].v_slot(n));
        let total = fold_v_into(&mut self.merge_v, v, m_n, m_v);
        self.hi[p].admit(n, &self.merge_k, &self.merge_v);
        let noff = nidx * self.d;
        self.k_hi_buf[noff..noff + self.d].copy_from_slice(self.hi[p].k_slot(n));
        self.v_hi_buf[noff..noff + self.d].copy_from_slice(self.hi[p].v_slot(n));
        self.dirty.mark(n);

        self.merge_mass[nidx] = total;
        self.merge_mass[idx] = 0.0;
        self.placement[idx] = Placement::Merged;
        self.ledger.merges += 1;
        self.ledger.folded_mass += m_v as f64;
    }

    /// Promote a lo slot back into the hi tier: stage its dequantized K/V
    /// through the reusable scratch buffers (allocation-free slot handoff),
    /// clear the packed and shadow lo state, and re-admit at hi precision.
    ///
    /// Retention is lossy-once — the lo codes are all that survives the
    /// original demotion — so promotion re-quantizes *those* values to hi
    /// precision. What it buys is forward-looking: the slot stops being
    /// read through the lo dequant path, is exempt from further
    /// demote→requantize rounding, and the paper's invariant ("important
    /// KV pairs kept at relatively higher precision") is restored for
    /// tokens whose importance emerged late.
    fn promote(&mut self, p: usize, s: usize) {
        debug_assert_eq!(self.placement(p, s), Placement::Lo);
        let mut k = std::mem::take(&mut self.scratch_k);
        let mut v = std::mem::take(&mut self.scratch_v);
        self.lo[p].take_slot_into(s, &mut k, &mut v);
        // The lo tier stores balanced keys (paper eq. 3); undo it so the
        // hi tier holds the effective key, exactly what the attention
        // kernel (and `effective_kv`) sees.
        self.balancers[p].unbalance_key_into(&mut k);
        self.clear_lo_shadow(p, s);
        let idx = self.slot_idx(p, s);
        self.lo_mask[idx] = 0.0;
        self.placement[idx] = Placement::Empty;
        self.admit_hi(p, s, &k, &v); // stamps tier_since + dirty row
        self.scratch_k = k;
        self.scratch_v = v;
    }

    /// One plane's lo→hi promotion pass (no-op without
    /// [`CacheConfig::promotion`]). Runs after budget enforcement: up to
    /// `max_per_step` times, the hottest residency-eligible lo slot by
    /// [`crate::policies::ImportancePolicy::reaccess`] is promoted —
    /// outright when the hi tier has spare budget, otherwise by swapping
    /// down the coldest residency-eligible hi slot outside the recency
    /// window, and only when the candidate clears `promote_margin ×` the
    /// victim's signal (the hysteresis band). A promotion the signal asks
    /// for but residency blocks increments `thrash_suppressed`.
    fn promote_pass(&mut self, p: usize, new_len: usize, budget: usize) {
        let Some(pcfg) = self.cfg.promotion else { return };
        let protect_from = new_len.saturating_sub(self.cfg.recent_window.max(1));
        for _ in 0..pcfg.max_per_step {
            // Hottest lo slot: overall (to detect residency-blocked heat)
            // and among residency-eligible candidates (actionable).
            let mut best: Option<(f32, usize)> = None;
            let mut best_any: Option<(f32, usize)> = None;
            for s in 0..new_len {
                if self.placement(p, s) != Placement::Lo {
                    continue;
                }
                let r = self.policy.reaccess(p, s);
                if r <= 0.0 {
                    continue;
                }
                let beats_any = match best_any {
                    Some((br, _)) => r > br,
                    None => true,
                };
                if beats_any {
                    best_any = Some((r, s));
                }
                let beats_best = match best {
                    Some((br, _)) => r > br,
                    None => true,
                };
                if beats_best && self.residency(p, s) >= pcfg.min_residency {
                    best = Some((r, s));
                }
            }
            let Some((hottest_any, _)) = best_any else {
                break; // no lo slot has any re-access signal
            };

            // Swap victim: the coldest residency-eligible hi slot outside
            // the recency window (only needed when hi is at budget).
            let need_swap = self.hi_count[p] >= budget;
            let mut victim: Option<(f32, usize)> = None;
            if need_swap {
                for s in 0..protect_from {
                    if self.placement(p, s) != Placement::Hi
                        || self.residency(p, s) < pcfg.min_residency
                    {
                        continue;
                    }
                    let r = self.policy.reaccess(p, s);
                    let colder = match victim {
                        Some((vr, _)) => r < vr,
                        None => true,
                    };
                    if colder {
                        victim = Some((r, s));
                    }
                }
            }

            match (best, need_swap, victim) {
                // Spare hi budget: promote the hottest eligible outright.
                (Some((_, s)), false, _) => {
                    self.promote(p, s);
                    self.promo.promotions += 1;
                }
                // At budget: swap only past the hysteresis margin.
                (Some((r, s)), true, Some((vr, v))) if r > pcfg.promote_margin * vr => {
                    self.demote(p, v);
                    self.promote(p, s);
                    self.promo.promotions += 1;
                }
                // The eligible candidate sits inside the hysteresis band.
                // If a residency-blocked hotter slot WOULD clear it, only
                // the residency clock is holding the promotion back —
                // count that as suppressed thrash; either way stop.
                (Some(_), true, Some((vr, _))) => {
                    if hottest_any > pcfg.promote_margin * vr {
                        self.promo.thrash_suppressed += 1;
                    }
                    break;
                }
                // The signal asks for a promotion but residency blocks it
                // (the candidate's own clock, or no eligible swap victim):
                // count the suppressed thrash and stop.
                _ => {
                    let would_promote = match victim {
                        Some((vr, _)) => hottest_any > pcfg.promote_margin * vr,
                        None => true,
                    };
                    if would_promote {
                        self.promo.thrash_suppressed += 1;
                    }
                    break;
                }
            }
        }
    }

    /// Zero the dense shadow of one lo slot (codes + metadata) — the
    /// inverse of [`Self::refresh_lo_shadow`], used when a slot leaves the
    /// lo tier on promotion. Masked lanes must stay finite, so zeros (not
    /// garbage) are required for the HLO inputs.
    fn clear_lo_shadow(&mut self, p: usize, s: usize) {
        let d = self.d;
        let off = (p * self.cap + s) * d;
        let goff = (p * self.cap + s) * self.groups;
        self.k_lo_codes[off..off + d].fill(0.0);
        self.v_lo_codes[off..off + d].fill(0.0);
        self.k_lo_scale[goff..goff + self.groups].fill(0.0);
        self.k_lo_zero[goff..goff + self.groups].fill(0.0);
        self.v_lo_scale[goff..goff + self.groups].fill(0.0);
        self.v_lo_zero[goff..goff + self.groups].fill(0.0);
    }

    /// Rebuild the dense shadow of one lo slot from the packed tier.
    fn refresh_lo_shadow(&mut self, p: usize, s: usize) {
        let d = self.d;
        let off = (p * self.cap + s) * d;
        let goff = (p * self.cap + s) * self.groups;

        self.lo[p].k_codes_f32_into(s, &mut self.scratch_u8, &mut self.scratch_f32);
        self.k_lo_codes[off..off + d].copy_from_slice(&self.scratch_f32);
        self.lo[p].v_codes_f32_into(s, &mut self.scratch_u8, &mut self.scratch_f32);
        self.v_lo_codes[off..off + d].copy_from_slice(&self.scratch_f32);

        let (ks, kz) = self.lo[p].k_meta_slot(s);
        self.k_lo_scale[goff..goff + self.groups].copy_from_slice(ks);
        self.k_lo_zero[goff..goff + self.groups].copy_from_slice(kz);
        let (vs, vz) = self.lo[p].v_meta_slot(s);
        self.v_lo_scale[goff..goff + self.groups].copy_from_slice(vs);
        self.v_lo_zero[goff..goff + self.groups].copy_from_slice(vz);
    }

    // ------------------------------------------------------------------
    // Views & diagnostics
    // ------------------------------------------------------------------

    /// Dense plane-major views over the decode-graph inputs (row stride =
    /// [`Self::capacity`]; only `0..seq_len` rows are live — the engine's
    /// batch assembly pads to the graph's `max_seq`).
    pub fn decode_views(&self) -> DecodeViews<'_> {
        DecodeViews {
            seq_len: self.seq_len,
            cap: self.cap,
            groups: self.groups,
            k_hi: &self.k_hi_buf,
            v_hi: &self.v_hi_buf,
            hi_mask: &self.hi_mask,
            k_lo_codes: &self.k_lo_codes,
            k_lo_scale: &self.k_lo_scale,
            k_lo_zero: &self.k_lo_zero,
            v_lo_codes: &self.v_lo_codes,
            v_lo_scale: &self.v_lo_scale,
            v_lo_zero: &self.v_lo_zero,
            lo_mask: &self.lo_mask,
            inv_balancer: &self.inv_balancer,
        }
    }

    /// Drain the shadow rows touched since the last take (the engine's
    /// delta-assembly handshake — see [`crate::kvcache::dirty`]). Rows land
    /// in `out` sorted and deduplicated; with [`dirty::MAX_TRACKED_ROWS`]
    /// capacity pre-reserved in `out` this never allocates.
    ///
    /// [`dirty::MAX_TRACKED_ROWS`]: super::dirty::MAX_TRACKED_ROWS
    pub fn take_dirty_into(&mut self, out: &mut Vec<usize>) -> DirtyTake {
        self.dirty.take_into(out)
    }

    /// Current dirty-tracker sync version (diagnostics/tests).
    pub fn dirty_version(&self) -> u64 {
        self.dirty.version()
    }

    /// Allocation-free [`Self::effective_kv`]: write the effective K/V of
    /// `(plane, slot)` into caller buffers (each `[head_dim]`), borrowing
    /// hi slots directly and fused-dequantizing lo slots. Returns `false`
    /// (buffers untouched) if the slot is evicted/merged/empty — a merged
    /// slot's own row is gone; its mass is read through its neighbor.
    pub fn effective_kv_into(
        &self,
        p: usize,
        s: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> bool {
        debug_assert!(k_out.len() == self.d && v_out.len() == self.d);
        match self.placement(p, s) {
            Placement::Hi => {
                k_out.copy_from_slice(self.hi[p].k_slot(s));
                v_out.copy_from_slice(self.hi[p].v_slot(s));
                true
            }
            Placement::Lo => {
                self.lo[p].dequant_slot_into(s, k_out, v_out);
                self.balancers[p].unbalance_key_into(k_out);
                true
            }
            _ => false,
        }
    }

    /// Host-side reconstruction of what the attention kernel effectively
    /// sees for `(plane, slot)`: hi values verbatim, lo values dequantized
    /// with the balancer inverse applied to K. `None` if evicted/empty.
    /// (Allocating diagnostics wrapper over [`Self::effective_kv_into`].)
    pub fn effective_kv(&self, p: usize, s: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        let mut k = vec![0.0; self.d];
        let mut v = vec![0.0; self.d];
        self.effective_kv_into(p, s, &mut k, &mut v).then_some((k, v))
    }

    /// Tier occupancy summed over planes.
    pub fn occupancy(&self) -> Occupancy {
        let mut occ = Occupancy::default();
        for p in 0..self.planes {
            for s in 0..self.seq_len {
                match self.placement(p, s) {
                    Placement::Hi => occ.hi_slots += 1,
                    Placement::Lo => occ.lo_slots += 1,
                    // A merged slot stores no bits of its own (its value
                    // mass lives inside its neighbor's row), so for memory
                    // accounting it counts with the evicted slots.
                    Placement::Evicted | Placement::Merged => occ.evicted_slots += 1,
                    Placement::Empty => {}
                }
            }
        }
        occ
    }

    /// Current logical cache size as % of the uncompressed FP16 cache.
    pub fn cache_size_pct(&self) -> f64 {
        accounting::cache_size_pct(&self.cfg, &self.occupancy())
    }

    /// Host memory this session's cache state currently pins, measured from
    /// the live allocations (shadow blocks, tier storage, bookkeeping).
    pub fn host_footprint(&self) -> HostFootprint {
        let f32b = std::mem::size_of::<f32>();
        let shadow_bytes = (self.k_hi_buf.len()
            + self.v_hi_buf.len()
            + self.hi_mask.len()
            + self.k_lo_codes.len()
            + self.k_lo_scale.len()
            + self.k_lo_zero.len()
            + self.v_lo_codes.len()
            + self.v_lo_scale.len()
            + self.v_lo_zero.len()
            + self.lo_mask.len())
            * f32b;
        let tier_bytes = self.hi.iter().map(HiTier::host_bytes).sum::<usize>()
            + self.lo.iter().map(LoTier::host_bytes).sum::<usize>();
        let other_bytes = self.placement.len() * std::mem::size_of::<Placement>()
            + self.tier_since.len() * std::mem::size_of::<u32>()
            + self.inv_balancer.len() * f32b
            + self.balancers.iter().map(|b| b.b.len() * f32b).sum::<usize>()
            + self.scratch_u8.len()
            + self.scratch_f32.len() * f32b
            + (self.scratch_k.len() + self.scratch_v.len()) * f32b
            + (self.merge_k.len() + self.merge_v.len()) * f32b
            + self.merge_mass.len() * f32b
            + self.dirty.host_bytes();
        HostFootprint {
            shadow_bytes,
            tier_bytes,
            other_bytes,
        }
    }

    /// Invariant check used by tests and failure-injection: every slot below
    /// `seq_len` is in exactly one state consistent with the masks, and
    /// hi counts match.
    pub fn check_invariants(&self) -> Result<(), String> {
        for p in 0..self.planes {
            let mut hi_n = 0;
            for s in 0..self.cap {
                let idx = p * self.cap + s;
                let pl = self.placement[idx];
                let (hm, lm) = (self.hi_mask[idx], self.lo_mask[idx]);
                if s >= self.seq_len && pl != Placement::Empty {
                    return Err(format!("slot ({p},{s}) beyond seq_len is {pl:?}"));
                }
                match pl {
                    Placement::Hi => {
                        hi_n += 1;
                        if hm != 1.0 || lm != 0.0 {
                            return Err(format!("hi slot ({p},{s}) masks ({hm},{lm})"));
                        }
                    }
                    Placement::Lo => {
                        if hm != 0.0 || lm != 1.0 {
                            return Err(format!("lo slot ({p},{s}) masks ({hm},{lm})"));
                        }
                    }
                    Placement::Evicted | Placement::Merged | Placement::Empty => {
                        if hm != 0.0 || lm != 0.0 {
                            return Err(format!("storageless slot ({p},{s}) masks ({hm},{lm})"));
                        }
                    }
                }
                let mass = self.merge_mass[idx];
                if !mass.is_finite() || mass < 0.0 {
                    return Err(format!("slot ({p},{s}) merge mass {mass}"));
                }
            }
            if hi_n != self.hi_count[p] {
                return Err(format!("plane {p}: hi_count {} != actual {hi_n}", self.hi_count[p]));
            }
            if self.seq_len > 0 && self.hi_count[p] == 0 {
                return Err(format!("plane {p}: no hi tokens at seq_len {}", self.seq_len));
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Cold-tier snapshot (spill/restore)
    // ------------------------------------------------------------------

    /// Serialize this manager's tier state into a snapshot payload (see
    /// [`super::spill`] for the frame format). The snapshot carries the
    /// per-plane channel balancers, every live slot's placement/residency
    /// plus its tier payload (hi: the storage-rounded K/V row; lo: the
    /// packed quantization codes and per-group scale/zero metadata), the
    /// residency clock, the promotion counters, and the importance
    /// policy's opaque state blob — everything
    /// [`Self::restore_with_pool`] needs to rebuild a bit-identical
    /// manager. The shadow blocks are NOT serialized: they are derived
    /// state, rebuilt on restore.
    pub fn snapshot_into(&self, w: &mut super::spill::Writer) {
        w.put_u64(self.seq_len as u64);
        w.put_u32(self.step);
        w.put_u64(self.promo.promotions);
        w.put_u64(self.promo.thrash_suppressed);
        if self.cfg.merge.is_some() {
            // Merge ledger; the f64 totals travel as raw bits so the
            // round trip is exact.
            w.put_u64(self.ledger.merges);
            w.put_u64(self.ledger.folded_mass.to_bits());
            w.put_u64(self.ledger.seeded_mass.to_bits());
        }
        for p in 0..self.planes {
            w.put_f32_slice(&self.balancers[p].b);
        }
        for p in 0..self.planes {
            for s in 0..self.seq_len {
                let idx = p * self.cap + s;
                let pl = self.placement[idx];
                w.put_u8(match pl {
                    Placement::Hi => 0,
                    Placement::Lo => 1,
                    Placement::Evicted => 2,
                    Placement::Empty => 3,
                    Placement::Merged => 4,
                });
                w.put_u32(self.tier_since[idx]);
                if self.cfg.merge.is_some() {
                    w.put_f32(self.merge_mass[idx]);
                }
                match pl {
                    Placement::Hi => {
                        w.put_f32_slice(self.hi[p].k_slot(s));
                        w.put_f32_slice(self.hi[p].v_slot(s));
                    }
                    Placement::Lo => {
                        w.put_u32_slice(self.lo[p].k_codes_slot(s));
                        w.put_u32_slice(self.lo[p].v_codes_slot(s));
                        let (ks, kz) = self.lo[p].k_meta_slot(s);
                        w.put_f32_slice(ks);
                        w.put_f32_slice(kz);
                        let (vs, vz) = self.lo[p].v_meta_slot(s);
                        w.put_f32_slice(vs);
                        w.put_f32_slice(vz);
                    }
                    Placement::Evicted | Placement::Merged | Placement::Empty => {}
                }
            }
        }
        let mut blob = Vec::with_capacity(64);
        self.policy.export_state(&mut blob);
        w.put_bytes(&blob);
    }

    /// Rebuild a manager from a snapshot payload written by
    /// [`Self::snapshot_into`], checking shadow blocks out of `pool`.
    ///
    /// The restored manager is bit-identical to the spilled one in every
    /// input the decode graph and the tier state machine read: tier
    /// contents, placement, residency clocks, balancers, shadow blocks,
    /// policy state. The dirty tracker starts a fresh epoch (dirty-all),
    /// so the first post-restore assembly is a full rescatter and every
    /// subsequent delta step matches a never-spilled session. Hostile
    /// payloads surface as structured [`SpillError`]s — every value is
    /// validated and the result must pass [`Self::check_invariants`].
    ///
    /// [`SpillError`]: super::spill::SpillError
    pub fn restore_with_pool(
        cfg: CacheConfig,
        policy: Box<dyn ImportancePolicy>,
        pool: BufferPool,
        r: &mut super::spill::Reader<'_>,
    ) -> Result<CacheManager, super::spill::SpillError> {
        use super::spill::SpillError;
        let mut m = CacheManager::with_pool(cfg, policy, pool);
        let seq_len = r.u64()? as usize;
        if seq_len > m.s_max {
            return Err(SpillError::Incompatible("snapshot seq_len exceeds max_seq"));
        }
        m.step = r.u32()?;
        m.promo.promotions = r.u64()?;
        m.promo.thrash_suppressed = r.u64()?;
        if m.cfg.merge.is_some() {
            m.ledger.merges = r.u64()?;
            m.ledger.folded_mass = f64::from_bits(r.u64()?);
            m.ledger.seeded_mass = f64::from_bits(r.u64()?);
            if !m.ledger.folded_mass.is_finite()
                || !m.ledger.seeded_mass.is_finite()
                || m.ledger.folded_mass < 0.0
                || m.ledger.seeded_mass < 0.0
            {
                return Err(SpillError::Malformed("merge ledger"));
            }
        }
        // Sizes the blocks exactly as the live manager had them: capacity
        // growth is monotone in seq_len, so round_cap(seq_len) is the cap
        // the spilled manager ended at.
        m.ensure_capacity(seq_len);
        for p in 0..m.planes {
            r.f32_into(&mut m.balancers[p].b)?;
            if m.balancers[p].b.iter().any(|x| !x.is_finite() || *x <= 0.0) {
                return Err(SpillError::Malformed("non-positive balancer"));
            }
        }
        for p in 0..m.planes {
            for i in 0..m.d {
                // same computation as Balancer::inverse — bit-identical to
                // the spilled manager's shadow
                m.inv_balancer[p * m.d + i] = 1.0 / m.balancers[p].b[i];
            }
        }

        let words = m.lo.first().map(LoTier::words).unwrap_or(0);
        let mut kbuf = vec![0.0f32; m.d];
        let mut vbuf = vec![0.0f32; m.d];
        let mut kc = vec![0u32; words];
        let mut vc = vec![0u32; words];
        let mut ks = vec![0.0f32; m.groups];
        let mut kz = vec![0.0f32; m.groups];
        let mut vs = vec![0.0f32; m.groups];
        let mut vz = vec![0.0f32; m.groups];
        for p in 0..m.planes {
            for s in 0..seq_len {
                let idx = p * m.cap + s;
                let tag = r.u8()?;
                m.tier_since[idx] = r.u32()?;
                if m.cfg.merge.is_some() {
                    let mass = r.f32()?;
                    if !mass.is_finite() || mass < 0.0 {
                        return Err(SpillError::Malformed("merge mass"));
                    }
                    m.merge_mass[idx] = mass;
                }
                match tag {
                    0 => {
                        r.f32_into(&mut kbuf)?;
                        r.f32_into(&mut vbuf)?;
                        if kbuf.iter().chain(vbuf.iter()).any(|x| !x.is_finite()) {
                            return Err(SpillError::Malformed("non-finite hi values"));
                        }
                        // Raw writes: the spilled values are already
                        // storage-rounded; re-admitting would double-round.
                        m.hi[p].set_slot_raw(s, &kbuf, &vbuf);
                        let off = idx * m.d;
                        m.k_hi_buf[off..off + m.d].copy_from_slice(&kbuf);
                        m.v_hi_buf[off..off + m.d].copy_from_slice(&vbuf);
                        m.hi_mask[idx] = 1.0;
                        m.hi_count[p] += 1;
                        m.placement[idx] = Placement::Hi;
                    }
                    1 => {
                        r.u32_into(&mut kc)?;
                        r.u32_into(&mut vc)?;
                        r.f32_into(&mut ks)?;
                        r.f32_into(&mut kz)?;
                        r.f32_into(&mut vs)?;
                        r.f32_into(&mut vz)?;
                        if ks
                            .iter()
                            .chain(kz.iter())
                            .chain(vs.iter())
                            .chain(vz.iter())
                            .any(|x| !x.is_finite())
                        {
                            return Err(SpillError::Malformed("non-finite lo metadata"));
                        }
                        m.lo[p].set_slot_raw(s, &kc, &vc, &ks, &kz, &vs, &vz);
                        m.refresh_lo_shadow(p, s);
                        m.lo_mask[idx] = 1.0;
                        m.placement[idx] = Placement::Lo;
                    }
                    2 => m.placement[idx] = Placement::Evicted,
                    // A merged slot can only be produced with merge on; a
                    // tag-4 slot in a merge-off snapshot is hostile bytes.
                    4 if m.cfg.merge.is_some() => m.placement[idx] = Placement::Merged,
                    _ => return Err(SpillError::Malformed("placement tag")),
                }
            }
        }
        m.seq_len = seq_len;
        let blob = r.bytes()?;
        if !m.policy.import_state(blob) {
            return Err(SpillError::Malformed("policy state"));
        }
        // Restore contract: no engine lane holds this session's rows —
        // the first post-restore assembly must be a full rescatter.
        m.dirty.mark_all();
        m.check_invariants()
            .map_err(|_| SpillError::Malformed("tier invariants"))?;
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{MergeConfig, MergeLedger, PromotionConfig};
    use crate::policies::{make_policy, H2oPolicy};
    use crate::quant::Precision;
    use crate::util::rng::Pcg32;

    fn small_cfg(ratio: f64, retention: RetentionMode) -> CacheConfig {
        let mut c = CacheConfig::mikv(2, 2, 8, 32, ratio, Precision::Int4);
        c.retention = retention;
        c.recent_window = 2;
        c
    }

    /// Random prefill tensors for a config.
    fn prefill_data(
        cfg: &CacheConfig,
        t: usize,
        rng: &mut Pcg32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let planes = cfg.layers * cfg.kv_heads;
        let d = cfg.head_dim;
        let k: Vec<f32> = (0..planes * t * d).map(|_| rng.gen_normal()).collect();
        let v: Vec<f32> = (0..planes * t * d).map(|_| rng.gen_normal()).collect();
        let acc: Vec<f32> = (0..planes * t).map(|_| rng.gen_f32()).collect();
        let qmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
        let kmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
        (k, v, acc, qmax, kmax)
    }

    fn manager(ratio: f64, retention: RetentionMode) -> CacheManager {
        let cfg = small_cfg(ratio, retention);
        let planes = cfg.layers * cfg.kv_heads;
        let policy = Box::new(H2oPolicy::new(planes, cfg.max_seq));
        CacheManager::new(cfg, policy)
    }

    #[test]
    fn prefill_respects_budget_and_invariants() {
        let mut m = manager(0.25, RetentionMode::Retain);
        let mut rng = Pcg32::new(1);
        let t = 16;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        m.check_invariants().unwrap();
        let occ = m.occupancy();
        let planes = 4;
        assert_eq!(occ.total_slots(), (planes * t) as u64);
        // budget = ceil(0.25*16)=4 per plane
        assert_eq!(occ.hi_slots, (planes * 4) as u64);
        assert_eq!(occ.lo_slots, (planes * 12) as u64);
        assert_eq!(occ.evicted_slots, 0);
    }

    /// Regression for the NaN-unstable importance sort: the old
    /// `partial_cmp(..).unwrap_or(Equal)` comparator was inconsistent under
    /// NaN and could scramble the whole hi/lo ranking. With `total_cmp`,
    /// NaN sorts greatest, so a poisoned score deterministically lands the
    /// slot in the hi tier ("NaN = keep") and the rest of the ranking stays
    /// intact.
    #[test]
    fn nan_importance_score_deterministically_stays_hi() {
        let mut m = manager(0.25, RetentionMode::Retain);
        let mut rng = Pcg32::new(7);
        let t = 16;
        let (k, v, mut acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        let planes = 4;
        for p in 0..planes {
            // slot 0 is outside the recency window (recent_window = 2), so
            // only its score decides its tier.
            acc[p * t] = f32::NAN;
        }
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        m.check_invariants().unwrap();
        for p in 0..planes {
            assert_eq!(m.placement(p, 0), Placement::Hi, "plane {p}");
        }
        // the NaN slot consumed one budgeted spot, not more: budget still
        // holds (ceil(0.25 * 16) = 4 hi per plane)
        assert_eq!(m.occupancy().hi_slots, (planes * 4) as u64);
    }

    #[test]
    fn eviction_mode_discards() {
        let mut m = manager(0.25, RetentionMode::Evict);
        let mut rng = Pcg32::new(2);
        let t = 16;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        let occ = m.occupancy();
        assert_eq!(occ.lo_slots, 0);
        assert_eq!(occ.evicted_slots, 4 * 12);
        // evicted KVs are unrecoverable
        for p in 0..4 {
            for s in 0..t {
                if m.placement(p, s) == Placement::Evicted {
                    assert!(m.effective_kv(p, s).is_none());
                }
            }
        }
    }

    #[test]
    fn append_token_demotes_down_to_budget() {
        let mut m = manager(0.25, RetentionMode::Retain);
        let mut rng = Pcg32::new(3);
        let t0 = 8;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t0, &mut rng);
        m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);
        let planes = 4usize;
        let d = 8usize;
        let s_max = 32usize;
        for step in 0..10 {
            let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            let v_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            let attn_prev: Vec<f32> = (0..planes * s_max).map(|_| rng.gen_f32() * 0.1).collect();
            let attn_self: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
            m.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &v_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
            m.check_invariants()
                .unwrap_or_else(|e| panic!("step {step}: {e}"));
            let budget = m.config().hi_budget(m.seq_len());
            let occ = m.occupancy();
            assert!(
                occ.hi_slots <= (planes * budget) as u64 + planes as u64,
                "hi {} > budget {}",
                occ.hi_slots,
                planes * budget
            );
        }
        assert_eq!(m.seq_len(), 18);
        // no token left behind: nothing evicted in Retain mode
        assert_eq!(m.occupancy().evicted_slots, 0);
    }

    #[test]
    fn recent_window_is_protected() {
        let mut m = manager(0.1, RetentionMode::Retain);
        let mut rng = Pcg32::new(4);
        let t = 20;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        // last `recent_window` slots must be hi in every plane
        for p in 0..4 {
            for s in t - 2..t {
                assert_eq!(m.placement(p, s), Placement::Hi, "plane {p} slot {s}");
            }
        }
    }

    #[test]
    fn full_config_keeps_everything_hi() {
        let cfg = CacheConfig::full(2, 2, 8, 32);
        let planes = 4;
        let policy = make_policy("h2o", planes, 32, 0).unwrap();
        let mut m = CacheManager::new(cfg, policy);
        let mut rng = Pcg32::new(5);
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), 12, &mut rng);
        m.ingest_prefill(12, &k, &v, &acc, &qmax, &kmax);
        let occ = m.occupancy();
        assert_eq!(occ.hi_slots, 4 * 12);
        assert_eq!(occ.lo_slots, 0);
        assert!((m.cache_size_pct() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn rtn_config_quantizes_almost_everything() {
        let cfg = CacheConfig::rtn(2, 2, 8, 32, Precision::Int8);
        let planes = 4;
        let policy = make_policy("h2o", planes, 32, 0).unwrap();
        let mut m = CacheManager::new(cfg, policy);
        let mut rng = Pcg32::new(6);
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), 16, &mut rng);
        m.ingest_prefill(16, &k, &v, &acc, &qmax, &kmax);
        let occ = m.occupancy();
        assert_eq!(occ.hi_slots, 4); // one recent per plane
        assert_eq!(occ.lo_slots, 4 * 15);
    }

    #[test]
    fn effective_kv_hi_is_f16_exact() {
        let mut m = manager(1.0, RetentionMode::Retain);
        let mut rng = Pcg32::new(7);
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), 4, &mut rng);
        m.ingest_prefill(4, &k, &v, &acc, &qmax, &kmax);
        let (ke, _) = m.effective_kv(0, 2).unwrap();
        // plane 0, slot 2 of the original k
        let orig = &k[2 * 8..3 * 8];
        for (a, b) in ke.iter().zip(orig) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}"); // f16 rounding only
        }
    }

    #[test]
    fn effective_kv_lo_roundtrips_balancer() {
        // With outlier awareness on, dequantized lo K must approximate the
        // ORIGINAL key (balance → quantize → dequantize → unbalance ≈ id).
        let mut m = manager(0.1, RetentionMode::Retain);
        let mut rng = Pcg32::new(8);
        let t = 16;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        let d = 8;
        let mut found_lo = false;
        for s in 0..t {
            if m.placement(0, s) == Placement::Lo {
                found_lo = true;
                let (ke, _) = m.effective_kv(0, s).unwrap();
                let orig = &k[s * d..(s + 1) * d];
                for (a, b) in ke.iter().zip(orig) {
                    assert!((a - b).abs() < 0.8, "lo slot {s}: {a} vs {b}");
                }
            }
        }
        assert!(found_lo);
    }

    #[test]
    fn views_match_masks() {
        let mut m = manager(0.5, RetentionMode::Retain);
        let mut rng = Pcg32::new(9);
        let t = 10;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        let views = m.decode_views();
        assert_eq!(views.seq_len, t);
        let (cap, g) = (views.cap, views.groups);
        let d = 8;
        for p in 0..4 {
            for s in 0..t {
                let idx = p * cap + s;
                let hi = views.hi_mask[idx] == 1.0;
                let lo = views.lo_mask[idx] == 1.0;
                assert!(hi ^ lo, "slot must be exactly one tier");
                if lo {
                    // lo codes are integer-valued
                    let c = &views.k_lo_codes[idx * d..(idx + 1) * d];
                    assert!(c.iter().all(|x| *x == x.trunc()));
                }
                if hi {
                    // hi slot has zero lo metadata
                    let sc = &views.k_lo_scale[idx * g..(idx + 1) * g];
                    assert!(sc.iter().all(|&x| x == 0.0));
                }
            }
        }
    }

    #[test]
    fn host_footprint_tracks_seq_len_not_max_seq() {
        // The acceptance case: a manager compiled for max_seq = 4096 holding
        // a 64-token prefill must pin host memory proportional to 64 (the
        // pool-rounded capacity), not to 4096.
        let mut cfg = CacheConfig::mikv(2, 2, 8, 4096, 0.25, Precision::Int4);
        cfg.recent_window = 2;
        let planes = cfg.layers * cfg.kv_heads;
        let policy = Box::new(H2oPolicy::new(planes, cfg.max_seq));
        let mut m = CacheManager::new(cfg, policy);
        assert_eq!(m.capacity(), 0);

        let mut rng = Pcg32::new(11);
        let t = 64;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        m.check_invariants().unwrap();

        assert_eq!(m.capacity(), 64, "64-token prefill rounds to a 64-slot chunk");
        let fp = m.host_footprint();
        let expect = accounting::shadow_bytes(planes, 64, 8, m.groups());
        assert_eq!(fp.shadow_bytes, expect, "shadow bytes match the closed form");

        // nowhere near a dense max_seq allocation
        let dense = accounting::shadow_bytes(planes, 4096, 8, m.groups());
        assert!(
            fp.total() < dense / 16,
            "footprint {} should be far below the dense {}",
            fp.total(),
            dense
        );
    }

    #[test]
    fn capacity_grows_in_pow2_chunks_and_preserves_state() {
        let mut m = manager(0.5, RetentionMode::Retain);
        let mut rng = Pcg32::new(12);
        let t0 = 14;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t0, &mut rng);
        m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);
        assert_eq!(m.capacity(), 16);
        let before: Vec<_> = (0..t0).map(|s| m.effective_kv(0, s)).collect();

        let planes = 4usize;
        let d = 8usize;
        let s_max = 32usize;
        for _ in 0..4 {
            let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            let attn_prev = vec![0.01f32; planes * s_max];
            let attn_self = vec![0.01f32; planes];
            m.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &k_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
            m.check_invariants().unwrap();
        }
        // 14 + 4 = 18 slots → capacity doubled to 32 (== max_seq here)
        assert_eq!(m.seq_len(), 18);
        assert_eq!(m.capacity(), 32);
        // pre-growth contents survived the re-stride (modulo demotions: a
        // slot may have moved hi→lo, but it must still be present)
        for (s, kv) in before.iter().enumerate() {
            assert_eq!(kv.is_some(), m.effective_kv(0, s).is_some(), "slot {s}");
        }
    }

    #[test]
    fn dropping_manager_returns_blocks_to_shared_pool() {
        let pool = BufferPool::new();
        let cfg = small_cfg(0.5, RetentionMode::Retain);
        let planes = cfg.layers * cfg.kv_heads;
        {
            let policy = Box::new(H2oPolicy::new(planes, cfg.max_seq));
            let mut m = CacheManager::with_pool(cfg.clone(), policy, pool.clone());
            let mut rng = Pcg32::new(13);
            let (k, v, acc, qmax, kmax) = prefill_data(m.config(), 16, &mut rng);
            m.ingest_prefill(16, &k, &v, &acc, &qmax, &kmax);
        }
        let s = pool.stats();
        assert_eq!(s.outstanding_blocks, 0, "all blocks returned on drop");
        assert!(s.free_blocks > 0);

        // a second same-config session reuses the parked blocks
        let policy = Box::new(H2oPolicy::new(planes, cfg.max_seq));
        let mut m = CacheManager::with_pool(cfg, policy, pool.clone());
        let mut rng = Pcg32::new(14);
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), 16, &mut rng);
        m.ingest_prefill(16, &k, &v, &acc, &qmax, &kmax);
        m.check_invariants().unwrap();
        assert!(pool.stats().hits > 0, "second session hit the pool");
    }

    /// Paper §3.1 by construction: after ARBITRARY admit/observe/demote/
    /// **promote** sequences (random ratio / recency window / lo precision
    /// / policy / prompt length / decode steps / promotion knobs), the
    /// tier state always satisfies
    ///
    /// * per-plane hi occupancy never exceeds the importance budget
    ///   `hi_budget(seq_len)` (recency protection is inside the budget,
    ///   since `hi_budget >= min(recent_window, seq_len)`; promotion swaps
    ///   never grow the count past it);
    /// * the recency window is always hi-precision;
    /// * every demoted slot remains dequantizable to finite values — the
    ///   eviction-loss failure mode ("token left behind") is impossible in
    ///   Retain mode — and so is every promoted slot;
    /// * min-residency hysteresis: a slot is only ever promoted lo→hi
    ///   after at least `min_residency` decode steps in the lo tier;
    /// * the manager's structural invariants (masks/placement/counters)
    ///   hold after every single step.
    #[test]
    fn property_tier_invariants_under_random_sequences() {
        use crate::util::prop::{forall, Config};

        let check = |m: &CacheManager, label: &str| -> Result<(), String> {
            m.check_invariants()
                .map_err(|e| format!("{label}: {e}"))?;
            let t = m.seq_len();
            let cfg = m.config();
            let budget = cfg.hi_budget(t);
            let recent = cfg.recent_window.max(1).min(t);
            let planes = cfg.layers * cfg.kv_heads;
            for p in 0..planes {
                let hi_n = (0..t)
                    .filter(|&s| m.placement(p, s) == Placement::Hi)
                    .count();
                if hi_n > budget {
                    return Err(format!(
                        "{label}: plane {p} hi {hi_n} > budget {budget} at t={t}"
                    ));
                }
                for s in t - recent..t {
                    if m.placement(p, s) != Placement::Hi {
                        return Err(format!(
                            "{label}: recency slot ({p},{s}) is {:?} at t={t}",
                            m.placement(p, s)
                        ));
                    }
                }
                for s in 0..t {
                    match m.placement(p, s) {
                        Placement::Evicted => {
                            return Err(format!(
                                "{label}: slot ({p},{s}) evicted in Retain mode"
                            ))
                        }
                        Placement::Empty => {
                            return Err(format!("{label}: live slot ({p},{s}) empty"))
                        }
                        _ => {}
                    }
                    let (k, v) = m
                        .effective_kv(p, s)
                        .ok_or_else(|| format!("{label}: ({p},{s}) unrecoverable"))?;
                    if !k.iter().chain(v.iter()).all(|x| x.is_finite()) {
                        return Err(format!("{label}: ({p},{s}) dequantized non-finite"));
                    }
                }
            }
            Ok(())
        };

        forall(Config::default().cases(40).name("tier invariants"), |rng| {
            let max_seq = 48usize;
            let ratio = *rng.choose(&[0.1f64, 0.25, 0.5, 0.9]);
            let lo = *rng.choose(&[Precision::Int2, Precision::Int3, Precision::Int4]);
            let mut cfg = CacheConfig::mikv(2, 2, 8, max_seq, ratio, lo);
            cfg.recent_window = 1 + rng.gen_below(4) as usize;
            cfg.outlier_aware = rng.gen_bool(0.5);
            // Half the cases exercise the bidirectional lifecycle.
            // min_residency >= 1 also guarantees no same-step round trip,
            // so placement diffs below observe every transition.
            if rng.gen_bool(0.5) {
                cfg.promotion = Some(PromotionConfig {
                    max_per_step: 1 + rng.gen_below(2) as usize,
                    min_residency: 1 + rng.gen_below(3) as usize,
                    promote_margin: *rng.choose(&[1.2f32, 1.5, 2.0]),
                });
            }
            let promotion = cfg.promotion;
            let planes = cfg.layers * cfg.kv_heads;
            let policy_name = *rng.choose(&["h2o", "local", "random"]);
            let policy = make_policy(policy_name, planes, max_seq, rng.next_u64())
                .expect("known policy");
            let mut m = CacheManager::new(cfg, policy);

            let t0 = 1 + rng.gen_below(16) as usize;
            let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t0, rng);
            m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);
            check(&m, "after prefill")?;

            // External residency model: last observed placement and the
            // step each slot entered it (promotion must respect it).
            let snapshot = |m: &CacheManager| -> Vec<Vec<Placement>> {
                (0..planes)
                    .map(|p| (0..m.seq_len()).map(|s| m.placement(p, s)).collect())
                    .collect()
            };
            let mut prev = snapshot(&m);
            let mut entered = vec![vec![0usize; max_seq]; planes];

            let steps = (rng.gen_below(24) as usize).min(max_seq - t0);
            let d = m.config().head_dim;
            for step in 0..steps {
                let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
                let v_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
                let mut attn_prev: Vec<f32> =
                    (0..planes * max_seq).map(|_| rng.gen_f32() * 0.1).collect();
                // Sometimes concentrate attention on one slot so the
                // re-access EMA actually drives promotions.
                if rng.gen_bool(0.5) {
                    let hot = rng.gen_below(m.seq_len() as u32) as usize;
                    for p in 0..planes {
                        attn_prev[p * max_seq + hot] = 0.9;
                    }
                }
                let attn_self: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
                m.append_token(StepOutputs {
                    k_new: &k_new,
                    v_new: &v_new,
                    attn_prev: &attn_prev,
                    attn_self: &attn_self,
                });
                check(&m, &format!("after step {step}"))?;

                let now = snapshot(&m);
                let this_step = step + 1;
                for p in 0..planes {
                    for s in 0..m.seq_len() {
                        let old = prev[p].get(s).copied().unwrap_or(Placement::Empty);
                        let new = now[p][s];
                        if old == new {
                            continue;
                        }
                        if old == Placement::Lo && new == Placement::Hi {
                            let cfg_p = promotion.ok_or_else(|| {
                                format!("({p},{s}) promoted with promotion off")
                            })?;
                            let resided = this_step - entered[p][s];
                            crate::prop_assert!(
                                resided >= cfg_p.min_residency,
                                "({p},{s}) promoted after {resided} < min_residency {} steps",
                                cfg_p.min_residency
                            );
                        }
                        entered[p][s] = this_step;
                    }
                }
                prev = now;
            }
            if promotion.is_none() {
                crate::prop_assert!(
                    m.promotion_stats() == PromotionStats::default(),
                    "promotion-off counters moved: {:?}",
                    m.promotion_stats()
                );
            }
            Ok(())
        });
    }

    /// The delta-assembly handshake: prefill takes `all`; each append's
    /// take covers exactly the appended row plus any demoted victims; and
    /// the drained rows, applied to a stale copy of the shadow, reproduce
    /// the current shadow bit-for-bit.
    #[test]
    fn dirty_rows_cover_every_shadow_mutation() {
        let mut m = manager(0.25, RetentionMode::Retain);
        let mut rng = Pcg32::new(21);
        let t0 = 12;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t0, &mut rng);
        m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);

        let mut rows = Vec::new();
        let take = m.take_dirty_into(&mut rows);
        assert!(take.all, "first take after prefill is a full rescatter");
        assert_eq!((take.prev_version, take.version), (0, 1));

        // Snapshot the shadow, then mutate and apply only the dirty rows.
        let snap = |m: &CacheManager| -> Vec<Vec<f32>> {
            let vs = m.decode_views();
            vec![
                vs.k_hi.to_vec(), vs.v_hi.to_vec(), vs.hi_mask.to_vec(),
                vs.k_lo_codes.to_vec(), vs.k_lo_scale.to_vec(), vs.k_lo_zero.to_vec(),
                vs.v_lo_codes.to_vec(), vs.v_lo_scale.to_vec(), vs.v_lo_zero.to_vec(),
                vs.lo_mask.to_vec(),
            ]
        };
        let widths = [8usize, 8, 1, 8, 2, 2, 8, 2, 2, 1];
        let planes = 4usize;
        let mut stale = snap(&m);
        let cap_before = m.capacity();

        for _ in 0..3 {
            let k_new: Vec<f32> = (0..planes * 8).map(|_| rng.gen_normal()).collect();
            let attn_prev = vec![0.02f32; planes * 32];
            let attn_self = vec![0.02f32; planes];
            m.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &k_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
            let take = m.take_dirty_into(&mut rows);
            assert!(!take.all, "append is delta-trackable");
            assert!(!rows.is_empty(), "the appended row must be dirty");
            assert!(rows.contains(&(m.seq_len() - 1)));
            assert!(rows.iter().all(|&r| r < m.seq_len()));
            // capacity is stable in this range, so the stale copy's stride
            // still matches and a row-wise patch must reproduce the shadow
            assert_eq!(m.capacity(), cap_before);
            let now = snap(&m);
            for (b, &w) in widths.iter().enumerate() {
                for p in 0..planes {
                    for &r in &rows {
                        let o = (p * cap_before + r) * w;
                        stale[b][o..o + w].copy_from_slice(&now[b][o..o + w]);
                    }
                }
                assert_eq!(stale[b], now[b], "block {b}: dirty rows are complete");
            }
        }

        // A second consumer draining in between breaks the version chain.
        let v_before = m.dirty_version();
        let take = m.take_dirty_into(&mut rows);
        assert_eq!(take.prev_version, v_before);
        assert_eq!(take.version, v_before + 1);
    }

    /// `effective_kv_into` (borrow + fused dequant) agrees bitwise with
    /// the allocating wrapper across all placements.
    #[test]
    fn effective_kv_into_matches_wrapper() {
        let mut m = manager(0.25, RetentionMode::Retain);
        let mut rng = Pcg32::new(22);
        let t = 16;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t, &mut rng);
        m.ingest_prefill(t, &k, &v, &acc, &qmax, &kmax);
        let mut kb = vec![0.0f32; 8];
        let mut vb = vec![0.0f32; 8];
        for p in 0..4 {
            for s in 0..t {
                match m.effective_kv(p, s) {
                    Some((ke, ve)) => {
                        assert!(m.effective_kv_into(p, s, &mut kb, &mut vb));
                        assert_eq!(kb, ke, "plane {p} slot {s}");
                        assert_eq!(vb, ve, "plane {p} slot {s}");
                    }
                    None => assert!(!m.effective_kv_into(p, s, &mut kb, &mut vb)),
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Promotion (the demote-inverse path)
    // ------------------------------------------------------------------

    /// The tentpole acceptance case: a token with low attention at demote
    /// time but high attention afterwards (the late-emerging importance of
    /// LagKV / the fragility paper) is re-quantized back into the hi tier
    /// within the residency window, the hi budget is never exceeded along
    /// the way, and the early hot signal is hysteresis-suppressed (counted)
    /// rather than acted on immediately.
    #[test]
    fn promotion_recovers_late_important_token() {
        let mut cfg = small_cfg(0.25, RetentionMode::Retain);
        let pcfg = PromotionConfig {
            max_per_step: 1,
            min_residency: 2,
            promote_margin: 2.0,
        };
        cfg.promotion = Some(pcfg);
        let planes = cfg.layers * cfg.kv_heads;
        let policy = Box::new(H2oPolicy::new(planes, cfg.max_seq));
        let mut m = CacheManager::new(cfg, policy);
        let mut rng = Pcg32::new(41);
        let (t0, d, s_max) = (16usize, 8usize, 32usize);
        let x = 3usize; // the late-important token

        let (k, v, _, qmax, kmax) = prefill_data(m.config(), t0, &mut rng);
        // Importance seeding: slot X is the least important everywhere, so
        // prefill placement demotes it to the lo tier.
        let mut acc = vec![0.0f32; planes * t0];
        for p in 0..planes {
            for s in 0..t0 {
                acc[p * t0 + s] = if s == x { 0.001 } else { 0.2 + s as f32 * 0.01 };
            }
        }
        m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);
        for p in 0..planes {
            assert_eq!(m.placement(p, x), Placement::Lo, "plane {p}: X starts lo");
        }

        // Decode steps whose attention concentrates on X.
        let mut promoted_at: Option<usize> = None;
        for step in 1..=8 {
            let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            let mut attn_prev = vec![0.001f32; planes * s_max];
            for p in 0..planes {
                attn_prev[p * s_max + x] = 0.9;
            }
            let attn_self = vec![0.01f32; planes];
            m.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &k_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
            m.check_invariants()
                .unwrap_or_else(|e| panic!("step {step}: {e}"));
            let budget = m.config().hi_budget(m.seq_len());
            for p in 0..planes {
                let hi_n = (0..m.seq_len())
                    .filter(|&s| m.placement(p, s) == Placement::Hi)
                    .count();
                assert!(hi_n <= budget, "step {step} plane {p}: hi {hi_n} > {budget}");
            }
            if promoted_at.is_none()
                && (0..planes).all(|p| m.placement(p, x) == Placement::Hi)
            {
                promoted_at = Some(step);
            }
        }
        let at = promoted_at.expect("late-important token re-quantized to hi");
        assert!(
            at <= pcfg.min_residency + 4,
            "promotion within the residency window: step {at}"
        );
        let stats = m.promotion_stats();
        assert!(
            stats.promotions >= planes as u64,
            "every plane promoted X: {stats:?}"
        );
        assert!(
            stats.thrash_suppressed >= 1,
            "the pre-residency hot signal was suppressed, not acted on: {stats:?}"
        );

        // The promoted slot reads through the hi path: mask flipped, lo
        // shadow (codes + metadata) fully cleared, values finite.
        let g = m.groups();
        let cap = m.capacity();
        let views = m.decode_views();
        for p in 0..planes {
            let idx = p * cap + x;
            assert_eq!(views.hi_mask[idx], 1.0, "plane {p}");
            assert_eq!(views.lo_mask[idx], 0.0, "plane {p}");
            assert!(
                views.k_lo_scale[idx * g..(idx + 1) * g].iter().all(|&s| s == 0.0),
                "plane {p}: stale lo metadata"
            );
            assert!(
                views.k_lo_codes[idx * d..(idx + 1) * d].iter().all(|&c| c == 0.0),
                "plane {p}: stale lo codes"
            );
        }
        let (ke, ve) = m.effective_kv(0, x).expect("promoted slot readable");
        assert!(ke.iter().chain(ve.iter()).all(|f| f.is_finite()));
    }

    /// Default-off regression lock: without `promotion` in the config the
    /// promote pass never runs — zero counters, and no slot ever moves
    /// lo→hi — so the tier lifecycle is exactly the historical one-way
    /// street.
    #[test]
    fn promotion_off_is_inert() {
        let mut m = manager(0.25, RetentionMode::Retain);
        let mut rng = Pcg32::new(42);
        let t0 = 12;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t0, &mut rng);
        m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);
        let planes = 4usize;
        let (d, s_max) = (8usize, 32usize);
        let mut was_lo = vec![[false; 64]; planes];
        for _ in 0..10 {
            for p in 0..planes {
                for s in 0..m.seq_len() {
                    if m.placement(p, s) == Placement::Lo {
                        was_lo[p][s] = true;
                    }
                }
            }
            let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            // Hot attention that would trigger promotion if it were on.
            let mut attn_prev = vec![0.001f32; planes * s_max];
            for p in 0..planes {
                attn_prev[p * s_max + 1] = 0.9;
            }
            let attn_self = vec![0.01f32; planes];
            m.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &k_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
            for p in 0..planes {
                for s in 0..m.seq_len() {
                    if was_lo[p][s] {
                        assert_eq!(
                            m.placement(p, s),
                            Placement::Lo,
                            "({p},{s}) left the lo tier with promotion off"
                        );
                    }
                }
            }
        }
        assert_eq!(m.promotion_stats(), PromotionStats::default());
    }

    /// Promotion mutations are delta-trackable: with promotion firing, the
    /// drained dirty rows applied to a stale shadow copy still reproduce
    /// the live shadow bit-for-bit (the same contract PR 4 locked for
    /// append/demote, extended to the promote/swap edges).
    #[test]
    fn dirty_rows_cover_promotion_mutations() {
        let mut cfg = small_cfg(0.25, RetentionMode::Retain);
        cfg.promotion = Some(PromotionConfig {
            max_per_step: 2,
            min_residency: 1,
            promote_margin: 1.2,
        });
        let planes = cfg.layers * cfg.kv_heads;
        let policy = Box::new(H2oPolicy::new(planes, cfg.max_seq));
        let mut m = CacheManager::new(cfg, policy);
        let mut rng = Pcg32::new(43);
        let t0 = 12;
        let (k, v, _, qmax, kmax) = prefill_data(m.config(), t0, &mut rng);
        let mut acc = vec![0.2f32; planes * t0];
        for p in 0..planes {
            acc[p * t0 + 2] = 0.001; // slot 2 demotes, then becomes hot
        }
        m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);

        let mut rows = Vec::new();
        assert!(m.take_dirty_into(&mut rows).all);

        let snap = |m: &CacheManager| -> Vec<Vec<f32>> {
            let vs = m.decode_views();
            vec![
                vs.k_hi.to_vec(), vs.v_hi.to_vec(), vs.hi_mask.to_vec(),
                vs.k_lo_codes.to_vec(), vs.k_lo_scale.to_vec(), vs.k_lo_zero.to_vec(),
                vs.v_lo_codes.to_vec(), vs.v_lo_scale.to_vec(), vs.v_lo_zero.to_vec(),
                vs.lo_mask.to_vec(),
            ]
        };
        let widths = [8usize, 8, 1, 8, 2, 2, 8, 2, 2, 1];
        let mut stale = snap(&m);
        let cap = m.capacity();

        for _ in 0..3 {
            let k_new: Vec<f32> = (0..planes * 8).map(|_| rng.gen_normal()).collect();
            let mut attn_prev = vec![0.001f32; planes * 32];
            for p in 0..planes {
                attn_prev[p * 32 + 2] = 0.9;
            }
            let attn_self = vec![0.01f32; planes];
            m.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &k_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
            let take = m.take_dirty_into(&mut rows);
            assert!(!take.all, "append+promote stays delta-trackable");
            assert_eq!(m.capacity(), cap, "stride stable for the patch");
            let now = snap(&m);
            for (b, &w) in widths.iter().enumerate() {
                for p in 0..planes {
                    for &r in &rows {
                        let o = (p * cap + r) * w;
                        stale[b][o..o + w].copy_from_slice(&now[b][o..o + w]);
                    }
                }
                assert_eq!(stale[b], now[b], "block {b}: dirty rows incomplete");
            }
        }
        assert!(
            m.promotion_stats().promotions > 0,
            "the run must actually exercise promotion"
        );
    }

    // ------------------------------------------------------------------
    // Merge (the third lifecycle outcome)
    // ------------------------------------------------------------------

    /// Default-off regression lock: without `merge` in the config the
    /// Evict lifecycle is exactly the historical drop-on-demote — zero
    /// ledger, no `Merged` placements, no mass accumulators.
    #[test]
    fn merge_off_is_inert() {
        let mut m = manager(0.25, RetentionMode::Evict);
        let mut rng = Pcg32::new(52);
        let t0 = 16;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t0, &mut rng);
        m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);
        let planes = 4usize;
        let (d, s_max) = (8usize, 32usize);
        for _ in 0..6 {
            let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            let attn_prev: Vec<f32> = (0..planes * s_max).map(|_| rng.gen_f32() * 0.1).collect();
            let attn_self: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
            m.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &k_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
        }
        assert_eq!(m.merge_ledger(), MergeLedger::default());
        for p in 0..planes {
            for s in 0..m.seq_len() {
                assert_ne!(m.placement(p, s), Placement::Merged, "({p},{s})");
                assert_eq!(m.merge_mass(p, s), 0.0, "({p},{s})");
            }
        }
        assert!(m.occupancy().evicted_slots > 0, "the run must actually evict");
    }

    /// The fold itself, against a merge-off twin fed identical inputs:
    /// every slot the baseline evicts is `Merged` instead (tier decisions
    /// are untouched by the feature), K rows are bit-identical everywhere
    /// (a fold never moves a key), at least one neighbor V row absorbed
    /// mass, and the mass ledger balances against the live accumulators.
    #[test]
    fn merge_folds_victim_into_neighbor() {
        let mut cfg = small_cfg(0.25, RetentionMode::Evict);
        cfg.merge = Some(MergeConfig::default());
        let planes = cfg.layers * cfg.kv_heads;
        let policy_on = Box::new(H2oPolicy::new(planes, cfg.max_seq));
        let policy_off = Box::new(H2oPolicy::new(planes, cfg.max_seq));
        let mut on = CacheManager::new(cfg, policy_on);
        let mut off = CacheManager::new(small_cfg(0.25, RetentionMode::Evict), policy_off);

        let mut rng = Pcg32::new(51);
        let t0 = 16;
        let (k, v, acc, qmax, kmax) = prefill_data(on.config(), t0, &mut rng);
        on.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);
        off.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);
        let (d, s_max) = (8usize, 32usize);
        for _ in 0..6 {
            let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            let v_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            let attn_prev: Vec<f32> = (0..planes * s_max).map(|_| rng.gen_f32() * 0.1).collect();
            let attn_self: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
            on.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &v_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
            off.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &v_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
        }
        on.check_invariants().unwrap();

        let ledger = on.merge_ledger();
        assert!(ledger.merges > 0, "the run must actually fold");
        assert_eq!(off.merge_ledger(), MergeLedger::default());
        let t = on.seq_len();
        let mut merged_n = 0u64;
        let mut live_mass = 0.0f64;
        let mut v_diff = false;
        for p in 0..planes {
            for s in 0..t {
                live_mass += on.merge_mass(p, s) as f64;
                match off.placement(p, s) {
                    Placement::Evicted => {
                        assert_eq!(on.placement(p, s), Placement::Merged, "({p},{s})");
                        merged_n += 1;
                        assert!(on.effective_kv(p, s).is_none(), "({p},{s})");
                    }
                    Placement::Hi => {
                        assert_eq!(on.placement(p, s), Placement::Hi, "({p},{s})");
                        let (k_on, v_on) = on.effective_kv(p, s).unwrap();
                        let (k_off, v_off) = off.effective_kv(p, s).unwrap();
                        assert_eq!(k_on, k_off, "({p},{s}): a fold must never touch a K row");
                        assert!(k_on.iter().chain(v_on.iter()).all(|x| x.is_finite()));
                        if v_on != v_off {
                            v_diff = true;
                        }
                    }
                    other => panic!("baseline ({p},{s}) is {other:?} under Evict"),
                }
            }
        }
        assert_eq!(merged_n, ledger.merges, "every fold leaves exactly one Merged slot");
        assert!(v_diff, "at least one neighbor V row absorbed folded mass");
        let expect = ledger.expected_live_mass();
        assert!(
            (live_mass - expect).abs() <= expect.abs() * 1e-3 + 1e-6,
            "mass conservation: live {live_mass} vs seeded {expect}"
        );
    }

    /// Merge lifecycle property (paper's "no token left behind" for the
    /// Evict+merge arm): after arbitrary prefill/append runs — random
    /// ratio, recency window, neighbor window, policy (including the
    /// attention-free lagkv) —
    ///
    /// * structural invariants and the hi budget hold after every step;
    /// * nothing is ever plain-`Evicted`: every victim folds (a retained
    ///   neighbor always exists), so `Merged` count == ledger merges;
    /// * merged mass is conserved into neighbors: Σ live accumulators ==
    ///   Σ seeded mass (folds move mass, never mint or drop it);
    /// * every surviving slot dequantizes finite.
    #[test]
    fn property_merge_lifecycle_invariants() {
        use crate::util::prop::{forall, Config};

        forall(Config::default().cases(30).name("merge lifecycle"), |rng| {
            let max_seq = 48usize;
            let ratio = *rng.choose(&[0.1f64, 0.25, 0.5]);
            let mut cfg = CacheConfig::mikv(2, 2, 8, max_seq, ratio, Precision::Int4);
            cfg.retention = RetentionMode::Evict;
            cfg.recent_window = 1 + rng.gen_below(4) as usize;
            cfg.merge = Some(MergeConfig {
                neighbor_window: *rng.choose(&[0usize, 2, 8, 64]),
                min_mass: 1e-6,
            });
            let planes = cfg.layers * cfg.kv_heads;
            let policy_name = *rng.choose(&["h2o", "local", "random", "lagkv"]);
            let policy = make_policy(policy_name, planes, max_seq, rng.next_u64())
                .expect("known policy");
            let mut m = CacheManager::new(cfg, policy);

            let t0 = 1 + rng.gen_below(16) as usize;
            let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t0, rng);
            m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);

            let d = m.config().head_dim;
            let steps = (rng.gen_below(24) as usize).min(max_seq - t0);
            for step in 0..=steps {
                if step > 0 {
                    let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
                    let v_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
                    let attn_prev: Vec<f32> =
                        (0..planes * max_seq).map(|_| rng.gen_f32() * 0.1).collect();
                    let attn_self: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
                    m.append_token(StepOutputs {
                        k_new: &k_new,
                        v_new: &v_new,
                        attn_prev: &attn_prev,
                        attn_self: &attn_self,
                    });
                }
                m.check_invariants().map_err(|e| format!("step {step}: {e}"))?;
                let t = m.seq_len();
                let budget = m.config().hi_budget(t);
                let mut merged_n = 0u64;
                let mut live_mass = 0.0f64;
                for p in 0..planes {
                    let mut hi_n = 0usize;
                    for s in 0..t {
                        live_mass += m.merge_mass(p, s) as f64;
                        match m.placement(p, s) {
                            Placement::Hi => {
                                hi_n += 1;
                                let (kk, vv) =
                                    m.effective_kv(p, s).ok_or("hi slot unreadable")?;
                                crate::prop_assert!(
                                    kk.iter().chain(vv.iter()).all(|x| x.is_finite()),
                                    "({p},{s}) non-finite after folds"
                                );
                            }
                            Placement::Merged => merged_n += 1,
                            other => {
                                return Err(format!(
                                    "step {step}: ({p},{s}) is {other:?} under Evict+merge"
                                ))
                            }
                        }
                    }
                    crate::prop_assert!(
                        hi_n <= budget,
                        "plane {p}: hi {hi_n} > budget {budget} at t={t}"
                    );
                }
                let ledger = m.merge_ledger();
                crate::prop_assert!(
                    merged_n == ledger.merges,
                    "Merged slots {merged_n} != ledger merges {}",
                    ledger.merges
                );
                let expect = ledger.expected_live_mass();
                crate::prop_assert!(
                    (live_mass - expect).abs() <= expect.abs() * 1e-3 + 1e-6,
                    "mass leak at step {step}: live {live_mass} vs seeded {expect}"
                );
            }
            Ok(())
        });
    }

    /// Merge mutations are delta-trackable: with folds firing, the drained
    /// dirty rows applied to a stale shadow copy reproduce the live shadow
    /// bit-for-bit — the victim's hi clear AND the neighbor's folded V row
    /// both land in the take (the same contract locked for append/demote
    /// and promotion).
    #[test]
    fn dirty_rows_cover_merge_mutations() {
        let mut cfg = small_cfg(0.25, RetentionMode::Evict);
        cfg.merge = Some(MergeConfig::default());
        let planes = cfg.layers * cfg.kv_heads;
        let policy = Box::new(H2oPolicy::new(planes, cfg.max_seq));
        let mut m = CacheManager::new(cfg, policy);
        let mut rng = Pcg32::new(53);
        let t0 = 12;
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), t0, &mut rng);
        m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);

        let mut rows = Vec::new();
        assert!(m.take_dirty_into(&mut rows).all);

        let snap = |m: &CacheManager| -> Vec<Vec<f32>> {
            let vs = m.decode_views();
            vec![
                vs.k_hi.to_vec(), vs.v_hi.to_vec(), vs.hi_mask.to_vec(),
                vs.k_lo_codes.to_vec(), vs.k_lo_scale.to_vec(), vs.k_lo_zero.to_vec(),
                vs.v_lo_codes.to_vec(), vs.v_lo_scale.to_vec(), vs.v_lo_zero.to_vec(),
                vs.lo_mask.to_vec(),
            ]
        };
        let widths = [8usize, 8, 1, 8, 2, 2, 8, 2, 2, 1];
        let mut stale = snap(&m);
        let cap = m.capacity();

        for _ in 0..3 {
            let k_new: Vec<f32> = (0..planes * 8).map(|_| rng.gen_normal()).collect();
            let attn_prev: Vec<f32> = (0..planes * 32).map(|_| rng.gen_f32() * 0.1).collect();
            let attn_self: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
            m.append_token(StepOutputs {
                k_new: &k_new,
                v_new: &k_new,
                attn_prev: &attn_prev,
                attn_self: &attn_self,
            });
            let take = m.take_dirty_into(&mut rows);
            assert!(!take.all, "append+merge stays delta-trackable");
            assert_eq!(m.capacity(), cap, "stride stable for the patch");
            let now = snap(&m);
            for (b, &w) in widths.iter().enumerate() {
                for p in 0..planes {
                    for &r in &rows {
                        let o = (p * cap + r) * w;
                        stale[b][o..o + w].copy_from_slice(&now[b][o..o + w]);
                    }
                }
                assert_eq!(stale[b], now[b], "block {b}: dirty rows incomplete");
            }
        }
        assert!(m.merge_ledger().merges > 0, "the run must actually fold");
    }

    #[test]
    #[should_panic(expected = "cache full")]
    fn append_beyond_capacity_panics() {
        let mut m = manager(1.0, RetentionMode::Retain);
        let mut rng = Pcg32::new(10);
        let (k, v, acc, qmax, kmax) = prefill_data(m.config(), 32, &mut rng);
        m.ingest_prefill(32, &k, &v, &acc, &qmax, &kmax);
        let z = vec![0.0f32; 4 * 8];
        let a = vec![0.0f32; 4 * 32];
        m.append_token(StepOutputs {
            k_new: &z,
            v_new: &z,
            attn_prev: &a,
            attn_self: &z[..4],
        });
    }
}
