//! The mixed-precision KV cache — the paper's system contribution.
//!
//! A token's KV pair lives in exactly one of three states:
//!
//! * **Hi tier** — the *importance cache*: high precision (FP16 by default,
//!   optionally INT8/INT4, paper §3.3 / Table 3).
//! * **Lo tier** — the *retained cache*: the pairs an eviction policy would
//!   have discarded, kept in low-bit per-token asymmetric quantization with
//!   the outlier channel balancer (paper §3.1–3.2).
//! * **Evicted** — gone. Only the eviction *baselines* (H2O, local window)
//!   ever use this state; MiKV never fully discards a token
//!   ("no token left behind").
//!
//! Tier membership is **bidirectional** when the opt-in
//! [`PromotionConfig`] is set: besides the demote edge (hi → lo, driven by
//! the importance budget), the manager runs a *promotion* pass (lo → hi)
//! that re-quantizes the lo slots receiving the most recent attention back
//! into the hi tier, under a per-step budget and min-residency hysteresis
//! (see `ARCHITECTURE.md` for the full state machine). Default `None`
//! keeps the historical one-way lifecycle bit-for-bit.
//!
//! [`manager::CacheManager`] owns the per-session tier state, the importance
//! policy bookkeeping, the channel balancers, and produces dense
//! plane-major blocks the decode HLO graph consumes (sized to the live
//! sequence length and checked out of a shared [`pool::BufferPool`]; the
//! engine's batch assembly pads them to the compiled graph's `max_seq`).
//! [`accounting`] computes both the logical memory footprint — the paper's
//! "KV cache size %" axis — and the physical host bytes a session pins.

pub mod accounting;
pub mod dirty;
pub mod manager;
pub mod merge;
pub mod pool;
pub mod spill;
pub mod tier;

pub use accounting::HostFootprint;
pub use dirty::{DirtyTake, DirtyTracker};
pub use manager::{CacheManager, PromotionStats, StepOutputs};
pub use merge::{MergeConfig, MergeLedger};
pub use pool::{BufferPool, PoolStats, PooledBuf};
pub use spill::{SpillError, SpillResult};

use crate::quant::Precision;

/// Precision + grouping of one tier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierConfig {
    pub precision: Precision,
    /// Channels per scale/zero group (quantized tiers only).
    pub group: usize,
}

impl TierConfig {
    pub fn fp16() -> Self {
        Self {
            precision: Precision::Fp16,
            group: 0,
        }
    }

    pub fn quantized(precision: Precision, group: usize) -> Self {
        assert!(precision.is_quantized());
        assert!(group > 0);
        Self { precision, group }
    }
}

/// Opt-in configuration of the lo→hi *promotion* pass (the demote
/// inverse). A lo-tier slot whose post-demotion re-access signal
/// ([`crate::policies::ImportancePolicy::reaccess`]) dominates the coldest
/// eligible hi slot is re-quantized back into the hi tier, swapping the
/// cold slot down so the hi budget is never exceeded. Hysteresis comes
/// from two sides: a slot must sit `min_residency` decode steps in its
/// current tier before the promotion machinery may move it again, and a
/// promotion needs a `promote_margin` (> 1) advantage over the would-be
/// demotion threshold, so a boundary token cannot thrash hi⇄lo.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PromotionConfig {
    /// Maximum lo→hi promotions per plane per decode step.
    pub max_per_step: usize,
    /// Decode steps a slot must spend in its current tier before the
    /// promotion pass may move it (applies to the promoted lo slot and to
    /// the hi slot swapped down to make room).
    pub min_residency: usize,
    /// A lo slot is promoted only when its re-access signal exceeds
    /// `promote_margin ×` the signal of the coldest eligible hi slot —
    /// the separate promote/demote thresholds of the hysteresis band.
    pub promote_margin: f32,
}

impl Default for PromotionConfig {
    fn default() -> Self {
        Self {
            max_per_step: 1,
            min_residency: 4,
            promote_margin: 2.0,
        }
    }
}

/// How non-important tokens are handled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetentionMode {
    /// MiKV: demoted tokens are quantized into the lo tier.
    Retain,
    /// Eviction baseline (H2O-style): demoted tokens are discarded.
    Evict,
}

/// Where a token's KV currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    Hi,
    Lo,
    Evicted,
    /// Folded into a retained neighbor by the opt-in WeightedKV-style merge
    /// lifecycle ([`MergeConfig`]): the slot's own storage is gone (like
    /// `Evicted`) but its value mass lives on, attention-weighted, inside
    /// the neighbor's V row.
    Merged,
    /// Slot beyond the current sequence length.
    Empty,
}

/// Full cache configuration for one model.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    pub layers: usize,
    /// KV heads (≤ query heads under GQA).
    pub kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub hi: TierConfig,
    pub lo: TierConfig,
    /// Fraction of the context kept in the hi tier (the paper's
    /// "importance ratio"): hi budget at sequence length `t` is
    /// `max(ceil(ratio·t), recent_window)`.
    pub importance_ratio: f64,
    /// Most-recent tokens are always kept hi (H2O keeps a recency window
    /// alongside the heavy hitters).
    pub recent_window: usize,
    pub retention: RetentionMode,
    /// Apply the §3.2 outlier channel balancer to lo-tier keys.
    pub outlier_aware: bool,
    /// Opt-in lo→hi promotion on re-access. `None` (the default in every
    /// preset) keeps the historical one-way hi→lo lifecycle exactly.
    pub promotion: Option<PromotionConfig>,
    /// Opt-in WeightedKV-style merge: in `Evict` retention, a demotion
    /// victim folds into its nearest retained neighbor instead of being
    /// dropped (see [`MergeConfig`]). `None` (the default in every preset)
    /// keeps the drop-on-demote lifecycle bit-for-bit.
    pub merge: Option<MergeConfig>,
}

impl CacheConfig {
    /// Hi-tier token budget at sequence length `t`.
    pub fn hi_budget(&self, t: usize) -> usize {
        let by_ratio = (self.importance_ratio * t as f64).ceil() as usize;
        by_ratio.max(self.recent_window.min(t)).min(t)
    }

    /// A full-precision (no compression) configuration.
    pub fn full(layers: usize, kv_heads: usize, head_dim: usize, max_seq: usize) -> Self {
        Self {
            layers,
            kv_heads,
            head_dim,
            max_seq,
            hi: TierConfig::fp16(),
            lo: TierConfig::quantized(Precision::Int4, head_dim / 2),
            importance_ratio: 1.0,
            recent_window: 0,
            retention: RetentionMode::Retain,
            outlier_aware: true,
            promotion: None,
            merge: None,
        }
    }

    /// Paper-default MiKV: FP16 importance cache, INT2/INT4-style retained
    /// cache with group = head_dim/2 and outlier awareness on.
    pub fn mikv(
        layers: usize,
        kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        importance_ratio: f64,
        lo_precision: Precision,
    ) -> Self {
        Self {
            layers,
            kv_heads,
            head_dim,
            max_seq,
            hi: TierConfig::fp16(),
            lo: TierConfig::quantized(lo_precision, (head_dim / 2).max(1)),
            importance_ratio,
            recent_window: 4,
            retention: RetentionMode::Retain,
            outlier_aware: true,
            promotion: None,
            merge: None,
        }
    }

    /// H2O-style eviction baseline: same importance machinery, no lo tier.
    pub fn h2o(
        layers: usize,
        kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        importance_ratio: f64,
    ) -> Self {
        Self {
            retention: RetentionMode::Evict,
            ..Self::mikv(layers, kv_heads, head_dim, max_seq, importance_ratio, Precision::Int4)
        }
    }

    /// Uniform round-to-nearest quantization baseline: no importance cache,
    /// everything quantized at `precision`.
    pub fn rtn(
        layers: usize,
        kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        precision: Precision,
    ) -> Self {
        Self {
            importance_ratio: 0.0,
            recent_window: 1, // decode needs the current token visible hi
            outlier_aware: false,
            ..Self::mikv(layers, kv_heads, head_dim, max_seq, 0.0, precision)
        }
    }

    pub fn n_slots(&self) -> usize {
        self.layers * self.kv_heads * self.max_seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hi_budget_math() {
        let mut c = CacheConfig::mikv(2, 2, 8, 64, 0.25, Precision::Int2);
        c.recent_window = 4;
        assert_eq!(c.hi_budget(100), 25);
        assert_eq!(c.hi_budget(8), 4);  // recent window floor
        assert_eq!(c.hi_budget(2), 2);  // clamped to t
        c.importance_ratio = 1.0;
        assert_eq!(c.hi_budget(10), 10);
    }

    #[test]
    fn presets_are_consistent() {
        let f = CacheConfig::full(4, 8, 32, 128);
        assert_eq!(f.hi_budget(128), 128);
        let h = CacheConfig::h2o(4, 8, 32, 128, 0.2);
        assert_eq!(h.retention, RetentionMode::Evict);
        let r = CacheConfig::rtn(4, 8, 32, 128, Precision::Int8);
        assert_eq!(r.hi_budget(100), 1);
        assert!(!r.outlier_aware);
    }

    #[test]
    #[should_panic]
    fn quantized_tier_rejects_fp16() {
        TierConfig::quantized(Precision::Fp16, 8);
    }

    /// Promotion is opt-in: every preset leaves it off (the default-off
    /// regression lock — today's one-way tier lifecycle), and the default
    /// knobs form a sane hysteresis band.
    #[test]
    fn promotion_is_off_in_every_preset() {
        assert_eq!(CacheConfig::full(2, 2, 8, 32).promotion, None);
        assert_eq!(
            CacheConfig::mikv(2, 2, 8, 32, 0.25, Precision::Int4).promotion,
            None
        );
        assert_eq!(CacheConfig::h2o(2, 2, 8, 32, 0.25).promotion, None);
        assert_eq!(CacheConfig::rtn(2, 2, 8, 32, Precision::Int8).promotion, None);

        let p = PromotionConfig::default();
        assert!(p.max_per_step >= 1);
        assert!(p.min_residency >= 1);
        assert!(p.promote_margin > 1.0, "margin must open a hysteresis band");
    }

    /// Merge is opt-in: every preset leaves it off (the default-off
    /// regression lock — drop-on-demote stays bit-identical), and the
    /// default knobs are sane.
    #[test]
    fn merge_is_off_in_every_preset() {
        assert_eq!(CacheConfig::full(2, 2, 8, 32).merge, None);
        assert_eq!(
            CacheConfig::mikv(2, 2, 8, 32, 0.25, Precision::Int4).merge,
            None
        );
        assert_eq!(CacheConfig::h2o(2, 2, 8, 32, 0.25).merge, None);
        assert_eq!(CacheConfig::rtn(2, 2, 8, 32, Precision::Int8).merge, None);

        let m = MergeConfig::default();
        assert!(m.min_mass > 0.0, "mass floor keeps fold weights finite");
    }
}
