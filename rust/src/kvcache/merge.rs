//! Merge-instead-of-drop: the WeightedKV-style third lifecycle outcome.
//!
//! In `RetentionMode::Evict` a demotion victim is normally discarded — the
//! failure mode the paper's headline contrast is built on. With the opt-in
//! [`MergeConfig`] the victim instead *folds into its nearest retained
//! neighbor*: the neighbor keeps its own K row (queries keep addressing it
//! where they always did) while its V row becomes the attention-mass-weighted
//! average of both V rows (WeightedKV, PAPERS.md). Each retained slot carries
//! an accumulated merge mass so repeated folds stay correctly weighted:
//!
//! ```text
//!   V_n' = (m_n · V_n + m_v · V_v) / (m_n + m_v)      m_n' = m_n + m_v
//! ```
//!
//! where `m` is the policy's attention mass (floored at
//! [`MergeConfig::min_mass`] so signal-free policies still fold finitely),
//! plus whatever mass the slot already absorbed. The fold kernels here are
//! allocation-free — they run inside `CacheManager::append_token`'s budget
//! enforcement loop, which is decode-hot-path code (this module is in the
//! `mikv-lint` `hot-path-alloc-free` scope) — and the mass bookkeeping is
//! exact: `CacheManager`'s property suite checks that the total mass seeded
//! plus folded equals the mass held by live slots, i.e. no victim's
//! contribution is silently lost.

/// Opt-in configuration of the merge lifecycle. Meaningful only in
/// `RetentionMode::Evict` (in `Retain` mode demotions land in the lo tier
/// and nothing is ever dropped); `None` keeps drop-on-demote bit-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MergeConfig {
    /// Preferred neighbor search radius in slot-index distance. The nearest
    /// retained slot within the window is the fold target; if none exists
    /// the search widens to the whole sequence (there is always at least
    /// one retained slot — the hi tier is never empty while tokens exist),
    /// so a victim's mass is never dropped. `0` means unbounded from the
    /// start.
    pub neighbor_window: usize,
    /// Floor on a slot's attention mass when used as a fold weight. Keeps
    /// weights strictly positive (and the fold finite) under policies whose
    /// scores can be 0 — e.g. `local`'s slot 0, or `lagkv` before its lag
    /// window fills.
    pub min_mass: f32,
}

impl Default for MergeConfig {
    fn default() -> Self {
        Self {
            neighbor_window: 64,
            min_mass: 1e-6,
        }
    }
}

/// Fold a victim V row into a retained neighbor V row, in place:
/// `v_neighbor ← (m_n·v_neighbor + m_v·v_victim) / (m_n + m_v)`.
/// Both masses must be strictly positive (caller floors via
/// [`MergeConfig::min_mass`]). Returns the neighbor's new accumulated mass.
pub fn fold_v_into(v_neighbor: &mut [f32], v_victim: &[f32], m_n: f32, m_v: f32) -> f32 {
    debug_assert!(v_neighbor.len() == v_victim.len());
    debug_assert!(m_n > 0.0 && m_v > 0.0);
    let total = m_n + m_v;
    let wn = m_n / total;
    let wv = m_v / total;
    for (n, &v) in v_neighbor.iter_mut().zip(v_victim.iter()) {
        *n = wn * *n + wv * v;
    }
    total
}

/// Nearest retained slot to `victim` among `is_retained` candidates,
/// preferring the `neighbor_window` radius and widening to the whole range
/// when the window is empty. Ties (equal distance left/right) break toward
/// the *older* (lower-index) slot, matching WeightedKV's fold direction.
/// Returns `None` only when no slot except the victim is retained.
pub fn nearest_retained<F>(
    victim: usize,
    seq_len: usize,
    neighbor_window: usize,
    is_retained: F,
) -> Option<usize>
where
    F: Fn(usize) -> bool,
{
    let window = if neighbor_window == 0 {
        seq_len
    } else {
        neighbor_window
    };
    for radius in 1..seq_len.max(1) {
        let widened = radius > window;
        let below = victim.checked_sub(radius);
        let above = victim + radius;
        if let Some(b) = below {
            if is_retained(b) {
                return Some(b);
            }
        }
        if above < seq_len && is_retained(above) {
            return Some(above);
        }
        // Window exhausted with no hit: keep widening — dropping mass is
        // worse than a far fold. (`widened` only documents the phase.)
        let _ = widened;
    }
    None
}

/// Running totals of the merge lifecycle, reported through session stats
/// and checked by the mass-conservation property test.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MergeLedger {
    /// Completed folds (victim → neighbor).
    pub merges: u64,
    /// Σ of victim masses moved into neighbors (flow diagnostic; a victim
    /// that had itself absorbed earlier folds moves its whole accumulator).
    pub folded_mass: f64,
    /// Σ of first-touch masses: a slot's *own* attention mass enters the
    /// accumulator system exactly once, the first time it participates in
    /// a fold (as victim or as neighbor). Folds after that only move
    /// already-seeded mass around, so this is the conserved total.
    pub seeded_mass: f64,
}

impl MergeLedger {
    /// The mass the live per-slot accumulators must sum to (up to f32
    /// accumulation error): exactly what was seeded — folds move mass
    /// between accumulators, they never create or destroy it.
    pub fn expected_live_mass(&self) -> f64 {
        self.seeded_mass
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_is_weighted_average() {
        let mut n = [1.0f32, 0.0, 2.0];
        let v = [3.0f32, 4.0, 2.0];
        let total = fold_v_into(&mut n, &v, 1.0, 3.0);
        assert_eq!(total, 4.0);
        assert!((n[0] - (0.25 * 1.0 + 0.75 * 3.0)).abs() < 1e-6);
        assert!((n[1] - 3.0).abs() < 1e-6);
        assert!((n[2] - 2.0).abs() < 1e-6, "equal rows are a fixed point");
    }

    #[test]
    fn fold_mass_accumulates_across_repeated_folds() {
        // folding three unit-mass victims one by one equals the 4-way mean
        let mut n = [0.0f32];
        let mut m = 1.0f32;
        for &x in &[4.0f32, 8.0, 12.0] {
            m = fold_v_into(&mut n, &[x], m, 1.0);
        }
        assert_eq!(m, 4.0);
        assert!((n[0] - 6.0).abs() < 1e-5, "got {}", n[0]);
    }

    #[test]
    fn nearest_prefers_window_then_widens() {
        let retained = [false, false, true, false, false, false, false, true];
        let f = |s: usize| retained[s];
        // victim 4: slot 2 at distance 2 beats slot 7 at distance 3
        assert_eq!(nearest_retained(4, 8, 64, f), Some(2));
        // tight window of 1 finds nothing near victim 5 → widens to slot 7
        assert_eq!(nearest_retained(5, 8, 1, f), Some(7));
        // equal distances tie toward the older slot
        let both = [false, false, true, false, true];
        assert_eq!(nearest_retained(3, 5, 64, |s| both[s]), Some(2));
        // nothing retained at all
        assert_eq!(nearest_retained(3, 8, 64, |_| false), None);
        // unbounded window
        assert_eq!(nearest_retained(0, 8, 0, f), Some(2));
    }

    #[test]
    fn ledger_expectation_is_conserved_seeded_mass() {
        let l = MergeLedger {
            merges: 3,
            folded_mass: 2.5,
            seeded_mass: 1.25,
        };
        assert_eq!(l.expected_live_mass(), 1.25, "folds move mass, never mint it");
        assert_eq!(MergeLedger::default().expected_live_mass(), 0.0);
    }
}
