//! Versioned, checksummed snapshot codec for parked sessions (the cold
//! tier's wire format).
//!
//! A spilled session is one self-contained binary frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "MKVS"
//! 4       4     format version (u32 le)
//! 8       8     payload length (u64 le)
//! 16      8     FNV-1a 64 checksum of the payload (u64 le)
//! 24      n     payload
//! ```
//!
//! The payload serializes the session header (id, token history, prompt
//! length, mode tag), the cache configuration, and the cache body: for a
//! MiKV session the per-plane channel balancers plus each live slot's
//! placement, residency clock, and tier payload (hi: storage-rounded K/V
//! rows; lo: packed quantization codes + per-group scale/zero metadata),
//! followed by the importance policy's opaque state blob; for the
//! Full/Oracle baselines the dense K/V prefix. Restore rebuilds a pooled
//! [`CacheManager`] (or [`FullCache`]) bit-identical to the spilled one —
//! see `ARCHITECTURE.md` §Cold tier for the restore contract.
//!
//! Decoding is hardened against hostile bytes: every read is bounds-
//! checked, every enum tag and float validated, and the restored manager
//! must pass `check_invariants` before it is handed back. Corruption
//! surfaces as a structured [`SpillError`], never a panic — this module is
//! inside the `panic-free-serving` and `hot-path-alloc-free` lint scopes.

use super::manager::CacheManager;
use super::pool::BufferPool;
use super::{CacheConfig, MergeConfig, PromotionConfig, RetentionMode, TierConfig};
use crate::model::session::{CacheMode, FullCache, Session, SessionCache};
use crate::policies::make_policy;
use crate::quant::Precision;
use crate::runtime::ModelDims;

/// Frame magic: "MKVS" (MiKV Snapshot).
pub const MAGIC: [u8; 4] = *b"MKVS";
/// Current snapshot format version. Bump on any layout change; decoders
/// reject other versions with [`SpillError::UnsupportedVersion`].
/// v2: cache config gained the merge flag byte (and merge-enabled
/// snapshots carry the ledger + per-slot fold masses).
pub const VERSION: u32 = 2;
/// Frame header length in bytes (magic + version + payload len + checksum).
pub const HEADER_LEN: usize = 24;

/// FNV-1a 64 over a byte slice — the frame checksum. Not cryptographic;
/// it guards against truncation, bit rot and torn writes, which is what a
/// local spill directory actually faces.
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Structured decode failure. Every hostile input maps onto one of these;
/// the decoder never panics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpillError {
    /// The input ended before a required field.
    Truncated { needed: usize, have: usize },
    /// The frame does not start with [`MAGIC`].
    BadMagic,
    /// The frame's format version is not [`VERSION`].
    UnsupportedVersion(u32),
    /// The payload checksum does not match the header.
    ChecksumMismatch,
    /// A field decoded but its value is structurally invalid (bad enum
    /// tag, non-finite float, inconsistent lengths, ...).
    Malformed(&'static str),
    /// The snapshot is well-formed but does not fit this worker's model
    /// (mismatched dims or an over-long sequence).
    Incompatible(&'static str),
}

impl std::fmt::Display for SpillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpillError::Truncated { needed, have } => {
                write!(f, "snapshot truncated: needed {needed} bytes, have {have}")
            }
            SpillError::BadMagic => write!(f, "not a snapshot (bad magic)"),
            SpillError::UnsupportedVersion(v) => {
                write!(f, "unsupported snapshot version {v}")
            }
            SpillError::ChecksumMismatch => write!(f, "snapshot checksum mismatch"),
            SpillError::Malformed(what) => write!(f, "malformed snapshot: {what}"),
            SpillError::Incompatible(what) => write!(f, "incompatible snapshot: {what}"),
        }
    }
}

impl std::error::Error for SpillError {}

pub type SpillResult<T> = Result<T, SpillError>;

// ----------------------------------------------------------------------
// Writer
// ----------------------------------------------------------------------

/// Little-endian payload writer. Finish with [`Writer::into_frame`] to get
/// the headered, checksummed byte frame.
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn with_capacity(n: usize) -> Self {
        Self {
            buf: Vec::with_capacity(n),
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32_slice(&mut self, xs: &[f32]) {
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn put_u32_slice(&mut self, xs: &[u32]) {
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed (u64) raw bytes.
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.put_u64(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }

    /// Seal the payload into a headered, checksummed frame.
    pub fn into_frame(self) -> Vec<u8> {
        let sum = checksum(&self.buf);
        let mut out = Vec::with_capacity(self.buf.len() + HEADER_LEN);
        out.extend_from_slice(MAGIC.as_slice());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.buf.len() as u64).to_le_bytes());
        out.extend_from_slice(&sum.to_le_bytes());
        out.extend_from_slice(&self.buf);
        out
    }
}

// ----------------------------------------------------------------------
// Reader
// ----------------------------------------------------------------------

/// Bounds-checked little-endian payload reader over a validated frame's
/// payload (see [`open_frame`]).
pub struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Bytes left to read.
    pub fn remaining(&self) -> usize {
        self.bytes.len().saturating_sub(self.pos)
    }

    /// Error unless the payload was consumed exactly.
    pub fn finish(&self) -> SpillResult<()> {
        if self.remaining() != 0 {
            return Err(SpillError::Malformed("trailing payload bytes"));
        }
        Ok(())
    }

    fn take(&mut self, n: usize) -> SpillResult<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or(SpillError::Malformed("length overflow"))?;
        let have = self.remaining();
        let s = self
            .bytes
            .get(self.pos..end)
            .ok_or(SpillError::Truncated { needed: n, have })?;
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> SpillResult<u8> {
        let s = self.take(1)?;
        s.first().copied().ok_or(SpillError::Truncated { needed: 1, have: 0 })
    }

    pub fn u32(&mut self) -> SpillResult<u32> {
        let s = self.take(4)?;
        let mut a = [0u8; 4];
        a.copy_from_slice(s);
        Ok(u32::from_le_bytes(a))
    }

    pub fn u64(&mut self) -> SpillResult<u64> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }

    pub fn i64(&mut self) -> SpillResult<i64> {
        Ok(self.u64()? as i64)
    }

    pub fn f32(&mut self) -> SpillResult<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> SpillResult<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Fill `out` exactly from the stream (allocation-free bulk read).
    pub fn f32_into(&mut self, out: &mut [f32]) -> SpillResult<()> {
        let n = out
            .len()
            .checked_mul(4)
            .ok_or(SpillError::Malformed("length overflow"))?;
        let s = self.take(n)?;
        for (dst, chunk) in out.iter_mut().zip(s.chunks_exact(4)) {
            let mut a = [0u8; 4];
            a.copy_from_slice(chunk);
            *dst = f32::from_le_bytes(a);
        }
        Ok(())
    }

    /// Fill `out` exactly from the stream (allocation-free bulk read).
    pub fn u32_into(&mut self, out: &mut [u32]) -> SpillResult<()> {
        let n = out
            .len()
            .checked_mul(4)
            .ok_or(SpillError::Malformed("length overflow"))?;
        let s = self.take(n)?;
        for (dst, chunk) in out.iter_mut().zip(s.chunks_exact(4)) {
            let mut a = [0u8; 4];
            a.copy_from_slice(chunk);
            *dst = u32::from_le_bytes(a);
        }
        Ok(())
    }

    /// Length-prefixed raw bytes (length validated against the remainder
    /// before any allocation or copy can happen downstream).
    pub fn bytes(&mut self) -> SpillResult<&'a [u8]> {
        let n = self.u64()?;
        let have = self.remaining();
        if n > have as u64 {
            return Err(SpillError::Truncated { needed: n as usize, have });
        }
        self.take(n as usize)
    }

    /// Length-prefixed UTF-8 string.
    pub fn take_str(&mut self) -> SpillResult<&'a str> {
        std::str::from_utf8(self.bytes()?).map_err(|_| SpillError::Malformed("invalid utf-8"))
    }
}

/// Validate a frame (magic, version, length, checksum) and return a reader
/// over its payload.
pub fn open_frame(bytes: &[u8]) -> SpillResult<Reader<'_>> {
    let mut r = Reader { bytes, pos: 0 };
    let magic = r.take(4)?;
    if magic != MAGIC.as_slice() {
        return Err(SpillError::BadMagic);
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(SpillError::UnsupportedVersion(version));
    }
    let len = r.u64()?;
    let sum = r.u64()?;
    let have = r.remaining();
    if len > have as u64 {
        return Err(SpillError::Truncated { needed: len as usize, have });
    }
    let payload = r.take(len as usize)?;
    if r.remaining() != 0 {
        return Err(SpillError::Malformed("trailing bytes after frame"));
    }
    if checksum(payload) != sum {
        return Err(SpillError::ChecksumMismatch);
    }
    Ok(Reader { bytes: payload, pos: 0 })
}

// ----------------------------------------------------------------------
// Config codecs
// ----------------------------------------------------------------------

fn precision_tag(p: Precision) -> u8 {
    match p {
        Precision::Fp16 => 0,
        Precision::Int8 => 1,
        Precision::Int4 => 2,
        Precision::Int3 => 3,
        Precision::Int2 => 4,
    }
}

fn precision_from(tag: u8) -> SpillResult<Precision> {
    match tag {
        0 => Ok(Precision::Fp16),
        1 => Ok(Precision::Int8),
        2 => Ok(Precision::Int4),
        3 => Ok(Precision::Int3),
        4 => Ok(Precision::Int2),
        _ => Err(SpillError::Malformed("precision tag")),
    }
}

fn put_tier(w: &mut Writer, t: &TierConfig) {
    w.put_u8(precision_tag(t.precision));
    w.put_u64(t.group as u64);
}

fn read_tier(r: &mut Reader<'_>, head_dim: usize) -> SpillResult<TierConfig> {
    let precision = precision_from(r.u8()?)?;
    let group = r.u64()? as usize;
    if precision.is_quantized() && (group == 0 || group > head_dim || head_dim % group != 0) {
        return Err(SpillError::Malformed("tier group does not divide head_dim"));
    }
    Ok(TierConfig { precision, group })
}

fn put_cache_config(w: &mut Writer, c: &CacheConfig) {
    w.put_u64(c.layers as u64);
    w.put_u64(c.kv_heads as u64);
    w.put_u64(c.head_dim as u64);
    w.put_u64(c.max_seq as u64);
    put_tier(w, &c.hi);
    put_tier(w, &c.lo);
    w.put_f64(c.importance_ratio);
    w.put_u64(c.recent_window as u64);
    w.put_u8(match c.retention {
        RetentionMode::Retain => 0,
        RetentionMode::Evict => 1,
    });
    w.put_u8(c.outlier_aware as u8);
    match c.promotion {
        None => w.put_u8(0),
        Some(p) => {
            w.put_u8(1);
            w.put_u64(p.max_per_step as u64);
            w.put_u64(p.min_residency as u64);
            w.put_f32(p.promote_margin);
        }
    }
    match c.merge {
        None => w.put_u8(0),
        Some(m) => {
            w.put_u8(1);
            w.put_u64(m.neighbor_window as u64);
            w.put_f32(m.min_mass);
        }
    }
}

fn read_cache_config(r: &mut Reader<'_>) -> SpillResult<CacheConfig> {
    let layers = r.u64()? as usize;
    let kv_heads = r.u64()? as usize;
    let head_dim = r.u64()? as usize;
    let max_seq = r.u64()? as usize;
    if layers == 0 || kv_heads == 0 || head_dim == 0 || max_seq == 0 {
        return Err(SpillError::Malformed("zero cache dimension"));
    }
    let hi = read_tier(r, head_dim)?;
    let lo = read_tier(r, head_dim)?;
    if !lo.precision.is_quantized() {
        return Err(SpillError::Malformed("lo tier must be quantized"));
    }
    let importance_ratio = r.f64()?;
    if !importance_ratio.is_finite() || importance_ratio < 0.0 {
        return Err(SpillError::Malformed("importance ratio"));
    }
    let recent_window = r.u64()? as usize;
    let retention = match r.u8()? {
        0 => RetentionMode::Retain,
        1 => RetentionMode::Evict,
        _ => return Err(SpillError::Malformed("retention tag")),
    };
    let outlier_aware = match r.u8()? {
        0 => false,
        1 => true,
        _ => return Err(SpillError::Malformed("outlier flag")),
    };
    let promotion = match r.u8()? {
        0 => None,
        1 => {
            let max_per_step = r.u64()? as usize;
            let min_residency = r.u64()? as usize;
            let promote_margin = r.f32()?;
            if !promote_margin.is_finite() {
                return Err(SpillError::Malformed("promote margin"));
            }
            Some(PromotionConfig {
                max_per_step,
                min_residency,
                promote_margin,
            })
        }
        _ => return Err(SpillError::Malformed("promotion flag")),
    };
    let merge = match r.u8()? {
        0 => None,
        1 => {
            let neighbor_window = r.u64()? as usize;
            let min_mass = r.f32()?;
            if !min_mass.is_finite() || min_mass <= 0.0 {
                return Err(SpillError::Malformed("merge min_mass"));
            }
            Some(MergeConfig {
                neighbor_window,
                min_mass,
            })
        }
        _ => return Err(SpillError::Malformed("merge flag")),
    };
    Ok(CacheConfig {
        layers,
        kv_heads,
        head_dim,
        max_seq,
        hi,
        lo,
        importance_ratio,
        recent_window,
        retention,
        outlier_aware,
        promotion,
        merge,
    })
}

// ----------------------------------------------------------------------
// Full-cache body
// ----------------------------------------------------------------------

fn put_full_cache(w: &mut Writer, f: &FullCache) -> SpillResult<()> {
    let (planes, d, s_max, t) = (f.planes(), f.head_dim(), f.max_seq(), f.seq_len);
    w.put_u64(planes as u64);
    w.put_u64(d as u64);
    w.put_u64(s_max as u64);
    w.put_u64(t as u64);
    // Only the live `0..t` prefix of each plane is serialized; the mask is
    // derivable (live prefix = 1.0) and not stored.
    for p in 0..planes {
        let start = p * s_max * d;
        let row = f
            .k
            .get(start..start + t * d)
            .ok_or(SpillError::Malformed("full cache layout"))?;
        w.put_f32_slice(row);
    }
    for p in 0..planes {
        let start = p * s_max * d;
        let row = f
            .v
            .get(start..start + t * d)
            .ok_or(SpillError::Malformed("full cache layout"))?;
        w.put_f32_slice(row);
    }
    Ok(())
}

fn read_full_cache(r: &mut Reader<'_>, dims: &ModelDims) -> SpillResult<FullCache> {
    let mut f = FullCache::new(dims);
    let planes = r.u64()? as usize;
    let d = r.u64()? as usize;
    let s_max = r.u64()? as usize;
    let t = r.u64()? as usize;
    if planes != f.planes() || d != f.head_dim() || s_max != f.max_seq() {
        return Err(SpillError::Incompatible("full cache does not match model dims"));
    }
    if t > s_max {
        return Err(SpillError::Incompatible("seq_len exceeds max_seq"));
    }
    for p in 0..planes {
        let start = p * s_max * d;
        let row = f
            .k
            .get_mut(start..start + t * d)
            .ok_or(SpillError::Malformed("full cache layout"))?;
        r.f32_into(row)?;
        if row.iter().any(|x| !x.is_finite()) {
            return Err(SpillError::Malformed("non-finite cache values"));
        }
    }
    for p in 0..planes {
        let start = p * s_max * d;
        let row = f
            .v
            .get_mut(start..start + t * d)
            .ok_or(SpillError::Malformed("full cache layout"))?;
        r.f32_into(row)?;
        if row.iter().any(|x| !x.is_finite()) {
            return Err(SpillError::Malformed("non-finite cache values"));
        }
    }
    for p in 0..planes {
        let m = f
            .mask
            .get_mut(p * s_max..p * s_max + t)
            .ok_or(SpillError::Malformed("full cache layout"))?;
        m.fill(1.0);
    }
    f.seq_len = t;
    // Restore contract: no engine lane can hold this cache's rows, so the
    // first post-restore assembly must be a full rescatter.
    f.mark_all_dirty();
    Ok(f)
}

// ----------------------------------------------------------------------
// Session codec
// ----------------------------------------------------------------------

/// Serialize a session into a checksummed snapshot frame.
pub fn encode_session(sess: &Session) -> SpillResult<Vec<u8>> {
    let mut w = Writer::with_capacity(
        sess.cache.host_bytes() / 2 + sess.tokens.len() * 8 + 256,
    );
    w.put_u64(sess.id);
    w.put_u64(sess.tokens.len() as u64);
    for &t in &sess.tokens {
        w.put_i64(t);
    }
    w.put_u64(sess.prompt_len as u64);
    w.put_i64(sess.last_token);
    w.put_u8(sess.done as u8);
    match (&sess.mode, &sess.cache) {
        (CacheMode::Mikv { policy, .. }, SessionCache::Mikv(m)) => {
            w.put_u8(0);
            w.put_str(policy);
            put_cache_config(&mut w, m.config());
            m.snapshot_into(&mut w);
        }
        (CacheMode::Full, SessionCache::Full(f)) => {
            w.put_u8(1);
            put_full_cache(&mut w, f)?;
        }
        (CacheMode::Oracle { k }, SessionCache::Full(f)) => {
            w.put_u8(2);
            w.put_u64(*k as u64);
            put_full_cache(&mut w, f)?;
        }
        _ => return Err(SpillError::Malformed("session mode/cache mismatch")),
    }
    Ok(w.into_frame())
}

/// Decode a snapshot frame back into a live session whose cache blocks are
/// checked out of `pool`. The restored cache is bit-identical to the
/// spilled one; its dirty tracker starts a fresh epoch (dirty-all), so the
/// first post-restore decode assembly is a full rescatter and every
/// subsequent delta step matches a never-spilled session exactly.
pub fn decode_session(
    bytes: &[u8],
    dims: &ModelDims,
    pool: &BufferPool,
) -> SpillResult<Session> {
    let mut r = open_frame(bytes)?;
    let id = r.u64()?;
    let n_tokens = r.u64()?;
    let have = r.remaining();
    if n_tokens > (have / 8) as u64 {
        return Err(SpillError::Truncated {
            needed: (n_tokens as usize).saturating_mul(8),
            have,
        });
    }
    let mut tokens = Vec::with_capacity(n_tokens as usize);
    for _ in 0..n_tokens {
        tokens.push(r.i64()?);
    }
    let prompt_len = r.u64()? as usize;
    if prompt_len > tokens.len() {
        return Err(SpillError::Malformed("prompt_len exceeds token count"));
    }
    let last_token = r.i64()?;
    let done = match r.u8()? {
        0 => false,
        1 => true,
        _ => return Err(SpillError::Malformed("done flag")),
    };
    let (mode, cache) = match r.u8()? {
        0 => {
            let policy_name = r.take_str()?.to_string();
            let cfg = read_cache_config(&mut r)?;
            if cfg.layers != dims.n_layers
                || cfg.kv_heads != dims.n_kv_heads
                || cfg.head_dim != dims.d_head
                || cfg.max_seq != dims.max_seq
            {
                return Err(SpillError::Incompatible("cache config does not match model dims"));
            }
            let planes = cfg.layers * cfg.kv_heads;
            let policy = make_policy(&policy_name, planes, cfg.max_seq, id)
                .ok_or(SpillError::Malformed("unknown policy"))?;
            let m = CacheManager::restore_with_pool(cfg.clone(), policy, pool.clone(), &mut r)?;
            (
                CacheMode::Mikv {
                    cfg,
                    policy: policy_name,
                },
                SessionCache::Mikv(m),
            )
        }
        1 => (
            CacheMode::Full,
            SessionCache::Full(read_full_cache(&mut r, dims)?),
        ),
        2 => {
            let k = r.u64()? as usize;
            (
                CacheMode::Oracle { k },
                SessionCache::Full(read_full_cache(&mut r, dims)?),
            )
        }
        _ => return Err(SpillError::Malformed("mode tag")),
    };
    r.finish()?;
    Ok(Session {
        id,
        mode,
        cache,
        tokens,
        prompt_len,
        last_token,
        done,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::StepOutputs;
    use crate::kvcache::Placement;
    use crate::quant::packing::{pack, packed_words, unpack};
    use crate::util::prop::{forall, gen_vec_normal, Config};
    use crate::util::rng::Pcg32;

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            max_seq: 48,
            quant_group: 4,
            params: 0,
        }
    }

    fn sample_frame() -> Vec<u8> {
        let mut w = Writer::with_capacity(64);
        w.put_u64(0xDEAD_BEEF);
        w.put_str("hello");
        w.put_f32(1.5);
        w.into_frame()
    }

    #[test]
    fn frame_round_trip() {
        let f = sample_frame();
        assert_eq!(&f[..4], b"MKVS");
        let mut r = open_frame(&f).unwrap();
        assert_eq!(r.u64().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.take_str().unwrap(), "hello");
        assert_eq!(r.f32().unwrap(), 1.5);
        r.finish().unwrap();
    }

    #[test]
    fn frame_rejects_bad_magic() {
        let mut f = sample_frame();
        f[0] ^= 0xFF;
        assert_eq!(open_frame(&f).err(), Some(SpillError::BadMagic));
    }

    #[test]
    fn frame_rejects_unknown_version() {
        let mut f = sample_frame();
        f[4] = 99;
        assert_eq!(
            open_frame(&f).err(),
            Some(SpillError::UnsupportedVersion(99))
        );
    }

    #[test]
    fn frame_rejects_truncation() {
        let f = sample_frame();
        // every truncation point fails with a structured error
        for cut in 0..f.len() {
            let err = open_frame(&f[..cut]).err().expect("truncated frame decodes");
            assert!(
                matches!(err, SpillError::Truncated { .. } | SpillError::BadMagic),
                "cut at {cut}: {err:?}"
            );
        }
    }

    #[test]
    fn frame_rejects_payload_corruption_and_trailing_bytes() {
        let mut f = sample_frame();
        f[HEADER_LEN] ^= 0x01;
        assert_eq!(open_frame(&f).err(), Some(SpillError::ChecksumMismatch));
        let mut g = sample_frame();
        g.push(0);
        assert_eq!(
            open_frame(&g).err(),
            Some(SpillError::Malformed("trailing bytes after frame"))
        );
    }

    /// The codec carries packed code words for every quantizable bit width.
    /// [`Precision`] only exposes 2/3/4/8, so this exercises the full
    /// `1..=8` range at the pack/serialize/unpack level.
    #[test]
    fn packed_words_round_trip_all_widths_1_to_8() {
        for bits in 1..=8u32 {
            let n = 64usize;
            let codes: Vec<u8> = (0..n).map(|i| ((i * 7 + 3) % (1usize << bits)) as u8).collect();
            let words = pack(&codes, bits);
            assert_eq!(words.len(), packed_words(n, bits));

            let mut w = Writer::with_capacity(words.len() * 4 + 16);
            w.put_u64(words.len() as u64);
            w.put_u32_slice(&words);
            let frame = w.into_frame();

            let mut r = open_frame(&frame).unwrap();
            let m = r.u64().unwrap() as usize;
            let mut back = vec![0u32; m];
            r.u32_into(&mut back).unwrap();
            r.finish().unwrap();
            assert_eq!(back, words, "bits={bits}");
            assert_eq!(unpack(&back, bits, n), codes, "bits={bits}");
        }
    }

    #[test]
    fn cache_config_codec_round_trips() {
        let mut cfg = CacheConfig::mikv(2, 2, 8, 48, 0.25, Precision::Int3);
        cfg.retention = RetentionMode::Evict;
        cfg.outlier_aware = false;
        cfg.promotion = Some(PromotionConfig {
            max_per_step: 2,
            min_residency: 3,
            promote_margin: 1.5,
        });
        cfg.merge = Some(MergeConfig {
            neighbor_window: 8,
            min_mass: 1e-5,
        });
        let mut w = Writer::with_capacity(64);
        put_cache_config(&mut w, &cfg);
        let frame = w.into_frame();
        let mut r = open_frame(&frame).unwrap();
        let back = read_cache_config(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(back.layers, cfg.layers);
        assert_eq!(back.kv_heads, cfg.kv_heads);
        assert_eq!(back.head_dim, cfg.head_dim);
        assert_eq!(back.max_seq, cfg.max_seq);
        assert_eq!(back.hi, cfg.hi);
        assert_eq!(back.lo, cfg.lo);
        assert_eq!(back.importance_ratio, cfg.importance_ratio);
        assert_eq!(back.recent_window, cfg.recent_window);
        assert_eq!(back.retention, cfg.retention);
        assert_eq!(back.outlier_aware, cfg.outlier_aware);
        assert_eq!(back.promotion, cfg.promotion);
        assert_eq!(back.merge, cfg.merge);

        // merge: None round-trips too (the default-off lock).
        cfg.merge = None;
        let mut w = Writer::with_capacity(64);
        put_cache_config(&mut w, &cfg);
        let frame = w.into_frame();
        let mut r = open_frame(&frame).unwrap();
        let back = read_cache_config(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(back.merge, None);
    }

    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    fn compare_managers(a: &CacheManager, b: &CacheManager) -> Result<(), String> {
        if a.seq_len() != b.seq_len() {
            return Err(format!("seq_len {} != {}", a.seq_len(), b.seq_len()));
        }
        if a.capacity() != b.capacity() {
            return Err(format!("capacity {} != {}", a.capacity(), b.capacity()));
        }
        if a.occupancy() != b.occupancy() {
            return Err(format!("occupancy {:?} != {:?}", a.occupancy(), b.occupancy()));
        }
        if a.promotion_stats() != b.promotion_stats() {
            return Err("promotion stats diverged".into());
        }
        if a.merge_ledger() != b.merge_ledger() {
            return Err(format!(
                "merge ledger {:?} != {:?}",
                a.merge_ledger(),
                b.merge_ledger()
            ));
        }
        let cfg = a.config();
        let planes = cfg.layers * cfg.kv_heads;
        let d = cfg.head_dim;
        let (mut ka, mut va) = (vec![0.0f32; d], vec![0.0f32; d]);
        let (mut kb, mut vb) = (vec![0.0f32; d], vec![0.0f32; d]);
        for p in 0..planes {
            for s in 0..a.seq_len() {
                if a.placement(p, s) != b.placement(p, s) {
                    return Err(format!(
                        "placement ({p},{s}): {:?} != {:?}",
                        a.placement(p, s),
                        b.placement(p, s)
                    ));
                }
                if a.residency(p, s) != b.residency(p, s) {
                    return Err(format!("residency ({p},{s}) diverged"));
                }
                if a.merge_mass(p, s).to_bits() != b.merge_mass(p, s).to_bits() {
                    return Err(format!("merge mass ({p},{s}) not bit-identical"));
                }
                let ga = a.effective_kv_into(p, s, &mut ka, &mut va);
                let gb = b.effective_kv_into(p, s, &mut kb, &mut vb);
                if ga != gb {
                    return Err(format!("effective_kv presence ({p},{s}) diverged"));
                }
                if ga && (!bits_eq(&ka, &kb) || !bits_eq(&va, &vb)) {
                    return Err(format!("effective_kv ({p},{s}) not bit-identical"));
                }
            }
        }
        let va_ = a.decode_views();
        let vb_ = b.decode_views();
        let blocks = [
            ("k_hi", va_.k_hi, vb_.k_hi),
            ("v_hi", va_.v_hi, vb_.v_hi),
            ("hi_mask", va_.hi_mask, vb_.hi_mask),
            ("k_lo_codes", va_.k_lo_codes, vb_.k_lo_codes),
            ("k_lo_scale", va_.k_lo_scale, vb_.k_lo_scale),
            ("k_lo_zero", va_.k_lo_zero, vb_.k_lo_zero),
            ("v_lo_codes", va_.v_lo_codes, vb_.v_lo_codes),
            ("v_lo_scale", va_.v_lo_scale, vb_.v_lo_scale),
            ("v_lo_zero", va_.v_lo_zero, vb_.v_lo_zero),
            ("lo_mask", va_.lo_mask, vb_.lo_mask),
            ("inv_balancer", va_.inv_balancer, vb_.inv_balancer),
        ];
        for (name, x, y) in blocks {
            if !bits_eq(x, y) {
                return Err(format!("decode view block {name} not bit-identical"));
            }
        }
        Ok(())
    }

    /// The tentpole acceptance property: spill → restore is bit-identical
    /// for both tiers across arbitrary admit/observe/demote/promote runs,
    /// and — the part serving actually depends on — a restored session
    /// continues to produce bit-identical decode-step state vs the
    /// never-spilled original.
    #[test]
    fn property_snapshot_round_trip_bit_identical() {
        forall(Config::default().cases(24).name("snapshot round trip"), |rng| {
            let dm = dims();
            let max_seq = dm.max_seq;
            let ratio = *rng.choose(&[0.0f64, 0.1, 0.25, 0.5, 1.0]);
            let lo = *rng.choose(&[
                Precision::Int2,
                Precision::Int3,
                Precision::Int4,
                Precision::Int8,
            ]);
            let mut cfg = CacheConfig::mikv(2, 2, 8, max_seq, ratio, lo);
            cfg.recent_window = 1 + rng.gen_below(4) as usize;
            cfg.outlier_aware = rng.gen_bool(0.5);
            if rng.gen_bool(0.25) {
                // quantized importance cache (paper §3.3)
                cfg.hi = TierConfig::quantized(Precision::Int8, 4);
            }
            if rng.gen_bool(0.25) {
                // eviction-baseline sessions spill too
                cfg.retention = RetentionMode::Evict;
                if rng.gen_bool(0.5) {
                    // ... and merge-enabled ones carry ledger + fold masses
                    cfg.merge = Some(MergeConfig {
                        neighbor_window: *rng.choose(&[0usize, 8, 64]),
                        min_mass: 1e-6,
                    });
                }
            }
            if rng.gen_bool(0.5) {
                cfg.promotion = Some(PromotionConfig {
                    max_per_step: 1 + rng.gen_below(2) as usize,
                    min_residency: 1 + rng.gen_below(3) as usize,
                    promote_margin: *rng.choose(&[1.2f32, 1.5, 2.0]),
                });
            }
            let policy_name = *rng.choose(&["h2o", "local", "random", "lagkv"]);
            let planes = cfg.layers * cfg.kv_heads;
            let d = cfg.head_dim;
            let id = rng.next_u64();
            let policy = make_policy(policy_name, planes, max_seq, id).expect("known policy");
            let mut m = CacheManager::new(cfg.clone(), policy);

            // Random prefill + decode history.
            let t0 = 1 + rng.gen_below(16) as usize;
            let k = gen_vec_normal(rng, planes * t0 * d, 1.0, 0.05);
            let v = gen_vec_normal(rng, planes * t0 * d, 1.0, 0.05);
            let acc: Vec<f32> = (0..planes * t0).map(|_| rng.gen_f32()).collect();
            let qmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
            let kmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
            m.ingest_prefill(t0, &k, &v, &acc, &qmax, &kmax);

            let post_steps = 4usize;
            let steps = (rng.gen_below(16) as usize).min(max_seq - t0 - post_steps);
            for _ in 0..steps {
                let k_new = gen_vec_normal(rng, planes * d, 1.0, 0.05);
                let v_new = gen_vec_normal(rng, planes * d, 1.0, 0.05);
                let mut attn_prev: Vec<f32> =
                    (0..planes * max_seq).map(|_| rng.gen_f32() * 0.1).collect();
                if rng.gen_bool(0.5) {
                    let hot = rng.gen_below(m.seq_len() as u32) as usize;
                    for p in 0..planes {
                        attn_prev[p * max_seq + hot] = 0.9;
                    }
                }
                let attn_self: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
                m.append_token(StepOutputs {
                    k_new: &k_new,
                    v_new: &v_new,
                    attn_prev: &attn_prev,
                    attn_self: &attn_self,
                });
            }

            // Wrap in a session, spill, restore into a fresh pool.
            let n_tok = m.seq_len();
            let mut sess = Session {
                id,
                mode: CacheMode::Mikv {
                    cfg: cfg.clone(),
                    policy: policy_name.to_string(),
                },
                cache: SessionCache::Mikv(m),
                tokens: (0..n_tok as i64).map(|t| t * 3 + 1).collect(),
                prompt_len: t0,
                last_token: 41,
                done: false,
            };
            let frame = encode_session(&sess).map_err(|e| e.to_string())?;
            let pool = BufferPool::new();
            let mut back =
                decode_session(&frame, &dims(), &pool).map_err(|e| e.to_string())?;

            crate::prop_assert!(back.id == sess.id, "id diverged");
            crate::prop_assert!(back.tokens == sess.tokens, "tokens diverged");
            crate::prop_assert!(back.prompt_len == sess.prompt_len, "prompt_len diverged");
            crate::prop_assert!(back.last_token == sess.last_token, "last_token diverged");
            crate::prop_assert!(back.done == sess.done, "done diverged");
            {
                let (SessionCache::Mikv(ma), SessionCache::Mikv(mb)) =
                    (&sess.cache, &back.cache)
                else {
                    return Err("restored cache is not MiKV".to_string());
                };
                compare_managers(ma, mb).map_err(|e| format!("after restore: {e}"))?;
                mb.check_invariants()
                    .map_err(|e| format!("restored invariants: {e}"))?;
            }

            // Drive IDENTICAL further decode steps into both sessions: the
            // restored one must stay bit-identical step for step (policy
            // state, RNG stream, residency clocks and tier contents all
            // round-tripped).
            for step in 0..post_steps {
                let k_new = gen_vec_normal(rng, planes * d, 1.0, 0.05);
                let v_new = gen_vec_normal(rng, planes * d, 1.0, 0.05);
                let mut attn_prev: Vec<f32> =
                    (0..planes * max_seq).map(|_| rng.gen_f32() * 0.1).collect();
                if rng.gen_bool(0.5) {
                    let hot = rng.gen_below(sess.cache.seq_len() as u32) as usize;
                    for p in 0..planes {
                        attn_prev[p * max_seq + hot] = 0.9;
                    }
                }
                let attn_self: Vec<f32> = (0..planes).map(|_| rng.gen_f32() * 0.1).collect();
                for s in [&mut sess, &mut back] {
                    s.try_ingest_step(&k_new, &v_new, &attn_prev, &attn_self)
                        .map_err(|e| format!("post-restore step {step}: {e}"))?;
                }
                let (SessionCache::Mikv(ma), SessionCache::Mikv(mb)) =
                    (&sess.cache, &back.cache)
                else {
                    return Err("restored cache is not MiKV".to_string());
                };
                compare_managers(ma, mb)
                    .map_err(|e| format!("post-restore step {step}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn full_mode_session_round_trips_and_continues_identically() {
        let dm = dims();
        let mut rng = Pcg32::new(77);
        let t0 = 6usize;
        let planes = dm.planes();
        let d = dm.d_head;
        let k: Vec<f32> = (0..planes * t0 * d).map(|_| rng.gen_normal()).collect();
        let v: Vec<f32> = (0..planes * t0 * d).map(|_| rng.gen_normal()).collect();

        for mode in [CacheMode::Full, CacheMode::Oracle { k: 4 }] {
            let mut sess = Session::new(9, &dm, mode).unwrap();
            let SessionCache::Full(f) = &mut sess.cache else {
                panic!("full-mode session")
            };
            f.ingest_prefill(t0, &k, &v);
            sess.tokens = vec![1, 2, 3, 4, 5, 6];
            sess.prompt_len = t0;
            sess.last_token = 6;

            let frame = encode_session(&sess).unwrap();
            let pool = BufferPool::new();
            let mut back = decode_session(&frame, &dm, &pool).unwrap();
            assert_eq!(back.tokens, sess.tokens);
            assert!(matches!(
                (&sess.mode, &back.mode),
                (CacheMode::Full, CacheMode::Full)
                    | (CacheMode::Oracle { .. }, CacheMode::Oracle { .. })
            ));
            if let (CacheMode::Oracle { k: ka }, CacheMode::Oracle { k: kb }) =
                (&sess.mode, &back.mode)
            {
                assert_eq!(ka, kb);
            }
            {
                let (SessionCache::Full(fa), SessionCache::Full(fb)) =
                    (&sess.cache, &back.cache)
                else {
                    panic!("restored cache is not Full")
                };
                assert_eq!(fa.seq_len, fb.seq_len);
                assert!(bits_eq(&fa.k, &fb.k), "K blocks not bit-identical");
                assert!(bits_eq(&fa.v, &fb.v), "V blocks not bit-identical");
                assert!(bits_eq(&fa.mask, &fb.mask), "masks not bit-identical");
            }

            // identical appends stay identical
            let k_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            let v_new: Vec<f32> = (0..planes * d).map(|_| rng.gen_normal()).collect();
            for s in [&mut sess, &mut back] {
                s.try_ingest_step(&k_new, &v_new, &[], &[]).unwrap();
            }
            let (SessionCache::Full(fa), SessionCache::Full(fb)) = (&sess.cache, &back.cache)
            else {
                panic!("restored cache is not Full")
            };
            assert!(bits_eq(&fa.k, &fb.k) && bits_eq(&fa.v, &fb.v));
        }
    }

    #[test]
    fn empty_session_round_trips() {
        let dm = dims();
        let sess = Session::new(1, &dm, CacheMode::Full).unwrap();
        let frame = encode_session(&sess).unwrap();
        let back = decode_session(&frame, &dm, &BufferPool::new()).unwrap();
        assert_eq!(back.cache.seq_len(), 0);
        assert!(back.tokens.is_empty());
    }

    #[test]
    fn decode_rejects_incompatible_dims() {
        let dm = dims();
        let mut sess = Session::new(2, &dm, CacheMode::mikv(&dm, 0.25, Precision::Int4)).unwrap();
        let SessionCache::Mikv(m) = &mut sess.cache else {
            panic!()
        };
        let mut rng = Pcg32::new(5);
        let planes = dm.planes();
        let (t0, d) = (8usize, dm.d_head);
        let k: Vec<f32> = (0..planes * t0 * d).map(|_| rng.gen_normal()).collect();
        let acc: Vec<f32> = (0..planes * t0).map(|_| rng.gen_f32()).collect();
        let qmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
        m.ingest_prefill(t0, &k, &k, &acc, &qmax, &qmax);

        let frame = encode_session(&sess).unwrap();
        let mut other = dims();
        other.max_seq = 32;
        assert!(matches!(
            decode_session(&frame, &other, &BufferPool::new()).err(),
            Some(SpillError::Incompatible(_))
        ));
    }

    /// A spilled MiKV session survives hostile mutation of any single byte
    /// of its frame with a structured error — never a panic, never a
    /// silently-wrong restore (the checksum catches payload flips, the
    /// header fields catch the rest).
    #[test]
    fn mikv_snapshot_rejects_single_byte_corruption_sample() {
        let dm = dims();
        let mut sess = Session::new(3, &dm, CacheMode::mikv(&dm, 0.25, Precision::Int2)).unwrap();
        let SessionCache::Mikv(m) = &mut sess.cache else {
            panic!()
        };
        let mut rng = Pcg32::new(6);
        let planes = dm.planes();
        let (t0, d) = (10usize, dm.d_head);
        let k: Vec<f32> = (0..planes * t0 * d).map(|_| rng.gen_normal()).collect();
        let acc: Vec<f32> = (0..planes * t0).map(|_| rng.gen_f32()).collect();
        let qmax: Vec<f32> = (0..planes * d).map(|_| rng.gen_f32() + 0.5).collect();
        m.ingest_prefill(t0, &k, &k, &acc, &qmax, &qmax);
        let frame = encode_session(&sess).unwrap();
        let pool = BufferPool::new();
        assert!(decode_session(&frame, &dm, &pool).is_ok());

        // sample every 7th byte position (full sweep lives in the
        // hostile-bytes integration test)
        for pos in (0..frame.len()).step_by(7) {
            let mut bad = frame.clone();
            bad[pos] ^= 0x10;
            assert!(
                decode_session(&bad, &dm, &pool).is_err(),
                "flip at {pos} must not decode"
            );
        }
    }
}
