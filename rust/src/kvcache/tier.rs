//! Tier storage for one (layer, kv-head) plane of the cache.
//!
//! * [`HiTier`] — the importance cache. FP16 tiers store values rounded
//!   through binary16; quantized hi tiers (paper §3.3) store the
//!   quantize→dequantize image, so downstream attention sees exactly the
//!   precision-limited values while accounting charges the logical bits.
//! * [`LoTier`] — the retained cache. Stores *actual packed codes* plus
//!   per-group FP16 scale/zero, because the decode graph dequantizes
//!   in-kernel: the host hands codes (as f32-held integers), scales and
//!   zeros straight to the HLO inputs.

use super::TierConfig;
use crate::quant::{
    asym::{quantize, QuantParams},
    f16::round_f16_slice,
    packing::{pack, packed_words, unpack_dequant_into, unpack_into},
    Precision,
};

/// High-precision tier plane: dense per-slot K/V vectors.
#[derive(Debug, Clone)]
pub struct HiTier {
    cfg: TierConfig,
    head_dim: usize,
    /// `[slots × head_dim]`, storage-rounded.
    k: Vec<f32>,
    v: Vec<f32>,
}

impl HiTier {
    // lint: hot-path-alloc-free-ok(fn): one-time tier constructor; decode reuses the buffers
    pub fn new(cfg: TierConfig, head_dim: usize, slots: usize) -> Self {
        Self {
            cfg,
            head_dim,
            k: vec![0.0; slots * head_dim],
            v: vec![0.0; slots * head_dim],
        }
    }

    /// Grow storage to hold at least `slots` slots (slot-major layout, so
    /// growth is a plain tail extension). Never shrinks.
    pub fn ensure_capacity(&mut self, slots: usize) {
        let need = slots * self.head_dim;
        if self.k.len() < need {
            self.k.resize(need, 0.0);
            self.v.resize(need, 0.0);
        }
    }

    /// Slots currently allocated.
    pub fn capacity(&self) -> usize {
        self.k.len() / self.head_dim.max(1)
    }

    /// Host bytes held by this plane's storage.
    pub fn host_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// Round a vector through this tier's storage precision.
    fn storage_round(cfg: &TierConfig, x: &mut [f32]) {
        match cfg.precision {
            Precision::Fp16 => round_f16_slice(x),
            p => {
                let prm = QuantParams::new(p, cfg.group.min(x.len()));
                let q = quantize(x, prm);
                let dq = crate::quant::dequantize(&q);
                x.copy_from_slice(&dq);
            }
        }
    }

    /// Admit a token's K/V into slot `s` (values rounded to tier precision).
    pub fn admit(&mut self, s: usize, k: &[f32], v: &[f32]) {
        let d = self.head_dim;
        debug_assert!(k.len() == d && v.len() == d);
        let ks = &mut self.k[s * d..(s + 1) * d];
        ks.copy_from_slice(k);
        Self::storage_round(&self.cfg, ks);
        let vs = &mut self.v[s * d..(s + 1) * d];
        vs.copy_from_slice(v);
        Self::storage_round(&self.cfg, vs);
    }

    /// Restore a slot from already-rounded values *without* re-applying
    /// storage rounding — the snapshot-restore path. The stored vectors
    /// were rounded when first admitted, so a raw copy reproduces the tier
    /// bit-for-bit; routing a restore through [`Self::admit`] would round a
    /// second time (idempotent for FP16, but not guaranteed for quantized
    /// hi tiers, whose group min/max would be recomputed from the rounded
    /// image).
    pub fn set_slot_raw(&mut self, s: usize, k: &[f32], v: &[f32]) {
        let d = self.head_dim;
        debug_assert!(k.len() == d && v.len() == d);
        self.k[s * d..(s + 1) * d].copy_from_slice(k);
        self.v[s * d..(s + 1) * d].copy_from_slice(v);
    }

    /// Read back the stored K/V of slot `s`.
    pub fn k_slot(&self, s: usize) -> &[f32] {
        &self.k[s * self.head_dim..(s + 1) * self.head_dim]
    }

    pub fn v_slot(&self, s: usize) -> &[f32] {
        &self.v[s * self.head_dim..(s + 1) * self.head_dim]
    }

    /// Clear a slot after demotion/eviction (keeps masked HLO inputs clean —
    /// masked lanes must still be finite).
    pub fn clear(&mut self, s: usize) {
        let d = self.head_dim;
        self.k[s * d..(s + 1) * d].fill(0.0);
        self.v[s * d..(s + 1) * d].fill(0.0);
    }

    /// Dense K plane `[slots × head_dim]` for input assembly.
    pub fn k_dense(&self) -> &[f32] {
        &self.k
    }

    pub fn v_dense(&self) -> &[f32] {
        &self.v
    }
}

/// Low-precision tier plane: packed codes + per-group metadata per slot.
#[derive(Debug, Clone)]
pub struct LoTier {
    prm: QuantParams,
    head_dim: usize,
    groups: usize,
    words: usize,
    /// `[slots × words]` packed K / V codes.
    k_codes: Vec<u32>,
    v_codes: Vec<u32>,
    /// `[slots × groups]` scale / zero (FP16-rounded by the quantizer).
    k_scales: Vec<f32>,
    k_zeros: Vec<f32>,
    v_scales: Vec<f32>,
    v_zeros: Vec<f32>,
}

impl LoTier {
    // lint: hot-path-alloc-free-ok(fn): one-time tier constructor; decode reuses the buffers
    pub fn new(cfg: TierConfig, head_dim: usize, slots: usize) -> Self {
        assert!(cfg.precision.is_quantized());
        let group = cfg.group.min(head_dim);
        let prm = QuantParams::new(cfg.precision, group);
        let groups = head_dim / group;
        let words = packed_words(head_dim, cfg.precision.bits());
        Self {
            prm,
            head_dim,
            groups,
            words,
            k_codes: vec![0; slots * words],
            v_codes: vec![0; slots * words],
            k_scales: vec![0.0; slots * groups],
            k_zeros: vec![0.0; slots * groups],
            v_scales: vec![0.0; slots * groups],
            v_zeros: vec![0.0; slots * groups],
        }
    }

    pub fn params(&self) -> QuantParams {
        self.prm
    }

    pub fn groups(&self) -> usize {
        self.groups
    }

    /// Packed `u32` words per slot (per K or V vector).
    pub fn words(&self) -> usize {
        self.words
    }

    /// Grow storage to hold at least `slots` slots (slot-major layout, so
    /// growth is a plain tail extension). Never shrinks.
    pub fn ensure_capacity(&mut self, slots: usize) {
        if self.k_scales.len() < slots * self.groups {
            self.k_codes.resize(slots * self.words, 0);
            self.v_codes.resize(slots * self.words, 0);
            self.k_scales.resize(slots * self.groups, 0.0);
            self.k_zeros.resize(slots * self.groups, 0.0);
            self.v_scales.resize(slots * self.groups, 0.0);
            self.v_zeros.resize(slots * self.groups, 0.0);
        }
    }

    /// Slots currently allocated.
    pub fn capacity(&self) -> usize {
        self.k_scales.len() / self.groups
    }

    /// Host bytes held by this plane's storage (packed codes + metadata).
    pub fn host_bytes(&self) -> usize {
        (self.k_codes.len() + self.v_codes.len()) * std::mem::size_of::<u32>()
            + (self.k_scales.len()
                + self.k_zeros.len()
                + self.v_scales.len()
                + self.v_zeros.len())
                * std::mem::size_of::<f32>()
    }

    /// Quantize and store a token's K/V into slot `s`. `k` is expected to be
    /// already balancer-multiplied when outlier awareness is on.
    pub fn admit(&mut self, s: usize, k: &[f32], v: &[f32]) {
        debug_assert!(k.len() == self.head_dim && v.len() == self.head_dim);
        let qk = quantize(k, self.prm);
        let qv = quantize(v, self.prm);
        let bits = self.prm.precision.bits();
        self.k_codes[s * self.words..(s + 1) * self.words]
            .copy_from_slice(&pack(&qk.codes, bits));
        self.v_codes[s * self.words..(s + 1) * self.words]
            .copy_from_slice(&pack(&qv.codes, bits));
        self.k_scales[s * self.groups..(s + 1) * self.groups].copy_from_slice(&qk.scales);
        self.k_zeros[s * self.groups..(s + 1) * self.groups].copy_from_slice(&qk.zeros);
        self.v_scales[s * self.groups..(s + 1) * self.groups].copy_from_slice(&qv.scales);
        self.v_zeros[s * self.groups..(s + 1) * self.groups].copy_from_slice(&qv.zeros);
    }

    pub fn clear(&mut self, s: usize) {
        self.k_codes[s * self.words..(s + 1) * self.words].fill(0);
        self.v_codes[s * self.words..(s + 1) * self.words].fill(0);
        self.k_scales[s * self.groups..(s + 1) * self.groups].fill(0.0);
        self.k_zeros[s * self.groups..(s + 1) * self.groups].fill(0.0);
        self.v_scales[s * self.groups..(s + 1) * self.groups].fill(0.0);
        self.v_zeros[s * self.groups..(s + 1) * self.groups].fill(0.0);
    }

    /// Unpack slot `s`'s K codes into `out` as f32-held integer codes
    /// (the decode graph's input representation).
    pub fn k_codes_f32_into(&self, s: usize, scratch: &mut [u8], out: &mut [f32]) {
        self.codes_f32_into(&self.k_codes, s, scratch, out)
    }

    pub fn v_codes_f32_into(&self, s: usize, scratch: &mut [u8], out: &mut [f32]) {
        self.codes_f32_into(&self.v_codes, s, scratch, out)
    }

    fn codes_f32_into(&self, codes: &[u32], s: usize, scratch: &mut [u8], out: &mut [f32]) {
        debug_assert!(scratch.len() == self.head_dim && out.len() == self.head_dim);
        unpack_into(
            &codes[s * self.words..(s + 1) * self.words],
            self.prm.precision.bits(),
            scratch,
        );
        for (o, &c) in out.iter_mut().zip(scratch.iter()) {
            *o = c as f32;
        }
    }

    /// Raw packed K code words of slot `s` (`[words]`) — the snapshot-spill
    /// read path: codes leave the tier exactly as stored, no dequantization.
    pub fn k_codes_slot(&self, s: usize) -> &[u32] {
        &self.k_codes[s * self.words..(s + 1) * self.words]
    }

    pub fn v_codes_slot(&self, s: usize) -> &[u32] {
        &self.v_codes[s * self.words..(s + 1) * self.words]
    }

    /// Restore a slot from raw packed codes + metadata *without*
    /// re-quantizing — the snapshot-restore path. Re-admitting dequantized
    /// values through [`Self::admit`] would recompute group min/max from the
    /// quantization image and could shift codes by one step; a raw copy
    /// reproduces the tier bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    pub fn set_slot_raw(
        &mut self,
        s: usize,
        k_codes: &[u32],
        v_codes: &[u32],
        k_scales: &[f32],
        k_zeros: &[f32],
        v_scales: &[f32],
        v_zeros: &[f32],
    ) {
        debug_assert!(k_codes.len() == self.words && v_codes.len() == self.words);
        debug_assert!(k_scales.len() == self.groups && v_zeros.len() == self.groups);
        self.k_codes[s * self.words..(s + 1) * self.words].copy_from_slice(k_codes);
        self.v_codes[s * self.words..(s + 1) * self.words].copy_from_slice(v_codes);
        self.k_scales[s * self.groups..(s + 1) * self.groups].copy_from_slice(k_scales);
        self.k_zeros[s * self.groups..(s + 1) * self.groups].copy_from_slice(k_zeros);
        self.v_scales[s * self.groups..(s + 1) * self.groups].copy_from_slice(v_scales);
        self.v_zeros[s * self.groups..(s + 1) * self.groups].copy_from_slice(v_zeros);
    }

    pub fn k_meta_slot(&self, s: usize) -> (&[f32], &[f32]) {
        (
            &self.k_scales[s * self.groups..(s + 1) * self.groups],
            &self.k_zeros[s * self.groups..(s + 1) * self.groups],
        )
    }

    pub fn v_meta_slot(&self, s: usize) -> (&[f32], &[f32]) {
        (
            &self.v_scales[s * self.groups..(s + 1) * self.groups],
            &self.v_zeros[s * self.groups..(s + 1) * self.groups],
        )
    }

    /// Dequantize slot `s` into caller buffers (each `[head_dim]`) through
    /// the fused unpack+dequant kernel — the allocation-free variant used
    /// on the serving read path (`CacheManager::effective_kv_into`).
    pub fn dequant_slot_into(&self, s: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        debug_assert!(k_out.len() == self.head_dim && v_out.len() == self.head_dim);
        let bits = self.prm.precision.bits();
        let g = self.prm.group;
        let (ks, kz) = self.k_meta_slot(s);
        unpack_dequant_into(
            &self.k_codes[s * self.words..(s + 1) * self.words],
            bits,
            ks,
            kz,
            g,
            k_out,
        );
        let (vs, vz) = self.v_meta_slot(s);
        unpack_dequant_into(
            &self.v_codes[s * self.words..(s + 1) * self.words],
            bits,
            vs,
            vz,
            g,
            v_out,
        );
    }

    /// Promotion staging: dequantize slot `s` into the caller's scratch
    /// buffers (each `[head_dim]`) and clear the packed slot in one pass —
    /// the lo→hi handoff used by `CacheManager::promote`. Allocation-free:
    /// the slot's contents move through caller-owned scratch, never a
    /// fresh `Vec`, so a steady-state promotion costs no heap traffic.
    pub fn take_slot_into(&mut self, s: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        self.dequant_slot_into(s, k_out, v_out);
        self.clear(s);
    }

    /// Fully dequantize slot `s` (allocating diagnostics wrapper over
    /// [`Self::dequant_slot_into`]).
    // lint: hot-path-alloc-free-ok(fn): allocating diagnostics wrapper over dequant_slot_into
    pub fn dequant_slot(&self, s: usize) -> (Vec<f32>, Vec<f32>) {
        let mut kc = vec![0.0f32; self.head_dim];
        let mut vc = vec![0.0f32; self.head_dim];
        self.dequant_slot_into(s, &mut kc, &mut vc);
        (kc, vc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{forall, gen_vec_normal, Config};

    #[test]
    fn hi_fp16_rounds_storage() {
        let mut t = HiTier::new(TierConfig::fp16(), 4, 2);
        let k = [1.0f32, 1e-10, 3.14159265, -2.5];
        let v = [0.1f32, 0.2, 0.3, 0.4];
        t.admit(1, &k, &v);
        let ks = t.k_slot(1);
        assert_eq!(ks[0], 1.0);
        assert_eq!(ks[1], 0.0); // f16 underflow
        assert!((ks[2] - 3.14159265).abs() < 2e-3);
        assert_eq!(ks[3], -2.5);
        // untouched slot stays zero
        assert!(t.k_slot(0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn hi_int8_storage_rounding() {
        let mut t = HiTier::new(TierConfig::quantized(Precision::Int8, 4), 4, 1);
        let k = [0.0f32, 1.0, 2.0, 3.0];
        t.admit(0, &k, &k);
        for (a, b) in t.k_slot(0).iter().zip(&k) {
            assert!((a - b).abs() <= 3.0 / 255.0 / 2.0 + 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn lo_roundtrip_within_quant_error() {
        let cfg = TierConfig::quantized(Precision::Int4, 4);
        let mut t = LoTier::new(cfg, 8, 3);
        let k: Vec<f32> = (0..8).map(|i| (i as f32 * 0.9).sin() * 2.0).collect();
        let v: Vec<f32> = (0..8).map(|i| (i as f32 * 0.4).cos()).collect();
        t.admit(2, &k, &v);
        let (kd, vd) = t.dequant_slot(2);
        for (a, b) in kd.iter().zip(&k) {
            assert!((a - b).abs() < 0.3, "{a} vs {b}");
        }
        for (a, b) in vd.iter().zip(&v) {
            assert!((a - b).abs() < 0.15, "{a} vs {b}");
        }
    }

    #[test]
    fn clear_resets_slot() {
        let mut hi = HiTier::new(TierConfig::fp16(), 4, 2);
        hi.admit(0, &[1.0; 4], &[2.0; 4]);
        hi.clear(0);
        assert!(hi.k_slot(0).iter().all(|&x| x == 0.0));

        let mut lo = LoTier::new(TierConfig::quantized(Precision::Int2, 2), 4, 2);
        lo.admit(1, &[1.0, -1.0, 2.0, 0.5], &[0.0, 1.0, 2.0, 3.0]);
        lo.clear(1);
        let (kd, vd) = lo.dequant_slot(1);
        assert!(kd.iter().chain(vd.iter()).all(|&x| x == 0.0));
    }

    #[test]
    fn property_lo_tier_matches_direct_quantization() {
        forall(Config::default().cases(150).name("lo tier fidelity"), |rng| {
            let d = *rng.choose(&[8usize, 16, 32]);
            let p = *rng.choose(&[Precision::Int2, Precision::Int3, Precision::Int4, Precision::Int8]);
            let cfg = TierConfig::quantized(p, d / 2);
            let mut t = LoTier::new(cfg, d, 1);
            let k = gen_vec_normal(rng, d, 1.5, 0.05);
            let v = gen_vec_normal(rng, d, 1.0, 0.0);
            t.admit(0, &k, &v);
            let (kd, _) = t.dequant_slot(0);
            // reference: direct quantize→dequantize
            let q = quantize(&k, t.params());
            let expect = crate::quant::dequantize(&q);
            for (a, b) in kd.iter().zip(&expect) {
                prop_assert!((a - b).abs() < 1e-6, "tier {a} vs direct {b}");
            }
            Ok(())
        });
    }

    #[test]
    fn tiers_grow_preserving_contents() {
        let mut hi = HiTier::new(TierConfig::fp16(), 4, 0);
        assert_eq!(hi.capacity(), 0);
        hi.ensure_capacity(2);
        hi.admit(1, &[1.0; 4], &[2.0; 4]);
        hi.ensure_capacity(8);
        assert_eq!(hi.capacity(), 8);
        assert_eq!(hi.k_slot(1), &[1.0; 4]);
        assert!(hi.k_slot(5).iter().all(|&x| x == 0.0));

        let mut lo = LoTier::new(TierConfig::quantized(Precision::Int4, 2), 4, 0);
        lo.ensure_capacity(2);
        let k = [0.5f32, -0.5, 1.0, 0.0];
        lo.admit(0, &k, &k);
        let before = lo.dequant_slot(0);
        lo.ensure_capacity(16);
        assert_eq!(lo.capacity(), 16);
        assert_eq!(lo.dequant_slot(0), before);
        assert!(lo.host_bytes() > 0);
    }

    /// The fused `dequant_slot_into` must be bit-identical to the old
    /// two-step reference (unpack codes, then `scale·code + zero` with
    /// per-group meta indexing) — same operation order, same f32 math.
    #[test]
    fn property_dequant_slot_into_matches_two_step_reference() {
        forall(Config::default().cases(120).name("fused slot dequant"), |rng| {
            let d = *rng.choose(&[8usize, 16, 32]);
            let p = *rng.choose(&[Precision::Int2, Precision::Int3, Precision::Int4, Precision::Int8]);
            let g = *rng.choose(&[d / 2, d / 4]);
            let cfg = TierConfig::quantized(p, g);
            let mut t = LoTier::new(cfg, d, 2);
            let k = gen_vec_normal(rng, d, 1.2, 0.05);
            let v = gen_vec_normal(rng, d, 0.8, 0.0);
            t.admit(1, &k, &v);

            let mut kd = vec![0.0f32; d];
            let mut vd = vec![0.0f32; d];
            t.dequant_slot_into(1, &mut kd, &mut vd);

            // two-step reference
            let mut scratch = vec![0u8; d];
            let mut kc = vec![0.0f32; d];
            let mut vc = vec![0.0f32; d];
            t.k_codes_f32_into(1, &mut scratch, &mut kc);
            t.v_codes_f32_into(1, &mut scratch, &mut vc);
            let (ks, kz) = t.k_meta_slot(1);
            let (vs, vz) = t.v_meta_slot(1);
            for i in 0..d {
                let ek = ks[i / g] * kc[i] + kz[i / g];
                let ev = vs[i / g] * vc[i] + vz[i / g];
                prop_assert!(kd[i].to_bits() == ek.to_bits(), "k[{i}]: {} vs {ek}", kd[i]);
                prop_assert!(vd[i].to_bits() == ev.to_bits(), "v[{i}]: {} vs {ev}", vd[i]);
            }
            Ok(())
        });
    }

    /// `take_slot_into` is exactly dequant-then-clear: the staged values
    /// match `dequant_slot_into` bit-for-bit and the slot reads back zero.
    #[test]
    fn take_slot_into_stages_and_clears() {
        let cfg = TierConfig::quantized(Precision::Int4, 4);
        let mut t = LoTier::new(cfg, 8, 2);
        let k: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).sin()).collect();
        let v: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).cos()).collect();
        t.admit(1, &k, &v);
        let mut want_k = vec![0.0f32; 8];
        let mut want_v = vec![0.0f32; 8];
        t.dequant_slot_into(1, &mut want_k, &mut want_v);

        let mut got_k = vec![0.0f32; 8];
        let mut got_v = vec![0.0f32; 8];
        t.take_slot_into(1, &mut got_k, &mut got_v);
        assert_eq!(got_k, want_k);
        assert_eq!(got_v, want_v);
        let (kd, vd) = t.dequant_slot(1);
        assert!(kd.iter().chain(vd.iter()).all(|&x| x == 0.0), "slot cleared");
        // the neighbouring slot is untouched
        t.admit(0, &k, &v);
        let before = t.dequant_slot(0);
        t.take_slot_into(1, &mut got_k, &mut got_v);
        assert_eq!(t.dequant_slot(0), before);
    }

    /// Raw get→set round-trip reproduces both tiers bit-for-bit, in
    /// contrast to re-admitting the dequantized image (which re-rounds).
    #[test]
    fn raw_slot_round_trip_is_bit_identical() {
        let mut hi = HiTier::new(TierConfig::quantized(Precision::Int8, 4), 8, 2);
        let k: Vec<f32> = (0..8).map(|i| (i as f32 * 0.9).sin() * 2.0).collect();
        let v: Vec<f32> = (0..8).map(|i| (i as f32 * 0.4).cos()).collect();
        hi.admit(1, &k, &v);
        let (sk, sv) = (hi.k_slot(1).to_vec(), hi.v_slot(1).to_vec());
        let mut hi2 = HiTier::new(TierConfig::quantized(Precision::Int8, 4), 8, 2);
        hi2.set_slot_raw(1, &sk, &sv);
        assert_eq!(hi2.k_slot(1), &sk[..]);
        assert_eq!(hi2.v_slot(1), &sv[..]);

        let cfg = TierConfig::quantized(Precision::Int3, 4);
        let mut lo = LoTier::new(cfg, 8, 2);
        lo.admit(0, &k, &v);
        let kc = lo.k_codes_slot(0).to_vec();
        let vc = lo.v_codes_slot(0).to_vec();
        let (ks, kz) = lo.k_meta_slot(0);
        let (vs, vz) = lo.v_meta_slot(0);
        let (ks, kz, vs, vz) = (ks.to_vec(), kz.to_vec(), vs.to_vec(), vz.to_vec());
        let mut lo2 = LoTier::new(cfg, 8, 2);
        lo2.set_slot_raw(0, &kc, &vc, &ks, &kz, &vs, &vz);
        assert_eq!(lo2.k_codes_slot(0), &kc[..]);
        assert_eq!(lo2.v_codes_slot(0), &vc[..]);
        let (a, b) = (lo.dequant_slot(0), lo2.dequant_slot(0));
        assert_eq!(a, b);
    }

    #[test]
    fn codes_are_integers_in_range() {
        let cfg = TierConfig::quantized(Precision::Int3, 4);
        let mut t = LoTier::new(cfg, 8, 1);
        t.admit(0, &[1.0, -3.0, 0.5, 2.0, -1.0, 0.0, 4.0, -2.0], &[0.0; 8]);
        let mut scratch = vec![0u8; 8];
        let mut codes = vec![0.0f32; 8];
        t.k_codes_f32_into(0, &mut scratch, &mut codes);
        for &c in &codes {
            assert_eq!(c, c.trunc());
            assert!((0.0..=7.0).contains(&c));
        }
    }
}
