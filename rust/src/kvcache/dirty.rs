//! Dirty-row tracking across the cache→engine decode-assembly boundary.
//!
//! Every mutation of a session's decode-shadow blocks touches a small,
//! known set of *rows* (token slots): an append writes one row per plane, a
//! demotion clears one hi row and writes one lo row, a promotion clears one
//! lo row and writes one hi row (plus its swap victim's demotion), a
//! prefill rewrites everything. The [`DirtyTracker`] records which rows
//! changed since the
//! engine last copied this session's shadow into its batch arena, so a
//! steady-state decode step copies **only the changed rows** instead of the
//! whole live prefix (see `model::assembly`).
//!
//! The protocol is a two-sided handshake:
//!
//! * the tracker keeps a monotonically increasing **version**, bumped on
//!   every [`DirtyTracker::take_into`];
//! * the engine's arena caches, per batch lane, the `(session id, version)`
//!   it last synchronized to;
//! * on the next take, the arena applies the drained rows **iff** its
//!   cached version equals [`DirtyTake::prev_version`] — otherwise some
//!   other consumer (a different arena, a different lane, a re-admitted
//!   session) drained rows this lane never saw, and the arena falls back
//!   to a full rescatter of the live prefix.
//!
//! Rows are tracked unioned across planes (a demotion in plane `p` marks
//! slot `s` for every plane): the engine copies a handful of clean rows it
//! didn't strictly need to, in exchange for O(1) bookkeeping per mutation
//! and a flat row list the copy loop can walk plane-major.

/// Rows the tracker holds before collapsing to "everything dirty". Bounds
/// both the tracker's memory and the engine's per-take scratch (which
/// pre-reserves this capacity so a steady-state take never allocates).
pub const MAX_TRACKED_ROWS: usize = 512;

/// Result of draining a tracker: the sync-version pair plus whether the
/// drained rows cover the mutations (`all == false`) or a full rescatter is
/// required (`all == true`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DirtyTake {
    /// The tracker's version before this take — a consumer whose cached
    /// version equals this saw every earlier mutation.
    pub prev_version: u64,
    /// The tracker's version after this take (cache this per lane).
    pub version: u64,
    /// The row list is meaningless; everything must be re-copied
    /// (prefill, a fresh tracker, or row-cap overflow).
    pub all: bool,
}

/// Accumulates dirty rows between takes (see module docs).
#[derive(Debug, Clone)]
pub struct DirtyTracker {
    version: u64,
    all: bool,
    rows: Vec<usize>,
}

impl Default for DirtyTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl DirtyTracker {
    /// A fresh tracker starts fully dirty: the first take after creation
    /// always reports `all` (nothing has ever been synchronized).
    // lint: hot-path-alloc-free-ok(fn): empty-capacity construction; takes reuse caller scratch
    pub fn new() -> DirtyTracker {
        DirtyTracker {
            version: 0,
            all: true,
            rows: Vec::new(),
        }
    }

    /// Record that row `row` of the shadow blocks changed.
    pub fn mark(&mut self, row: usize) {
        if self.all {
            return;
        }
        if self.rows.len() >= MAX_TRACKED_ROWS {
            self.mark_all();
            return;
        }
        // Appends mark the same tail row once per plane: skip the
        // immediate duplicate (full dedup happens at take).
        if self.rows.last() == Some(&row) {
            return;
        }
        self.rows.push(row);
    }

    /// Record that every row changed (prefill / re-stride-invalidating
    /// mutations).
    pub fn mark_all(&mut self) {
        self.all = true;
        self.rows.clear();
    }

    /// Current sync version (bumped by every take).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Rows currently pending (diagnostics/tests; 0 while `all`).
    pub fn pending_rows(&self) -> usize {
        self.rows.len()
    }

    /// Whether the next take will report `all`.
    pub fn is_all(&self) -> bool {
        self.all
    }

    /// Host bytes pinned by the tracker's bookkeeping.
    pub fn host_bytes(&self) -> usize {
        self.rows.capacity() * std::mem::size_of::<usize>()
    }

    /// Drain the pending rows into `out` (cleared first; sorted and
    /// deduplicated), bump the version, and return the sync info. `out`'s
    /// capacity is reused across takes — with at least
    /// [`MAX_TRACKED_ROWS`] reserved, a take never allocates.
    pub fn take_into(&mut self, out: &mut Vec<usize>) -> DirtyTake {
        out.clear();
        let all = self.all;
        if !all {
            out.extend_from_slice(&self.rows);
            out.sort_unstable();
            out.dedup();
        }
        self.rows.clear();
        self.all = false;
        let prev = self.version;
        self.version += 1;
        DirtyTake {
            prev_version: prev,
            version: self.version,
            all,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_tracker_takes_all_then_tracks_rows() {
        let mut t = DirtyTracker::new();
        assert!(t.is_all());
        let mut out = Vec::new();
        let take = t.take_into(&mut out);
        assert!(take.all);
        assert_eq!((take.prev_version, take.version), (0, 1));
        assert!(out.is_empty());

        t.mark(5);
        t.mark(5); // per-plane duplicate collapses
        t.mark(2);
        t.mark(5);
        assert!(!t.is_all());
        let take = t.take_into(&mut out);
        assert!(!take.all);
        assert_eq!((take.prev_version, take.version), (1, 2));
        assert_eq!(out, vec![2, 5], "sorted + deduped");

        // nothing since the last take → empty delta
        let take = t.take_into(&mut out);
        assert!(!take.all);
        assert!(out.is_empty());
        assert_eq!(take.prev_version, 2);
    }

    #[test]
    fn mark_all_and_overflow_collapse() {
        let mut t = DirtyTracker::new();
        let mut out = Vec::new();
        t.take_into(&mut out);
        t.mark(1);
        t.mark_all();
        assert_eq!(t.pending_rows(), 0);
        assert!(t.take_into(&mut out).all);

        // overflow: exceed the cap with distinct rows
        for r in 0..=MAX_TRACKED_ROWS {
            t.mark(2 * r); // distinct, non-adjacent
        }
        assert!(t.is_all(), "row cap collapses to all");
        assert!(t.take_into(&mut out).all);
    }

    #[test]
    fn take_reuses_capacity() {
        let mut t = DirtyTracker::new();
        let mut out = Vec::with_capacity(MAX_TRACKED_ROWS);
        t.take_into(&mut out);
        for r in 0..100 {
            t.mark(r);
        }
        let cap = out.capacity();
        t.take_into(&mut out);
        assert_eq!(out.len(), 100);
        assert_eq!(out.capacity(), cap, "no reallocation on take");
    }
}
