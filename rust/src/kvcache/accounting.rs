//! Logical memory accounting — the paper's "KV cache size %" axis.
//!
//! All sizes are *logical*: what the cache would occupy in a deployment that
//! stores FP16 floats and bit-packed integer codes, independent of the f32
//! host representation this CPU reproduction computes with. A full
//! (uncompressed) cache stores K and V at FP16: `16 bits × 2 × d` per token
//! per head per layer. Quantized tiers store `bits × 2 × d` plus FP16
//! scale+zero per group for K and for V.

use super::{CacheConfig, TierConfig};
use crate::quant::Precision;

/// Logical bits consumed by one token's K+V in a tier (per head, per layer).
pub fn bits_per_token(tier: &TierConfig, head_dim: usize) -> u64 {
    match tier.precision {
        Precision::Fp16 => 2 * 16 * head_dim as u64,
        p => {
            let groups = (head_dim as u64).div_ceil(tier.group as u64);
            // K and V each: packed codes + (scale, zero) FP16 per group.
            2 * (p.bits() as u64 * head_dim as u64 + groups * 2 * 16)
        }
    }
}

/// Snapshot of tier occupancy for one session (summed over layers/heads).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Occupancy {
    /// Token-slots currently in the hi tier (across all layers & heads).
    pub hi_slots: u64,
    /// Token-slots in the lo tier.
    pub lo_slots: u64,
    /// Token-slots evicted (baselines only).
    pub evicted_slots: u64,
}

impl Occupancy {
    pub fn total_slots(&self) -> u64 {
        self.hi_slots + self.lo_slots + self.evicted_slots
    }
}

/// Logical size in bits of the current cache contents.
pub fn logical_bits(cfg: &CacheConfig, occ: &Occupancy) -> u64 {
    occ.hi_slots * bits_per_token(&cfg.hi, cfg.head_dim)
        + occ.lo_slots * bits_per_token(&cfg.lo, cfg.head_dim)
}

/// Logical size of the *uncompressed* (all-FP16) cache holding the same
/// token count.
pub fn full_bits(cfg: &CacheConfig, occ: &Occupancy) -> u64 {
    occ.total_slots() * bits_per_token(&TierConfig::fp16(), cfg.head_dim)
}

/// The paper's "cache size %": compressed / full, in percent.
pub fn cache_size_pct(cfg: &CacheConfig, occ: &Occupancy) -> f64 {
    let full = full_bits(cfg, occ);
    if full == 0 {
        return 100.0;
    }
    100.0 * logical_bits(cfg, occ) as f64 / full as f64
}

// ----------------------------------------------------------------------
// Host-footprint accounting (the *physical* side: what a session actually
// pins in host memory, as opposed to the logical bits above).
// ----------------------------------------------------------------------

/// Host memory pinned by one session's cache state, in bytes.
///
/// `shadow_bytes` are the pooled decode-shadow blocks (proportional to the
/// pool-rounded capacity, **not** `max_seq` — the point of the buffer
/// pool); `tier_bytes` is the packed hi/lo tier storage; `other_bytes` is
/// bookkeeping (placement map, balancers, scratch).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HostFootprint {
    pub shadow_bytes: usize,
    pub tier_bytes: usize,
    pub other_bytes: usize,
}

impl HostFootprint {
    pub fn total(&self) -> usize {
        self.shadow_bytes + self.tier_bytes + self.other_bytes
    }
}

/// Closed-form size of the decode-shadow blocks at a given per-plane slot
/// capacity: four `[planes, cap, head_dim]` f32 blocks (hi K/V + lo K/V
/// codes), four `[planes, cap, groups]` metadata blocks, and two
/// `[planes, cap]` masks. The footprint test asserts the manager's measured
/// shadow bytes equal this at the pool-rounded capacity.
pub fn shadow_bytes(planes: usize, cap: usize, head_dim: usize, groups: usize) -> usize {
    planes * cap * (4 * head_dim + 4 * groups + 2) * std::mem::size_of::<f32>()
}

/// Closed-form expected cache-size % for a given configuration and hi-tier
/// fraction — used by the experiment drivers to label the x-axis exactly the
/// way the paper does (e.g. importance 20% + INT2 retained ⇒ ~32–33%).
pub fn expected_cache_size_pct(cfg: &CacheConfig, hi_fraction: f64) -> f64 {
    let hi_bits = bits_per_token(&cfg.hi, cfg.head_dim) as f64;
    let lo_bits = match cfg.retention {
        super::RetentionMode::Retain => bits_per_token(&cfg.lo, cfg.head_dim) as f64,
        super::RetentionMode::Evict => 0.0,
    };
    let full = bits_per_token(&TierConfig::fp16(), cfg.head_dim) as f64;
    100.0 * (hi_fraction * hi_bits + (1.0 - hi_fraction) * lo_bits) / full
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::RetentionMode;

    fn cfg(hi: TierConfig, lo: TierConfig, retention: RetentionMode) -> CacheConfig {
        CacheConfig {
            layers: 4,
            kv_heads: 8,
            head_dim: 32,
            max_seq: 128,
            hi,
            lo,
            importance_ratio: 0.2,
            recent_window: 4,
            retention,
            outlier_aware: true,
            promotion: None,
            merge: None,
        }
    }

    #[test]
    fn fp16_token_bits() {
        // 2 (K+V) * 16 bits * 32 channels = 1024 bits
        assert_eq!(bits_per_token(&TierConfig::fp16(), 32), 1024);
    }

    #[test]
    fn int4_token_bits_with_overhead() {
        // group 16 → 2 groups; 2*(4*32 + 2*2*16) = 2*(128+64) = 384
        let t = TierConfig::quantized(Precision::Int4, 16);
        assert_eq!(bits_per_token(&t, 32), 384);
    }

    #[test]
    fn paper_table1_cache_sizes() {
        // Paper Table 1 reports ~63%/59%/56% for importance 50% with
        // INT4/3/2 retained (and ~45/40/35 @25%, ~41/36/32 @20%).
        // With group = d/2 overhead our closed form should land within ~2pp.
        let d = 128usize; // Llama-like head dim for the published numbers
        let mk = |p| {
            let mut c = cfg(
                TierConfig::fp16(),
                TierConfig::quantized(p, d / 2),
                RetentionMode::Retain,
            );
            c.head_dim = d;
            c
        };
        let cases = [
            (0.50, Precision::Int4, 63.0),
            (0.50, Precision::Int3, 59.0),
            (0.50, Precision::Int2, 56.0),
            (0.25, Precision::Int4, 45.0),
            (0.25, Precision::Int3, 40.0),
            (0.25, Precision::Int2, 35.0),
            (0.20, Precision::Int4, 41.0),
            (0.20, Precision::Int3, 36.0),
            (0.20, Precision::Int2, 32.0),
        ];
        for (ratio, prec, paper_pct) in cases {
            let got = expected_cache_size_pct(&mk(prec), ratio);
            assert!(
                (got - paper_pct).abs() < 2.5,
                "ratio {ratio} {prec:?}: got {got:.1}%, paper {paper_pct}%"
            );
        }
    }

    #[test]
    fn eviction_matches_importance_ratio() {
        let c = cfg(
            TierConfig::fp16(),
            TierConfig::quantized(Precision::Int4, 16),
            RetentionMode::Evict,
        );
        assert!((expected_cache_size_pct(&c, 0.25) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn occupancy_based_accounting() {
        let c = cfg(
            TierConfig::fp16(),
            TierConfig::quantized(Precision::Int2, 16),
            RetentionMode::Retain,
        );
        let occ = Occupancy {
            hi_slots: 10,
            lo_slots: 90,
            evicted_slots: 0,
        };
        let pct = cache_size_pct(&c, &occ);
        // int2 g16: 2*(64+64)=256 bits vs 1024 full → lo alone = 25%.
        let expect = 100.0 * (10.0 * 1024.0 + 90.0 * 256.0) / (100.0 * 1024.0);
        assert!((pct - expect).abs() < 1e-9);
    }

    #[test]
    fn shadow_bytes_closed_form() {
        // 4 planes × 64 slots × (4·8 + 4·2 + 2) f32s × 4 bytes
        assert_eq!(shadow_bytes(4, 64, 8, 2), 4 * 64 * 42 * 4);
        assert_eq!(shadow_bytes(0, 64, 8, 2), 0);
        let fp = HostFootprint {
            shadow_bytes: 10,
            tier_bytes: 20,
            other_bytes: 5,
        };
        assert_eq!(fp.total(), 35);
    }

    #[test]
    fn empty_cache_is_100pct() {
        let c = cfg(
            TierConfig::fp16(),
            TierConfig::quantized(Precision::Int2, 16),
            RetentionMode::Retain,
        );
        assert_eq!(cache_size_pct(&c, &Occupancy::default()), 100.0);
    }
}
