//! Reusable host-buffer pool for the cache managers' shadow blocks.
//!
//! A `CacheManager`'s decode-shadow blocks are the dominant host allocation
//! of a session. Before this pool existed they were sized to `max_seq` at
//! construction, so a freshly admitted session with a 64-token prompt paid
//! for a 4096-token cache — and the coordinator's `max_active` knob was a
//! memory landmine rather than a throughput dial. The pool makes session
//! footprint proportional to *occupancy*:
//!
//! * [`BufferPool::checkout`] hands out a zeroed [`PooledBuf`] of exactly
//!   the requested length, reusing a previously returned block of the same
//!   size class when one is free;
//! * managers grow their blocks in power-of-two capacity steps (see
//!   `CacheManager::ensure_capacity`), so the pool sees a small number of
//!   distinct size classes and the per-class free lists stay hot across
//!   requests with similar sequence lengths;
//! * dropping a [`PooledBuf`] returns the allocation to the pool, so the
//!   coordinator recycles blocks across sessions instead of round-tripping
//!   the allocator every admit/retire.
//!
//! The pool is a cheap clonable handle (`Arc<Mutex<..>>`): the lock is taken
//! only on checkout/return/growth, never on the per-token decode path.

use std::collections::BTreeMap;
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Mutex};

/// Free blocks retained per size class; excess returns go to the allocator.
const MAX_FREE_PER_CLASS: usize = 64;

#[derive(Default)]
struct PoolInner {
    /// Size class (element count) → free blocks of exactly that length.
    free: BTreeMap<usize, Vec<Vec<f32>>>,
    outstanding_blocks: usize,
    outstanding_bytes: usize,
    hits: u64,
    misses: u64,
}

/// Aggregate pool counters (for stats reporting and tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Blocks currently parked in the free lists.
    pub free_blocks: usize,
    /// Bytes currently parked in the free lists.
    pub free_bytes: usize,
    /// Blocks currently checked out.
    pub outstanding_blocks: usize,
    /// Bytes currently checked out.
    pub outstanding_bytes: usize,
    /// Checkouts served from the free lists.
    pub hits: u64,
    /// Checkouts that had to allocate.
    pub misses: u64,
}

/// Shared, clonable handle to a buffer pool.
#[derive(Clone)]
pub struct BufferPool {
    inner: Arc<Mutex<PoolInner>>,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool {
            inner: Arc::new(Mutex::new(PoolInner::default())),
        }
    }

    /// Check out a zeroed block of exactly `len` elements.
    pub fn checkout(&self, len: usize) -> PooledBuf {
        let buf = {
            let mut inner = self.inner.lock().unwrap();
            let reused = inner.free.get_mut(&len).and_then(|bucket| bucket.pop());
            let buf = match reused {
                Some(mut b) => {
                    inner.hits += 1;
                    b.fill(0.0);
                    b
                }
                None => {
                    inner.misses += 1;
                    vec![0.0f32; len]
                }
            };
            inner.outstanding_blocks += 1;
            inner.outstanding_bytes += len * std::mem::size_of::<f32>();
            buf
        };
        PooledBuf {
            buf,
            pool: self.clone(),
        }
    }

    fn give_back(&self, buf: Vec<f32>) {
        let mut inner = self.inner.lock().unwrap();
        inner.outstanding_blocks = inner.outstanding_blocks.saturating_sub(1);
        inner.outstanding_bytes = inner
            .outstanding_bytes
            .saturating_sub(buf.len() * std::mem::size_of::<f32>());
        if buf.is_empty() {
            return;
        }
        let bucket = inner.free.entry(buf.len()).or_default();
        if bucket.len() < MAX_FREE_PER_CLASS {
            bucket.push(buf);
        }
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().unwrap();
        let (mut free_blocks, mut free_bytes) = (0usize, 0usize);
        for (len, bucket) in &inner.free {
            free_blocks += bucket.len();
            free_bytes += bucket.len() * len * std::mem::size_of::<f32>();
        }
        PoolStats {
            free_blocks,
            free_bytes,
            outstanding_blocks: inner.outstanding_blocks,
            outstanding_bytes: inner.outstanding_bytes,
            hits: inner.hits,
            misses: inner.misses,
        }
    }
}

impl fmt::Debug for BufferPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "BufferPool({:?})", self.stats())
    }
}

/// A checked-out block. Derefs to `[f32]`; returns to its pool on drop.
pub struct PooledBuf {
    buf: Vec<f32>,
    pool: BufferPool,
}

impl Deref for PooledBuf {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.buf
    }
}

impl DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        self.pool.give_back(std::mem::take(&mut self.buf));
    }
}

impl fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PooledBuf(len={})", self.buf.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_is_zeroed_and_sized() {
        let pool = BufferPool::new();
        let b = pool.checkout(16);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn drop_returns_and_checkout_reuses() {
        let pool = BufferPool::new();
        {
            let mut b = pool.checkout(32);
            b[3] = 9.0;
        }
        let s = pool.stats();
        assert_eq!(s.free_blocks, 1);
        assert_eq!(s.outstanding_blocks, 0);
        assert_eq!(s.misses, 1);

        // same size class → reused and re-zeroed
        let b = pool.checkout(32);
        assert!(b.iter().all(|&x| x == 0.0));
        let s = pool.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.free_blocks, 0);
        assert_eq!(s.outstanding_blocks, 1);
        assert_eq!(s.outstanding_bytes, 32 * 4);
    }

    #[test]
    fn distinct_size_classes_do_not_mix() {
        let pool = BufferPool::new();
        drop(pool.checkout(8));
        let b = pool.checkout(16); // different class → fresh allocation
        assert_eq!(b.len(), 16);
        let s = pool.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 2);
        assert_eq!(s.free_blocks, 1); // the len-8 block still parked
    }

    #[test]
    fn zero_length_blocks_are_not_pooled() {
        let pool = BufferPool::new();
        drop(pool.checkout(0));
        let s = pool.stats();
        assert_eq!(s.free_blocks, 0);
        assert_eq!(s.outstanding_blocks, 0);
    }

    #[test]
    fn shared_handle_sees_the_same_pool() {
        let a = BufferPool::new();
        let b = a.clone();
        drop(a.checkout(64));
        assert_eq!(b.stats().free_blocks, 1);
        drop(b.checkout(64));
        assert_eq!(a.stats().hits, 1);
    }
}
